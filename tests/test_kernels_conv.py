"""apr_conv: shape sweep incl. the paper's LeNet/ResNet/MobileNet layer
geometries, vs the lax.conv oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels.apr_conv import apr_conv2d, conv2d_ref

TOL = dict(rtol=2e-4, atol=2e-4)


def rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


@pytest.mark.parametrize("case", [
    # (B, H, W, C, Hf, Wf, M, stride, pad)   — paper benchmark geometries
    (1, 32, 32, 1, 5, 5, 6, 1, 0),    # LeNet conv1
    (1, 14, 14, 6, 5, 5, 16, 1, 0),   # LeNet conv2
    (1, 16, 16, 16, 3, 3, 32, 2, 1),  # ResNet-20 stage transition
    (1, 8, 8, 64, 1, 1, 64, 1, 0),    # pointwise (MobileNet pw)
    (2, 10, 10, 8, 3, 3, 12, 1, 1),
])
def test_paper_layer_geometries(case):
    b, h, w, c, hf, wf, m, s, p = case
    x, f = rand((b, h, w, c), 0), rand((hf, wf, c, m), 1)
    out = apr_conv2d(x, f, stride=s, padding=p)
    ref = conv2d_ref(x, f, stride=s, padding=p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_bfloat16_inputs():
    x, f = rand((1, 8, 8, 4), 2, jnp.bfloat16), rand((3, 3, 4, 8), 3, jnp.bfloat16)
    out = apr_conv2d(x, f, padding=1)
    ref = conv2d_ref(x, f, padding=1)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


def test_hbm_residency_matches():
    x, f = rand((1, 8, 8, 16), 4), rand((3, 3, 16, 8), 5)
    out = apr_conv2d(x, f, residency="hbm", padding=1)
    ref = conv2d_ref(x, f, padding=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 14), c=st.integers(1, 8), m=st.integers(1, 8),
    hf=st.sampled_from([1, 3]), stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 1000),
)
def test_property_conv_matches_oracle(h, c, m, hf, stride, seed):
    pad = hf // 2
    x, f = rand((1, h, h, c), seed), rand((hf, hf, c, m), seed + 1)
    out = apr_conv2d(x, f, stride=stride, padding=pad)
    ref = conv2d_ref(x, f, stride=stride, padding=pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-4, atol=5e-4)
