"""repro.graph: tracer fidelity, fusion-pass legality (property-tested),
planner invariants, executor parity (XLA + Pallas dispatch), and the
graph-prefill serving path."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.graph import (GraphExecutor, all_passes, arena_plan, compile_fn,
                         default_passes, memory_report, run_passes, trace)
from repro.models.cnn import CNNS
from repro.quant import quantize_channelwise


def _mlp():
    """relu(x @ w1 + b1) @ w2 + b2 — one epilogue, one bare matmul."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    w1 = jax.random.normal(k1, (24, 32))
    b1 = jax.random.normal(k2, (32,))
    w2 = jax.random.normal(k3, (32, 8))
    b2 = jax.random.normal(k4, (8,))

    def fn(x):
        return jax.nn.relu(x @ w1 + b1) @ w2 + b2
    return fn


def _qmlp():
    """The same MLP with an int8 first-layer weight (dequant in-graph)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    qt = quantize_channelwise(jax.random.normal(k1, (24, 32)))
    b1 = jax.random.normal(k2, (32,))
    w2 = jax.random.normal(k3, (32, 8))

    def fn(x):
        w1 = (qt.q.astype(jnp.float32) * qt.scale).astype(x.dtype)
        return jax.nn.relu(x @ w1 + b1) @ w2
    return fn


_X = jax.random.normal(jax.random.PRNGKey(9), (4, 24))


class TestTrace:
    def test_mlp_ops_and_execution(self):
        fn = _mlp()
        g = trace(fn, _X)
        ops = [n.op for n in g.nodes]
        assert ops.count("matmul") == 2
        assert "max" in ops  # relu inlined out of its custom_jvp wrapper
        np.testing.assert_allclose(np.asarray(GraphExecutor(g)(_X)),
                                   np.asarray(fn(_X)), rtol=1e-5, atol=1e-5)

    def test_closure_weights_become_consts(self):
        g = trace(_mlp(), _X)
        consts = [v for v in g.values.values() if v.kind == "const"]
        shapes = {v.shape for v in consts}
        assert (24, 32) in shapes and (32, 8) in shapes

    def test_pytree_output_roundtrip(self):
        def fn(x):
            return {"a": x * 2.0, "b": (x + 1.0, x.sum())}
        g = trace(fn, _X)
        out = GraphExecutor(g)(_X)
        assert set(out) == {"a", "b"} and len(out["b"]) == 2
        np.testing.assert_allclose(np.asarray(out["a"]),
                                   np.asarray(_X) * 2.0, rtol=1e-6)

    def test_pytree_input_mismatch_raises(self):
        ex = GraphExecutor(trace(_mlp(), _X))
        with pytest.raises(TypeError):
            ex(_X, _X)


class TestScanUnroll:
    """Short ``lax.scan`` equations unroll into the graph (the recurrent
    decode tick's state machine must expose its ops to the fusion passes);
    long scans stay opaque single nodes the executor re-binds."""

    @staticmethod
    def _scan_fn(length, reverse=False):
        w = jax.random.normal(jax.random.PRNGKey(3), (8, 8))
        xs = jax.random.normal(jax.random.PRNGKey(4), (length, 8))

        def fn(carry):
            def body(c, x):
                c = jnp.tanh(c @ w + x)
                return c, c * 2.0
            return jax.lax.scan(body, carry, xs, reverse=reverse)
        return fn

    @pytest.mark.parametrize("reverse", [False, True])
    def test_short_scan_unrolls_and_matches(self, reverse):
        from repro.graph.trace import SCAN_UNROLL_CAP
        fn = self._scan_fn(5, reverse)
        c0 = jax.random.normal(jax.random.PRNGKey(5), (8,))
        g = trace(fn, c0)
        assert all(n.op != "scan" for n in g.nodes), \
            "a scan below the cap must be unrolled, not kept opaque"
        assert sum(n.op == "matmul" for n in g.nodes) == 5
        carry, ys = GraphExecutor(g)(c0)
        ref_carry, ref_ys = fn(c0)
        np.testing.assert_allclose(np.asarray(carry), np.asarray(ref_carry),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ref_ys),
                                   rtol=1e-6)
        assert 5 <= SCAN_UNROLL_CAP

    def test_long_scan_stays_opaque(self):
        from repro.graph.trace import SCAN_UNROLL_CAP
        fn = self._scan_fn(SCAN_UNROLL_CAP + 1)
        c0 = jax.random.normal(jax.random.PRNGKey(5), (8,))
        g = trace(fn, c0)
        scans = [n for n in g.nodes if n.op == "scan"]
        assert len(scans) == 1 and not any(n.op == "matmul" for n in g.nodes)
        carry, ys = GraphExecutor(g)(c0)
        ref_carry, ref_ys = fn(c0)
        np.testing.assert_allclose(np.asarray(carry), np.asarray(ref_carry),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(ref_ys),
                                   rtol=1e-6)


class TestPasses:
    def test_matmul_epilogue_annotated_for_pallas(self):
        g = run_passes(trace(_mlp(), _X), ["fuse_matmul_epilogue"])
        fused = [n for n in g.nodes if n.is_fused]
        assert fused and fused[0].pattern == "matmul_epilogue"
        assert fused[0].attrs["pallas_ok"]
        assert fused[0].attrs["activation"] == "relu"
        assert fused[0].attrs["bias"] is not None

    def test_conv_epilogue_on_lenet(self):
        spec = CNNS["lenet"]
        p = spec["params"](jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2,) + spec["input"])
        g = run_passes(trace(lambda xx: spec["forward"](p, xx), x))
        patterns = [n.pattern for n in g.nodes if n.is_fused]
        assert patterns.count("conv_epilogue") == 2   # both lenet convs
        assert "matmul_epilogue" in patterns          # the fc relu layers

    def test_residual_side_input_is_legal(self):
        """conv + add(shortcut) + relu fuses; the shortcut (produced before
        the conv) enters the cluster as a side input without cycling."""
        f = jax.random.normal(jax.random.PRNGKey(2), (3, 3, 4, 4))
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 6, 6, 4))

        def fn(x):
            from repro.kernels.apr_conv.ref import conv2d_ref
            h = conv2d_ref(x, f, stride=1, padding=1)
            return jax.nn.relu(h + x)  # residual
        g = run_passes(trace(fn, x), ["fuse_conv_epilogue"])
        fused = [n for n in g.nodes if n.is_fused]
        assert fused and fused[0].pattern == "conv_epilogue"
        # residual add is not the Pallas bias shape -> XLA cluster execution
        np.testing.assert_allclose(np.asarray(GraphExecutor(g)(x)),
                                   np.asarray(fn(x)), rtol=1e-5, atol=1e-5)

    def test_quant_fold_rewrites_dequant_matmul(self):
        g = run_passes(trace(_qmlp(), _X), ["fold_quant_dequant"])
        assert any(n.op == "quant_matmul" for n in g.nodes)
        # the int8 payload survives as a const input of the folded node
        qnode = next(n for n in g.nodes if n.op == "quant_matmul")
        wq = g.values[qnode.inputs[1]]
        assert wq.kind == "const" and jnp.dtype(wq.dtype) == jnp.int8

    def test_transposed_contraction_is_not_folded_or_dispatched(self):
        """Regression: einsum('km,kn->mn') contracts the lhs's FIRST dim —
        the 2-D collapse the fold/dispatch paths use would silently
        compute x @ w instead of x.T @ w, so the predicate must reject it
        (square shapes make the wrong product shape-compatible)."""
        qt = quantize_channelwise(jax.random.normal(jax.random.PRNGKey(6),
                                                    (16, 16)))
        x = jax.random.normal(jax.random.PRNGKey(7), (16, 16))

        def fn(x):
            w = (qt.q.astype(jnp.float32) * qt.scale).astype(x.dtype)
            return jax.nn.relu(jnp.einsum("km,kn->mn", x, w) + 1.0)
        ref = np.asarray(fn(x))
        for impl in ("xla", "pallas"):
            g = run_passes(trace(fn, x))
            assert not any(bn.op == "quant_matmul"
                           for n in g.nodes for bn in n.body_nodes())
            out = np.asarray(GraphExecutor(g, impl=impl)(x))
            np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)

    def test_per_row_bias_is_not_a_pallas_epilogue(self):
        """Regression: a bias added along the ROW axis of a square output
        has the right element count but the wrong axis — it must not be
        annotated pallas_ok (the fused kernels add bias per output
        channel), and both impls must stay exact via the XLA cluster."""
        w = jax.random.normal(jax.random.PRNGKey(8), (24, 4))
        c = jax.random.normal(jax.random.PRNGKey(9), (4,))
        x = jax.random.normal(jax.random.PRNGKey(10), (4, 24))

        def fn(x):
            return jnp.maximum(x @ w + c[:, None], 0.0)  # (4,4) + per-row
        g = run_passes(trace(fn, x))
        fused = [n for n in g.nodes if n.is_fused]
        assert all(n.attrs.get("bias") is None for n in fused)
        ref = np.asarray(fn(x))
        for impl in ("xla", "pallas"):
            out = np.asarray(GraphExecutor(g, impl=impl)(x))
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_per_row_dequant_scale_is_not_folded(self):
        """Regression: folding distributes the scale over the contraction,
        which is only sound for per-OUTPUT-channel (or scalar) scales — a
        per-row (K, 1) scale must be left unfused (square shapes make the
        wrong fold shape-compatible)."""
        qt = quantize_channelwise(jax.random.normal(jax.random.PRNGKey(11),
                                                    (16, 16)), axis=-1)
        assert qt.scale.shape == (16, 1)
        x = jax.random.normal(jax.random.PRNGKey(12), (4, 16))

        def fn(x):
            w = (qt.q.astype(jnp.float32) * qt.scale).astype(x.dtype)
            return x @ w
        g = run_passes(trace(fn, x), ["fold_quant_dequant"])
        assert not any(n.op == "quant_matmul" for n in g.nodes)
        for impl in ("xla", "pallas"):
            out = np.asarray(GraphExecutor(run_passes(trace(fn, x)),
                                           impl=impl)(x))
            np.testing.assert_allclose(out, np.asarray(fn(x)),
                                       rtol=1e-4, atol=1e-4)

    def test_every_registered_pass_is_idempotent_on_fused_graph(self):
        for name, p in all_passes().items():
            g = run_passes(trace(_mlp(), _X))
            before = len(g.nodes)
            assert len(p(g).nodes) == before, name


# --- fusion-legality properties (the satellite contract): any legal
# sequence of fusion passes preserves graph outputs vs the unfused
# reference — within tolerance on the fp path, and exactly at top-1 on
# the int8 path (quant folding changes rounding: W8A8 dynamic activation
# quantization vs dequant-then-fp32). ---


def _chosen_passes(mask: int, order_seed: int):
    names = default_passes()
    perm = list(itertools.permutations(range(4)))[order_seed]
    return [names[i] for i in perm if mask & (1 << i)]


@settings(max_examples=10, deadline=None)
@given(mask=st.integers(0, 15), order_seed=st.integers(0, 23))
def test_any_pass_subset_preserves_fp_outputs(mask, order_seed):
    chosen = _chosen_passes(mask, order_seed)
    fn = _mlp()
    ref = np.asarray(GraphExecutor(trace(fn, _X))(_X))
    out = np.asarray(GraphExecutor(run_passes(trace(fn, _X), chosen))(_X))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(mask=st.integers(0, 15), order_seed=st.integers(0, 23))
def test_any_pass_subset_is_top1_exact_on_int8_path(mask, order_seed):
    chosen = _chosen_passes(mask, order_seed)
    fn = _qmlp()
    ref = np.asarray(GraphExecutor(trace(fn, _X))(_X))
    out = np.asarray(GraphExecutor(run_passes(trace(fn, _X), chosen))(_X))
    assert (out.argmax(-1) == ref.argmax(-1)).all()
    np.testing.assert_allclose(out, ref, rtol=0.2, atol=0.2)


class TestPlanner:
    def test_fusion_reduces_intermediates(self):
        fn = _mlp()
        before = memory_report(trace(fn, _X))
        after = memory_report(run_passes(trace(fn, _X)))
        assert after.intermediate_bytes < before.intermediate_bytes
        assert after.intermediate_traffic < before.intermediate_traffic
        assert after.output_bytes == before.output_bytes

    def test_arena_reuses_and_never_overlaps_live_blocks(self):
        spec = CNNS["lenet"]
        p = spec["params"](jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2,) + spec["input"])
        g = trace(lambda xx: spec["forward"](p, xx), x)
        plan = arena_plan(g)
        assert 0 < plan.arena_bytes <= plan.naive_bytes
        assert plan.reuse_factor >= 1.0
        # overlap check: values live at the same step must not share bytes
        order = {n.id: i for i, n in enumerate(g.nodes)}
        consumers = g.consumers()
        producers = g.producers()
        lives = {}
        for vid, (off, size) in plan.offsets.items():
            start = order[producers[vid].id]
            end = max([order[c.id] for c in consumers.get(vid, [])],
                      default=start)
            lives[vid] = (start, end, off, size)
        for a, b in itertools.combinations(lives.values(), 2):
            if a[0] <= b[1] and b[0] <= a[1]:  # intervals overlap in time
                assert a[2] + a[3] <= b[2] or b[2] + b[3] <= a[2]


class TestExecutorPallasDispatch:
    def test_matmul_epilogue_dispatch_matches_xla(self):
        fn = _mlp()
        g = run_passes(trace(fn, _X))
        out = GraphExecutor(g, impl="pallas")(_X)
        np.testing.assert_allclose(np.asarray(out), np.asarray(fn(_X)),
                                   rtol=1e-4, atol=1e-4)

    def test_quant_fold_dispatch_matches_xla_exactly(self):
        """Pallas quant kernel and the XLA fold share the integer math and
        the jitted activation quantizer, so they agree near-bitwise."""
        fn = _qmlp()
        g = run_passes(trace(fn, _X))
        out_xla = np.asarray(GraphExecutor(g)(_X))
        out_pl = np.asarray(GraphExecutor(g, impl="pallas")(_X))
        np.testing.assert_allclose(out_pl, out_xla, rtol=1e-5, atol=1e-5)

    def test_standalone_quant_node_executes_both_impls(self):
        """Regression: a folded quant_matmul with NO epilogue stays a bare
        node (not a cluster) — both executor impls must run it (the int8
        engine's graph prefill hits this on every projection matmul)."""
        qt = quantize_channelwise(jax.random.normal(jax.random.PRNGKey(5),
                                                    (24, 16)))

        def fn(x):
            w = (qt.q.astype(jnp.float32) * qt.scale).astype(x.dtype)
            return x @ w  # no bias/activation tail
        g = run_passes(trace(fn, _X), ["fold_quant_dequant"])
        assert any(n.op == "quant_matmul" and not n.is_fused
                   for n in g.nodes)
        ref = np.asarray(fn(_X))
        for impl in ("xla", "pallas"):
            out = np.asarray(GraphExecutor(g, impl=impl)(_X))
            np.testing.assert_allclose(out, ref, rtol=0.1, atol=0.1)
            assert (out.argmax(-1) == ref.argmax(-1)).all(), impl

    def test_unrecognized_cluster_falls_back_to_xla(self):
        def fn(x):  # silu tail: fused cluster, but not the relu pattern
            return jax.nn.silu(x @ jnp.ones((24, 16)))
        g = run_passes(trace(fn, _X))
        out = GraphExecutor(g, impl="pallas")(_X)
        np.testing.assert_allclose(np.asarray(out), np.asarray(fn(_X)),
                                   rtol=1e-4, atol=1e-4)


class TestCompileCache:
    def test_keyed_compile_fn_memoizes(self):
        from repro.graph import clear_compile_cache
        clear_compile_cache()
        fn = _mlp()
        ex1 = compile_fn(fn, _X, key=("test", "mlp"))
        ex2 = compile_fn(fn, _X, key=("test", "mlp"))
        assert ex1 is ex2
        assert compile_fn(fn, _X, key=("test", "other")) is not ex1
        clear_compile_cache()


@pytest.mark.slow
class TestGraphServing:
    # greedy token-identity of graph prefill against the plain engine (fp32
    # and int8 weights, sharing on/off) lives in the consolidated sweep
    # (tests/test_engine_identity.py); this class keeps the graph-structure
    # assertion the sweep's generic cells cannot express.

    def test_graph_prefill_folds_int8_weights(self):
        """The int8-weight engine's params carry QuantizedTensor consts:
        fold_quant_dequant sees them and the prefill graph grows
        quant_matmul nodes (fused or standalone)."""
        from repro.configs import get_config
        from repro.models import build_model
        from repro.parallel.sharding import ParallelContext
        from repro.serve import PagedServeEngine, Request

        cfg = get_config("llama3-8b", smoke=True)
        bundle = build_model(cfg)
        qparams = bundle.quantize_params(
            bundle.init_params(jax.random.PRNGKey(0)))
        eng = PagedServeEngine(bundle, qparams, ParallelContext(None),
                               slots=2, page_size=16, prefill_chunk=16,
                               use_graph=True)
        reqs = [Request(rid=i, prompt=[1 + i] + [3 + (j % 4)
                                                 for j in range(17)],
                        max_new_tokens=3) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        g = eng._prefill.executor.graph
        assert any(bn.op == "quant_matmul"
                   for n in g.nodes for bn in n.body_nodes())
