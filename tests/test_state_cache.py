"""Stateful property test for :class:`repro.serve.state_cache.StateCache`.

Random engine-shaped interleavings of admit / append / snapshot /
spec-verify-rollback / free / defrag are replayed against a pure-Python
reference, with a host-side mirror of the device state pool so *content*
is checked, not just bookkeeping: every id the cache says holds the state
after ``n`` committed tokens must hold exactly the digest of that slot's
first ``n`` tokens (digests depend on the full token history, so restoring
a checkpoint from the wrong speculative branch is caught even when the
token *count* matches).

Invariants checked after every operation (with the pending-copy queue
drained into the mirror pool, the way the engine drains it before any
forward pass reads state):

* current-state visibility — ``pool[cur] == digest(committed tokens)``
  whenever ``length > 0``, and ``read_id`` routes zero-length slots to the
  pristine ``NULL_STATE``;
* checkpoint visibility — every ring entry ``(c, sid)`` satisfies
  ``pool[sid] == digest(committed[:c])``;
* ring bounds — ascending unique counts, at most ``ring_depth`` entries,
  all counts ``<= length``;
* allocation hygiene — live ids are distinct, every refcount is exactly 1
  (states are never shared), ``used + free == num_slots``, and
  contract-respecting usage never raises :class:`OutOfStateSlots`;
* defrag — live ids end up compact at the low end and every content
  invariant still holds after the queued moves run;
* teardown — freeing every slot leaves the pool empty (leak-free).

Runs under the ``tests/_hyp`` shim: real hypothesis when installed
(``HYPOTHESIS_PROFILE=ci`` derandomized in the gate job), a deterministic
seeded fallback otherwise.
"""
import random

from _hyp import given, settings, st

from repro.serve.state_cache import (NULL_STATE, TRASH_STATE, StateCache,
                                     _FIRST)


def _digest(tokens):
    """Content fingerprint of a state that has absorbed ``tokens``."""
    return hash(("state",) + tuple(tokens))


_NULL_DIGEST = _digest(())


class _Mirror:
    """Host-side stand-in for the device state pool + reference model."""

    def __init__(self, cache: StateCache):
        self.cache = cache
        self.pool = {NULL_STATE: _NULL_DIGEST}   # physical id -> digest
        self.committed = {}                      # logical slot -> [tokens]

    def drain(self):
        for src, dst in self.cache.pop_state_copies():
            assert dst != NULL_STATE, "nothing may scatter into NULL_STATE"
            # a freshly alloc'd current slot is never written before its
            # first commit (reads route to NULL_STATE), so a checkpoint
            # taken at length 0 legitimately copies stale content
            self.pool[dst] = self.pool.get(src, ("stale", src))

    # -- operations (engine-shaped) --------------------------------------
    def admit(self, slot):
        self.cache.alloc(slot)
        self.committed[slot] = []

    def append(self, slot, token):
        """One decode tick: read at ``read_id``, write the post-token
        state in place at ``cur``, commit the new length."""
        c = self.cache
        toks = self.committed[slot]
        rid = c.read_id(slot)
        assert self.pool.get(rid) == _digest(toks)
        toks.append(token)
        self.pool[c.cur(slot)] = _digest(toks)
        c.commit(slot, len(toks))

    def snapshot(self, slot):
        """Plain checkpoint of state the slot already holds."""
        self.cache.snapshot(slot)

    def spec_tick(self, slot, draft, accepted):
        """A verify tick: every drafted position scatters its post-token
        state into a fresh empty checkpoint, then the rollback restores
        the checkpoint at the accepted count (``accepted + 1`` counts the
        pending token, mirroring the spec engine's ``1 + accepted``)."""
        c = self.cache
        toks = self.committed[slot]
        base = len(toks)
        branch = toks + draft
        for t in range(len(draft)):
            sid = c.snapshot(slot, base + t + 1, copy=False)
            assert sid not in (NULL_STATE, TRASH_STATE)
            self.pool[sid] = _digest(branch[:base + t + 1])
        target = base + accepted + 1
        c.truncate(slot, target)
        self.committed[slot] = branch[:target]

    def free(self, slot):
        self.cache.free_slot(slot)
        del self.committed[slot]

    def defrag(self):
        moves = self.cache.defrag()
        live = sorted(self.cache._ref)
        assert live == list(range(_FIRST, _FIRST + len(live))), \
            "defrag must compact live ids to the low end"
        return moves

    # -- invariants -------------------------------------------------------
    def check(self):
        c = self.cache
        self.drain()
        live = []
        for slot in range(c.slots):
            if slot not in self.committed:
                assert c.cur(slot) == NULL_STATE
                assert c.snapshot_counts(slot) == ()
                assert c.length(slot) == 0
                continue
            toks = self.committed[slot]
            assert c.length(slot) == len(toks)
            live.append(c.cur(slot))
            if toks:
                assert c.read_id(slot) == c.cur(slot)
                assert self.pool[c.cur(slot)] == _digest(toks)
            else:
                assert c.read_id(slot) == NULL_STATE
            counts = c.snapshot_counts(slot)
            assert list(counts) == sorted(set(counts)), \
                "ring counts must be ascending and unique"
            assert len(counts) <= c.ring_depth
            assert all(n <= len(toks) for n in counts)
            for n, sid in c._ring[slot]:
                live.append(sid)
                if n > 0:
                    assert self.pool[sid] == _digest(toks[:n])
                # an n == 0 checkpoint holds stale content by design: a
                # restore to 0 sets length 0, and read_id routes
                # zero-length slots to NULL_STATE, so it is never read
        assert len(live) == len(set(live)), "live ids must be distinct"
        assert all(c.refcount(sid) == 1 for sid in live)
        assert all(sid >= _FIRST for sid in live)
        assert c.used_slots == len(live)
        assert c.used_slots + c.free_slots == c.num_slots


@settings(max_examples=300, deadline=None)
@given(slots=st.integers(1, 3), ring=st.integers(1, 3),
       seed=st.integers(0, 10 ** 6))
def test_state_cache_random_interleavings(slots, ring, seed):
    rng = random.Random((slots, ring, seed).__hash__())
    cache = StateCache(slots=slots, ring_depth=ring)
    assert cache.pool_slots == 2 + slots * (1 + ring)
    m = _Mirror(cache)

    for _ in range(40):
        active = sorted(m.committed)
        idle = [s for s in range(slots) if s not in m.committed]
        ops = ["defrag"]
        if idle:
            ops += ["admit"] * 3
        if active:
            ops += ["append"] * 6 + ["snapshot"] * 2 + ["spec"] * 3 + ["free"]
        op = rng.choice(ops)
        if op == "admit":
            m.admit(rng.choice(idle))
        elif op == "append":
            m.append(rng.choice(active), rng.randrange(1000))
        elif op == "snapshot":
            m.snapshot(rng.choice(active))
        elif op == "spec":
            k = rng.randint(1, ring)
            draft = [rng.randrange(1000) for _ in range(k)]
            m.spec_tick(rng.choice(active), draft, rng.randint(0, k - 1))
        elif op == "free":
            m.free(rng.choice(active))
        else:
            m.defrag()
        m.check()

    # leak-free teardown
    for slot in sorted(m.committed):
        m.free(slot)
    m.check()
    assert cache.used_slots == 0
    assert cache.free_slots == cache.num_slots


@settings(max_examples=60, deadline=None)
@given(slots=st.integers(1, 3), ring=st.integers(1, 3),
       seed=st.integers(0, 10 ** 6))
def test_state_cache_rejects_contract_violations(slots, ring, seed):
    """Misuse raises without corrupting state: double alloc, commit and
    snapshot on an empty slot, truncate with no checkpoint at the target."""
    rng = random.Random((slots, ring, seed, "errs").__hash__())
    cache = StateCache(slots=slots, ring_depth=ring)
    m = _Mirror(cache)
    slot = rng.randrange(slots)

    for fn in (lambda: cache.commit(slot, 1),
               lambda: cache.snapshot(slot),
               lambda: cache.truncate(slot, 0)):
        try:
            fn()
        except ValueError:
            pass
        else:
            raise AssertionError("empty slot must reject commit/snap/trunc")

    m.admit(slot)
    try:
        cache.alloc(slot)
    except ValueError:
        pass
    else:
        raise AssertionError("double alloc must be rejected")

    for t in range(1 + rng.randrange(3)):
        m.append(slot, t)
    want = cache.length(slot) + 5   # no checkpoint there, never will be
    try:
        cache.truncate(slot, want)
    except ValueError as e:
        assert "checkpoint" in str(e)
    else:
        raise AssertionError("truncate without a checkpoint must raise")
    m.check()                       # the failed truncate changed nothing
