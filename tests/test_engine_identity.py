"""Cross-engine greedy token-identity matrix.

One parametrized sweep covers every serving engine variant —

    {slot, paged, paged+graph, speculative} x {fp32, int8 weights}
        x {bf16 KV, int8 KV} x {prefix sharing off, on}

— over one shared-system-prompt trace and asserts every cell emits exactly
the same greedy tokens as the golden reference (the plain paged engine:
fp32 weights, bf16 KV, sharing off).  This consolidates the per-feature
identity tests that accumulated across PRs (paged-vs-slot, int8-weight and
int8-KV top-1 agreement, graph prefill, speculative ngram) into a single
matrix, so a new engine axis extends the sweep instead of adding another
ad-hoc pairwise test.

Greedy identity is the repo-wide acceptance invariant: every serving
optimization (paging, chunked prefill, quantization, fused graph prefill,
speculative verify, prefix sharing + copy-on-write) must be invisible in
the emitted tokens.

The trace is deliberately adversarial for the *sharing* axis: every prompt
is one shared two-page head plus a unique tail, and the engine runs with
more requests than slots — so the matrix exercises prefix matching, the
concurrent-prefill retro-dedup path, and the COW split at the divergence
boundary, while still requiring byte-identical outputs.

The *mesh* axis re-runs the matrix on a 4-way tensor-parallel device mesh
(`repro.parallel.tp`): same cells, TP-divisible head geometry, and each
sharded cell compared to its *unsharded twin* — a 1-device paged engine
with the same (weights, kv_dtype) — token for token.  The twin, not the
bf16 golden, is the right reference for this axis: sharding must be
invisible *given* the cell's precision config (and in fact the mesh
forward is bitwise-identical to 1 device, see `tp_einsum`), whereas
int8-KV rounding may legitimately flip a low-margin token on the lifted
geometry just as it may on any new geometry.  The bf16-vs-int8 token
identity is locked by the 1-device matrix above on the default geometry.
The mesh cells need 4 devices and therefore only run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI ``mesh``
leg); on a plain single-device run they skip.

The *family* axis runs the recurrent-state families — rwkv6 (token-shift
+ wkv state), mamba2 (pure SSD state machine), zamba2 (hybrid: shared
attention over KV pages + mamba state slots) — through the same matrix
discipline: {paged, paged+graph, speculative} against a slot-engine
golden per family.  The hybrid's graph cell asserts the documented
rejection instead (its f32 SSD update is FMA-contraction sensitive at
cluster boundaries, so graph execution can't guarantee token identity —
see ``PagedServeEngine``).  Prefix sharing is structurally unsupported there (a
state is a lossy running summary), so instead of a sharing-on cell the
axis asserts the loud rejection; likewise the mesh leg asserts these
families reject a TP mesh instead of silently running unsharded.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.parallel import ParallelContext
from repro.serve import PagedServeEngine, Request, ServeEngine
from repro.spec import SpeculativeServeEngine

PCTX = ParallelContext(None)

#: shared 2-page head (page_size=8) every request starts with
_HEAD = [2 + (j % 5) for j in range(16)]

#: engine geometry shared by every paged-family cell
_PAGED_KW = dict(slots=2, page_size=8, num_pages=16, prefill_chunk=8)

#: (engine, weights, kv_dtype) cells; every cell runs sharing off AND on
MATRIX = [
    ("paged", "fp32", "bfloat16"),
    ("paged", "int8", "bfloat16"),
    ("paged", "fp32", "int8"),
    ("paged", "int8", "int8"),
    ("graph", "fp32", "bfloat16"),
    ("graph", "int8", "bfloat16"),
    ("spec", "fp32", "bfloat16"),
    ("spec", "int8", "bfloat16"),
    ("spec", "fp32", "int8"),
]


def _trace(n=3, max_new=6):
    return [Request(rid=i, prompt=_HEAD + [50 + i] * 4, max_new_tokens=max_new)
            for i in range(n)]


def _drain(eng):
    reqs = _trace()
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


def _build(engine, bundle, params, *, kv_dtype, sharing, pctx=PCTX):
    kw = dict(_PAGED_KW, kv_dtype=kv_dtype, prefix_sharing=sharing)
    if engine == "paged":
        return PagedServeEngine(bundle, params, pctx, **kw)
    if engine == "graph":
        return PagedServeEngine(bundle, params, pctx, use_graph=True, **kw)
    assert engine == "spec"
    return SpeculativeServeEngine(bundle, params, pctx, spec_k=3, **kw)


@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    return bundle, bundle.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qparams(llama):
    bundle, params = llama
    return bundle.quantize_params(params)


@pytest.fixture(scope="module")
def golden(llama):
    """The matrix reference: plain paged engine, fp32, bf16 KV, no sharing."""
    bundle, params = llama
    return _drain(PagedServeEngine(bundle, params, PCTX, **_PAGED_KW))


@pytest.mark.slow
@pytest.mark.parametrize("engine,weights,kv_dtype", MATRIX,
                         ids=[f"{e}-{w}w-{k}kv" for e, w, k in MATRIX])
def test_identity_matrix(engine, weights, kv_dtype, llama, qparams, golden):
    bundle, params = llama
    p = qparams if weights == "int8" else params

    out_off = _drain(_build(engine, bundle, p,
                            kv_dtype=kv_dtype, sharing=False))
    assert out_off == golden, (engine, weights, kv_dtype, "sharing off")

    eng = _build(engine, bundle, p, kv_dtype=kv_dtype, sharing=True)
    out_on = _drain(eng)
    assert out_on == golden, (engine, weights, kv_dtype, "sharing on")

    # sharing must actually have engaged on this trace (prefix hits on the
    # late admission, retro-dedup between the concurrent first two)
    m = eng.metrics
    shared = (m.prefix_hit_requests + m.cow_copies
              + eng.kv.stats["dedup_reclaimed"])
    assert shared > 0, "prefix sharing never engaged"
    assert m.effective_kv_multiplier > 1.0
    assert eng.kv.used_pages == 0        # all requests flushed on finish

    if engine == "graph":
        summary = eng._prefill.executor.graph.summary()
        assert summary["n_fused"] > 0
        assert summary["n_nodes"] < summary["n_primitive_ops"]
        # the clustering was chosen by the cost model (on by default) and
        # the decision artifact rides on the executor for --explain
        schedule = eng._prefill.executor.schedule
        assert schedule is not None
        assert schedule.passes and schedule.traffic_reduction > 1.0
        if weights == "int8":
            g = eng._prefill.executor.graph
            assert any(bn.op == "quant_matmul"
                       for n in g.nodes for bn in n.body_nodes())
            assert "fold_quant_dequant" in schedule.passes


@pytest.mark.slow
@pytest.mark.parametrize("weights", ["fp32", "int8"])
def test_slot_engine_matches_matrix_reference(weights, llama, qparams, golden):
    """The contiguous slot engine (no paging, no sharing axis) anchors the
    matrix to the numerics baseline for both weight precisions."""
    bundle, params = llama
    p = qparams if weights == "int8" else params
    eng = ServeEngine(bundle, p, PCTX, slots=2, max_seq=64)
    assert _drain(eng) == golden


# ---------------------------------------------------------------------------
# mesh axis: the whole matrix again, 4-way tensor parallel
# ---------------------------------------------------------------------------

requires_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def tp_llama():
    """Llama smoke lifted to a 4-shardable head layout (h=8, hkv=4); the
    default smoke geometry (h=4, hkv=2) doesn't divide over 4 shards."""
    cfg = get_config("llama3-8b", smoke=True)
    cfg = dataclasses.replace(cfg, num_heads=8, num_kv_heads=4,
                              head_dim=cfg.resolved_head_dim)
    bundle = build_model(cfg)
    return bundle, bundle.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tp_qparams(tp_llama):
    bundle, params = tp_llama
    return bundle.quantize_params(params)


@pytest.fixture(scope="module")
def tp_reference(tp_llama, tp_qparams):
    """Memoized 1-device twin per (weights, kv_dtype): the plain paged
    engine on the lifted geometry with the cell's own precision config.
    The mesh invariant is sharded == unsharded twin (bitwise, in fact),
    *not* lifted == default and not int8 == bf16 on this geometry."""
    bundle, params = tp_llama
    cache = {}

    def ref(weights, kv_dtype):
        key = (weights, kv_dtype)
        if key not in cache:
            p = tp_qparams if weights == "int8" else params
            cache[key] = _drain(PagedServeEngine(
                bundle, p, PCTX, kv_dtype=kv_dtype, **_PAGED_KW))
        return cache[key]

    return ref


@pytest.fixture(scope="module")
def mesh4():
    from repro.parallel import make_serving_mesh, make_tp_context
    return make_tp_context(make_serving_mesh(4))


@requires_mesh
@pytest.mark.slow
@pytest.mark.parametrize("engine,weights,kv_dtype", MATRIX,
                         ids=[f"{e}-{w}w-{k}kv" for e, w, k in MATRIX])
def test_identity_matrix_mesh4(engine, weights, kv_dtype,
                               tp_llama, tp_qparams, tp_reference, mesh4):
    bundle, params = tp_llama
    p = tp_qparams if weights == "int8" else params

    if engine == "graph":
        # the graph executor is a host-side op loop; a TP mesh must be
        # rejected loudly at construction, not silently run unsharded
        with pytest.raises(ValueError, match="TP mesh"):
            _build(engine, bundle, p, kv_dtype=kv_dtype, sharing=False,
                   pctx=mesh4)
        return

    twin = tp_reference(weights, kv_dtype)
    eng = None
    for sharing in (False, True):
        eng = _build(engine, bundle, p, kv_dtype=kv_dtype, sharing=sharing,
                     pctx=mesh4)
        out = _drain(eng)
        assert out == twin, (engine, weights, kv_dtype,
                             f"mesh4 sharing={sharing}")
        assert eng.kv.used_pages == 0

    # the cells really ran sharded: 4-way plan, KV pool bytes per device
    # at least 3x below the logical pool (hkv=4 shards exactly 4x)
    assert eng.tp_plan is not None and eng.tp_plan.degree == 4
    assert eng.tp_plan.shard_kv
    assert eng.kv_pool_bytes_per_device() * 3 <= eng.kv_pool_bytes()
    assert eng.weight_bytes_per_device() * 2 <= _tree_bytes(eng.params)


def _tree_bytes(tree):
    return sum(a.nbytes for a in jax.tree.leaves(tree)
               if hasattr(a, "nbytes"))


# ---------------------------------------------------------------------------
# family axis: recurrent/hybrid families, same discipline
# ---------------------------------------------------------------------------

#: attention-free with token-shift state / pure SSD state machine / hybrid
#: (shared attention over KV pages + mamba state slots in one block table)
FAMILY_ARCHS = ["rwkv6-3b", "mamba2-2.7b", "zamba2-1.2b"]

#: (engine,) cells per family; sharing is structurally unsupported for
#: recurrent state, so the sharing axis is a rejection test instead
FAMILY_CELLS = ["paged", "graph", "spec"]


@pytest.fixture(scope="module", params=FAMILY_ARCHS)
def family(request):
    cfg = get_config(request.param, smoke=True)
    bundle = build_model(cfg)
    return bundle, bundle.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def family_golden(family):
    """Per-family numerics baseline: the contiguous slot engine, which
    carries recurrent state as dense per-slot registers with no paging,
    no checkpoints, no graph in the loop."""
    bundle, params = family
    return _drain(ServeEngine(bundle, params, PCTX, slots=2, max_seq=64))


@pytest.mark.slow
@pytest.mark.parametrize("engine", FAMILY_CELLS)
def test_family_identity_matrix(engine, family, family_golden):
    bundle, params = family
    if engine == "graph" and bundle.cfg.family == "hybrid":
        # cluster-at-a-time execution cannot guarantee token identity for
        # the hybrid's FMA-contraction-sensitive f32 SSD update: the cell
        # is a loud rejection, not a silent near-miss
        with pytest.raises(ValueError, match="use_graph.*hybrid"):
            _build(engine, bundle, params, kv_dtype="bfloat16",
                   sharing=False)
        return
    eng = _build(engine, bundle, params, kv_dtype="bfloat16", sharing=False)
    assert _drain(eng) == family_golden, (bundle.cfg.name, engine)

    # state pool drained leak-free: every slot's current id + ring
    # checkpoints released on finish, pages (hybrid) flushed too
    assert eng.state is not None and eng.state.used_slots == 0
    assert eng.kv.used_pages == 0

    if engine == "graph":
        # both compiled steps really fused (the decode tick is the new one)
        for step in (eng._prefill, eng._decode_step):
            summary = step.executor.graph.summary()
            assert summary["n_fused"] > 0
            assert summary["n_nodes"] < summary["n_primitive_ops"]
    if engine == "spec":
        # rollbacks happened and were invisible: every speculative step
        # restored a state checkpoint (accepted-count snapshot -> cur)
        assert eng.state.stats["restores"] > 0


@pytest.mark.slow
def test_family_rejects_prefix_sharing(family):
    """A recurrent state is a lossy running summary, not an addressable
    prefix — sharing must be rejected loudly at construction."""
    bundle, params = family
    with pytest.raises(ValueError, match="prefix_sharing"):
        _build("paged", bundle, params, kv_dtype="bfloat16", sharing=True)


@requires_mesh
@pytest.mark.slow
def test_family_rejects_tp_mesh(family, mesh4):
    """State pools are per-sequence registers, not head-sharded tensors;
    a TP mesh must be rejected, not silently run unsharded."""
    bundle, params = family
    with pytest.raises(ValueError, match="TP"):
        _build("paged", bundle, params, kv_dtype="bfloat16", sharing=False,
               pctx=mesh4)
