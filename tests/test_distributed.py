"""Multi-device tests (8 host devices) run in subprocesses so the main
pytest process keeps its single-device view (XLA fixes the device count at
first init)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SCRIPT = os.path.join(HERE, "_dist_checks.py")

# These all passed xfail-free since every shard_map region went fully
# manual (explicit collectives on every mesh axis) — the partial-manual
# regions that used to abort 0.4.x XLA's SPMD partitioner are gone; see
# docs/known_failures.md for the history.


def run_check(name: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, SCRIPT, name],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"{name} failed:\n{proc.stdout}\n{proc.stderr[-3000:]}"
    assert f"CHECK {name} OK" in proc.stdout
    return proc.stdout


@pytest.mark.slow
def test_moe_expert_parallel_matches_local():
    run_check("moe_ep")


@pytest.mark.slow
def test_pipeline_parallel_forward_and_grad():
    run_check("pipeline_parallel")


@pytest.mark.slow
def test_crosspod_gradient_compression():
    run_check("compression")


@pytest.mark.slow
def test_elastic_remesh_8_to_4_devices():
    run_check("elastic_remesh")
