"""repro.quant: round-trip bounds, quant_matmul vs oracle across the tune
space, int8 paged-KV (jnp path + Pallas kernel), and engine-level greedy
top-1 agreement between the float and int8-weight decode paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.bench import get_spec
from repro.kernels.apr_matmul.ref import matmul_ref
from repro.kernels.flash_decode import (flash_decode_paged,
                                        paged_decode_attention_q_ref,
                                        paged_decode_attention_ref)
from repro.kernels.quant_matmul import (quant_matmul, quant_matmul_ref,
                                        quantize_activations, quantize_weights)
from repro.quant import (QuantizedTensor, quantize_channelwise,
                         quantize_params, weight_bytes)


def rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Quantize / dequantize round trip.
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 96), cols=st.integers(1, 96),
       seed=st.integers(0, 2**16))
def test_property_roundtrip_error_bound(rows, cols, seed):
    """Symmetric per-channel int8: |w - dq(q(w))| <= amax_channel / 254
    (half a quantization step of the per-channel grid)."""
    w = rand((rows, cols), seed)
    qt = quantize_channelwise(w, axis=-2)
    err = jnp.abs(qt.dequantize() - w)
    bound = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0 / 2.0
    assert bool(jnp.all(err <= bound + 1e-7)), float(jnp.max(err - bound))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 512), seed=st.integers(0, 2**16))
def test_property_activation_quant_rowwise_bound(n, seed):
    x = rand((4, n), seed)
    q, scale = quantize_activations(x)
    err = jnp.abs(q.astype(jnp.float32) * scale - x)
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 254.0
    assert bool(jnp.all(err <= bound + 1e-7))


def test_roundtrip_exact_on_grid():
    """Values already on the per-channel grid (integer multiples of
    amax/127, incl. the amax itself at +/-127) survive exactly."""
    codes = jnp.array([[127.0, -127.0], [64.0, 127.0], [-127.0, 0.0]])
    scales = jnp.array([[0.5, 0.031]])  # per-channel grid steps
    w = codes * scales
    qt = quantize_channelwise(w, axis=-2)
    np.testing.assert_array_equal(np.asarray(qt.q), np.asarray(codes, np.int8))
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(w),
                               rtol=1e-6, atol=0)


def test_zero_channel_is_stable():
    w = jnp.zeros((8, 4), jnp.float32)
    qt = quantize_channelwise(w)
    assert not bool(jnp.any(jnp.isnan(qt.dequantize())))
    np.testing.assert_array_equal(np.asarray(qt.q), 0)


def test_quantized_tensor_is_pytree():
    qt = quantize_channelwise(rand((16, 8), 0))
    leaves = jax.tree.leaves(qt)
    assert len(leaves) == 2
    qt2 = jax.tree.map(lambda a: a, qt)
    assert isinstance(qt2, QuantizedTensor)
    sliced = jax.tree.map(lambda a: a[:1], quantize_channelwise(rand((4, 16, 8), 1)))
    assert sliced.q.shape == (1, 16, 8) and sliced.scale.shape == (1, 1, 8)


# ---------------------------------------------------------------------------
# quant_matmul kernel vs oracle.
# ---------------------------------------------------------------------------


def test_quant_matmul_matches_oracle_across_tune_space():
    """Every legal candidate config must reproduce the oracle — the same
    gate the autotuner applies before timing."""
    spec = get_spec("quant_matmul")
    shape = {"m": 64, "k": 128, "n": 64}
    args = spec.make_inputs(shape, "float32", 0)
    ref = np.asarray(spec.ref(args), np.float32)
    candidates = spec.candidates(shape)
    assert len(candidates) >= 4
    for cfg in candidates:
        out = np.asarray(spec.run(args, cfg, True), np.float32)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                                   err_msg=str(cfg))


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),
    (64, 128, 128),
    (100, 300, 120),     # unaligned -> padding path
    (1, 128, 257),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_shapes_and_dtypes(m, k, n, dtype):
    x = rand((m, k), 0, dtype)
    w_q, w_scale = quantize_weights(rand((k, n), 1))
    out = quant_matmul(x, w_q, w_scale)
    ref = quant_matmul_ref(x, w_q, w_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_quant_matmul_accepts_quantized_tensor():
    x, w = rand((32, 128), 0), rand((128, 64), 1)
    qt = quantize_channelwise(w)
    out = quant_matmul(x, qt)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(quant_matmul_ref(x, qt.q, qt.scale)),
                               rtol=1e-4, atol=1e-4)


def test_quant_matmul_close_to_fp32_product():
    """W8A8 error stays ~1% of the fp32 product's scale on gaussian data."""
    x, w = rand((64, 256), 2), rand((256, 64), 3)
    w_q, w_scale = quantize_weights(w)
    out = np.asarray(quant_matmul(x, w_q, w_scale))
    fp = np.asarray(matmul_ref(x, w))
    rel = np.max(np.abs(out - fp)) / np.max(np.abs(fp))
    assert rel < 0.05, rel


# ---------------------------------------------------------------------------
# Param-tree quantization.
# ---------------------------------------------------------------------------


def _smoke_bundle():
    from repro.configs import get_config
    from repro.models import build_model
    return build_model(get_config("llama3-8b", smoke=True))


def test_quantize_params_selects_matmul_weights_only():
    bundle = _smoke_bundle()
    params = bundle.init_params(jax.random.PRNGKey(0))
    q = bundle.quantize_params(params)
    assert not isinstance(q["embed"], QuantizedTensor)      # gathered
    assert not isinstance(q["blk.0.ln1"], QuantizedTensor)  # 1D gain
    assert isinstance(q["blk.0.mlp.w_gate"], QuantizedTensor)
    assert isinstance(q["blk.0.attn.wq"], QuantizedTensor)
    wb = weight_bytes(q)
    assert wb["bytes_fp32"] / wb["bytes_actual"] >= 2.0     # the headline
    # stacked layers keep their leading dim on both leaves
    qt = q["blk.0.mlp.w_gate"]
    assert qt.q.shape[0] == qt.scale.shape[0]


def test_quantize_params_unsupported_family_raises():
    from repro.configs import get_config
    from repro.models import build_model
    bundle = build_model(get_config("rwkv6-3b", smoke=True))
    with pytest.raises(ValueError, match="int8"):
        bundle.quantize_params(bundle.init_params(jax.random.PRNGKey(0)))


def test_quantize_params_audio_family_forward_runs():
    """Positional tables (pos_dec/pos_enc) are consumed by indexing, not
    matmul — they must stay plain arrays or encdec's forward crashes."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel.sharding import ParallelContext
    bundle = build_model(get_config("whisper-large-v3", smoke=True))
    cfg = bundle.cfg
    params = bundle.init_params(jax.random.PRNGKey(0))
    qparams = bundle.quantize_params(params)
    assert not isinstance(qparams["pos_dec"], QuantizedTensor)
    assert not isinstance(qparams["pos_enc"], QuantizedTensor)
    assert isinstance(qparams["dec.mlp.w1"], QuantizedTensor)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32),
             "frames": jnp.zeros((1, 16, cfg.d_model), jnp.float32)}
    pctx = ParallelContext(None)
    lf = bundle.forward(params, batch, pctx).astype(jnp.float32)
    lq = bundle.forward(qparams, batch, pctx).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(lf - lq))) < 1.0


def test_forward_logits_close_under_int8_weights():
    from repro.models import lm
    from repro.parallel.sharding import ParallelContext
    bundle = _smoke_bundle()
    cfg = bundle.cfg
    params = bundle.init_params(jax.random.PRNGKey(0))
    qparams = bundle.quantize_params(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    pctx = ParallelContext(None)
    lf = lm.lm_forward(params, cfg, pctx, toks).astype(jnp.float32)
    lq = lm.lm_forward(qparams, cfg, pctx, toks).astype(jnp.float32)
    err = float(jnp.max(jnp.abs(lf - lq)))
    assert err < 0.5, err  # logits std is ~1.0 at init; 8-bit keeps ~0.1


# ---------------------------------------------------------------------------
# int8 paged KV.
# ---------------------------------------------------------------------------


def _paged_int8_inputs(seed=0, b=2, hq=4, hkv=2, d=32, pages=4, ps=32):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    pool = b * pages + 1
    q = jax.random.normal(kq, (b, hq, d), jnp.float32)
    k = jax.random.normal(kk, (pool, ps, hkv, d), jnp.float32)
    v = jax.random.normal(kv, (pool, ps, hkv, d), jnp.float32)
    bt = (1 + jnp.arange(pages)[None, :] * b
          + jnp.arange(b)[:, None]).astype(jnp.int32)
    lengths = jnp.array([pages * ps, 3 * ps - 5], jnp.int32)
    kqt = quantize_channelwise(k, axis=-1)
    vqt = quantize_channelwise(v, axis=-1)
    return (q, k, v, kqt.q, vqt.q, kqt.scale[..., 0], vqt.scale[..., 0],
            lengths, bt)


def test_paged_int8_kernel_matches_oracle():
    q, _, _, kq, vq, ks, vs, lengths, bt = _paged_int8_inputs()
    out = flash_decode_paged(q, kq, vq, lengths, bt, k_scales=ks, v_scales=vs)
    ref = paged_decode_attention_q_ref(q, kq, vq, ks, vs, lengths, bt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [16, 32])
def test_paged_int8_kernel_chunk_sweep(chunk):
    q, _, _, kq, vq, ks, vs, lengths, bt = _paged_int8_inputs(seed=1)
    out = flash_decode_paged(q, kq, vq, lengths, bt, k_scales=ks,
                             v_scales=vs, chunk=chunk)
    ref = paged_decode_attention_q_ref(q, kq, vq, ks, vs, lengths, bt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_int8_close_to_float_attention():
    q, k, v, kq, vq, ks, vs, lengths, bt = _paged_int8_inputs(seed=2)
    out = flash_decode_paged(q, kq, vq, lengths, bt, k_scales=ks, v_scales=vs)
    fp = paged_decode_attention_ref(q, k, v, lengths, bt)
    assert float(jnp.max(jnp.abs(out - fp))) < 0.02  # 8-bit KV error


def test_int8_kv_engine_warms_kvint8_tune_key():
    """An int8-KV engine must warm/tune the ``_kvint8`` variant of the
    paged family — the key the int8 gather-dequant kernel resolves — not
    the float variant it never runs."""
    from repro.parallel.sharding import ParallelContext
    from repro.serve import PagedServeEngine
    bundle = _smoke_bundle()
    params = bundle.init_params(jax.random.PRNGKey(0))
    eng = PagedServeEngine(bundle, params, ParallelContext(None), slots=2,
                           page_size=8, kv_dtype="int8")
    paged_keys = [k for k in eng.tuned_configs if k.startswith("flash_decode_paged|")]
    assert paged_keys and all(k.endswith("_kvint8") for k in paged_keys), paged_keys


def test_bench_family_int8_variant_matches_oracle():
    """The ``kv_int8`` shape flag of the flash_decode_paged family routes
    the sweep through the int8 kernel + int8 oracle."""
    spec = get_spec("flash_decode_paged")
    shape = {"b": 2, "hq": 4, "hkv": 2, "d": 32, "pages": 2, "ps": 16,
             "kv_int8": 1}
    assert spec.shape_key(shape).endswith("_kvint8")
    args = spec.make_inputs(shape, "float32", 0)
    assert len(args) == 7  # q, k_q, v_q, k_scales, v_scales, lengths, bt
    out = np.asarray(spec.run(args, spec.default_config(shape), True))
    ref = np.asarray(spec.ref(args))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_init_paged_cache_int8_layout():
    from repro.models import lm
    bundle = _smoke_bundle()
    cache = lm.init_paged_cache(bundle.cfg, pool_pages=5, page_size=8,
                                kv_dtype="int8")
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].dtype == jnp.float32
    assert cache["k_scale"].shape == cache["k"].shape[:-1]
    bf16 = lm.init_paged_cache(bundle.cfg, pool_pages=5, page_size=8)
    assert "k_scale" not in bf16 and bf16["k"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Engine-level greedy top-1 agreement (the acceptance criterion) moved into
# the consolidated cross-engine sweep: tests/test_engine_identity.py covers
# {int8 weights, int8 KV} x {every engine variant} x {sharing on/off}.
# ---------------------------------------------------------------------------
