"""PagedKVCache allocator: alloc/free/defrag/truncate bookkeeping,
null-page invariants, OutOfPages semantics.  Pure host logic — no model,
no jax (the device-side int8 scale-slot consistency of rollback is covered
in tests/test_spec.py)."""
import random

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis, or deterministic fallback
from repro.serve.paged_cache import NULL_PAGE, OutOfPages, PagedKVCache


def make(slots=2, num_pages=6, page_size=4, **kw):
    return PagedKVCache(slots=slots, num_pages=num_pages, page_size=page_size,
                        **kw)


class TestAllocate:
    def test_pages_grow_with_tokens(self):
        kv = make()
        assert kv.allocate(0, 3) != []          # 3 tokens -> 1 page
        assert kv.allocate(0, 4) == []          # still fits the same page
        assert len(kv.allocate(0, 5)) == 1      # crosses into page 2
        assert kv.owned_pages(0) == (1, 2)
        assert kv.used_pages == 2 and kv.free_pages == 4

    def test_null_page_never_allocated(self):
        kv = make(slots=3, num_pages=6)
        for slot in range(3):
            kv.allocate(slot, 2 * kv.page_size)
        owned = [p for s in range(3) for p in kv.owned_pages(s)]
        assert NULL_PAGE not in owned
        assert sorted(owned) == list(range(1, 7))
        # unallocated block-table entries stay at the null page
        kv2 = make()
        kv2.allocate(0, 1)
        assert kv2.block_tables[0, 1:].tolist() == [NULL_PAGE] * (
            kv2.max_pages_per_slot - 1)

    def test_pool_pages_includes_null(self):
        assert make(num_pages=6).pool_pages == 7

    def test_out_of_pages_has_no_side_effects(self):
        kv = make(slots=2, num_pages=3, page_size=4)
        kv.allocate(0, 8)                       # 2 pages
        before = (kv.owned_pages(1), kv.free_pages, kv.block_tables.copy())
        with pytest.raises(OutOfPages):
            kv.allocate(1, 8)                   # needs 2, only 1 free
        assert kv.owned_pages(1) == before[0]
        assert kv.free_pages == before[1]
        np.testing.assert_array_equal(kv.block_tables, before[2])

    def test_max_pages_per_slot_cap(self):
        kv = make(num_pages=6, max_pages_per_slot=2)
        assert kv.max_tokens_per_slot() == 8
        with pytest.raises(OutOfPages):
            kv.allocate(0, 9)
        assert kv.can_grow(0, 8) and not kv.can_grow(0, 9)


class TestFree:
    def test_free_slot_returns_everything(self):
        kv = make()
        kv.allocate(0, 10)
        kv.commit(0, 10)
        n = kv.free_slot(0)
        assert n == 3 and kv.free_pages == 6 and kv.length(0) == 0
        assert kv.owned_pages(0) == ()
        assert (kv.block_tables[0] == NULL_PAGE).all()

    def test_freed_pages_are_reusable(self):
        kv = make(slots=2, num_pages=2, page_size=4)
        kv.allocate(0, 8)
        with pytest.raises(OutOfPages):
            kv.allocate(1, 4)
        kv.free_slot(0)
        assert kv.allocate(1, 8)                # the whole pool again

    def test_commit_tracks_lengths_and_utilization(self):
        kv = make()
        kv.allocate(0, 5)
        kv.commit(0, 5)
        assert kv.length(0) == 5
        assert kv.utilization() == pytest.approx(2 / 6)
        v = kv.view()
        assert v.lengths[0] == 5 and v.block_tables[0, 0] == 1


class TestTruncate:
    """`truncate` is speculative decoding's rollback primitive
    (repro.spec): verify writes K+1 candidates, then the rejected suffix is
    discarded by truncating to the accepted length."""

    def test_truncate_across_page_boundary(self):
        kv = make()                             # page_size = 4
        kv.allocate(0, 12)                      # pages 1, 2, 3
        kv.commit(0, 12)
        before = kv.owned_pages(0)
        freed = kv.truncate(0, 5)               # keep 2 pages (tokens 0..4)
        assert kv.length(0) == 5
        assert kv.owned_pages(0) == before[:2]
        assert freed == [before[2]]
        assert kv.free_pages == 4
        assert tuple(kv.block_tables[0, :2]) == before[:2]
        assert (kv.block_tables[0, 2:] == NULL_PAGE).all()

    def test_truncate_within_page_keeps_it(self):
        kv = make()
        kv.allocate(0, 8)
        kv.commit(0, 8)
        assert kv.truncate(0, 6) == []          # 6 tokens still needs 2 pages
        assert kv.length(0) == 6 and len(kv.owned_pages(0)) == 2

    def test_truncate_commits_uncommitted_writes(self):
        # the speculative flow: allocate for K+1 candidate writes, verify,
        # then truncate straight to the accepted length (never committing
        # the worst case)
        kv = make()
        kv.allocate(0, 4)
        kv.commit(0, 4)
        kv.allocate(0, 4 + 5)                   # K+1 = 5 candidate tokens
        kv.truncate(0, 6)                       # 2 candidates survived
        assert kv.length(0) == 6 and len(kv.owned_pages(0)) == 2

    def test_truncate_to_zero_then_free_slot(self):
        kv = make()
        kv.allocate(0, 10)
        kv.commit(0, 10)
        kv.truncate(0, 0)
        assert kv.length(0) == 0 and kv.owned_pages(0) == ()
        assert kv.free_pages == kv.num_pages
        assert (kv.block_tables[0] == NULL_PAGE).all()
        assert kv.free_slot(0) == 0             # no double free
        assert kv.free_pages == kv.num_pages
        assert len(kv.allocate(1, 6 * kv.page_size)) == 6  # all reusable

    def test_truncate_beyond_capacity_raises_without_side_effects(self):
        kv = make()
        kv.allocate(0, 4)
        kv.commit(0, 4)
        before = (kv.owned_pages(0), kv.free_pages, kv.length(0))
        with pytest.raises(ValueError):
            kv.truncate(0, 5)                   # only 1 page allocated
        with pytest.raises(ValueError):
            kv.truncate(0, -1)
        assert (kv.owned_pages(0), kv.free_pages, kv.length(0)) == before

    def test_freed_pages_are_rerentable(self):
        kv = make(slots=2, num_pages=3, page_size=4)
        kv.allocate(0, 12)
        kv.commit(0, 12)
        freed = kv.truncate(0, 4)
        got = kv.allocate(1, 8)
        assert sorted(got) == sorted(freed)
        assert set(got).isdisjoint(kv.owned_pages(0))


class TestDefrag:
    def test_compacts_live_pages_to_low_ids(self):
        kv = make(slots=3, num_pages=9)
        for s in range(3):
            kv.allocate(s, 2 * kv.page_size)    # pages 1..6
        kv.free_slot(1)                         # holes at 3, 4
        moves = kv.defrag()
        assert moves                            # something moved
        live = sorted(p for s in range(3) for p in kv.owned_pages(s))
        assert live == [1, 2, 3, 4]             # dense prefix
        # block tables mirror the new ids
        for s in (0, 2):
            assert tuple(kv.block_tables[s, :2]) == kv.owned_pages(s)
        # every destination was free before its source released (sequential
        # application on the device pools is safe)
        assert all(dst < src for src, dst in moves)

    def test_noop_when_already_dense(self):
        kv = make()
        kv.allocate(0, 2 * kv.page_size)
        assert kv.defrag() == []

    def test_free_list_consistent_after_defrag(self):
        kv = make(slots=2, num_pages=4)
        kv.allocate(0, 4)
        kv.allocate(1, 4)
        kv.free_slot(0)
        kv.defrag()
        # all 3 free pages allocatable again, none colliding with live ones
        got = kv.allocate(0, 3 * kv.page_size)
        assert len(got) == 3
        assert set(got).isdisjoint(kv.owned_pages(1))


class TestPrefixSharing:
    """Refcounted prefix sharing: match/register/COW/park/evict/defrag.
    Host-side only; the engine-level consequences (device page copies,
    token-identical outputs) are covered in tests/test_engine_identity.py."""

    def test_register_then_match_shares_pages(self):
        kv = make(enable_sharing=True)           # ps=4, 6 pages
        prompt = list(range(30, 42))             # 3 full pages
        kv.allocate(0, 12)
        kv.commit(0, 12)
        kv.register_prefix(0, prompt)
        assert kv.registered_pages == 3
        m = kv.match_prefix(1, prompt + [7, 8])  # same head, longer tail
        assert m == 12
        assert kv.owned_pages(1) == kv.owned_pages(0)
        assert [kv.refcount(p) for p in kv.owned_pages(0)] == [2, 2, 2]
        assert kv.length(1) == 12
        assert kv.used_pages == 3                # shared pages count once
        assert kv.stats["shared_attached"] == 3

    def test_match_caps_below_full_prompt(self):
        # at least one prompt token must run through prefill (the engine
        # needs next-token logits), so an identical prompt never matches
        # its own last token
        kv = make(enable_sharing=True)
        prompt = list(range(50, 58))             # 2 full pages
        kv.allocate(0, 8)
        kv.commit(0, 8)
        kv.register_prefix(0, prompt)
        # the walk covers page 1 whole, then partial-matches page 2 up to
        # the cap: 7 of 8 tokens, never all 8
        assert kv.match_prefix(1, list(prompt)) == 7

    def test_sharing_off_matches_nothing(self):
        kv = make()                              # enable_sharing=False
        kv.allocate(0, 8)
        kv.commit(0, 8)
        kv.register_prefix(0, list(range(8)))    # no-op
        assert kv.registered_pages == 0
        assert kv.match_prefix(1, list(range(8)) + [9]) == 0
        assert kv.cached_pages == 0

    def test_cow_split_on_divergent_append(self):
        kv = make(enable_sharing=True)
        prompt = list(range(10, 22))             # 12 tokens, 3 pages
        kv.allocate(0, 12)
        kv.commit(0, 12)
        kv.register_prefix(0, prompt)
        # slot 1 shares 2 full pages + a partial match into page 3
        m = kv.match_prefix(1, prompt[:10] + [99, 98])
        assert m == 10
        p3 = kv.owned_pages(0)[2]
        assert kv.refcount(p3) == 2
        # first divergent write (token 10) splits the shared boundary page
        assert kv.allocate(1, 11) == []          # replace-in-place, no growth
        dst = kv.owned_pages(1)[2]
        assert dst != p3
        assert kv.refcount(p3) == 1 and kv.refcount(dst) == 1
        assert kv.owned_pages(0)[2] == p3        # publisher keeps its page
        assert kv.pop_page_copies() == [(p3, dst)]
        assert kv.stats["cow_splits"] == 1

    def test_retro_dedup_of_concurrent_identical_prefills(self):
        kv = make(num_pages=8, enable_sharing=True)
        prompt = [7] * 8
        for s in (0, 1):                         # concurrent admissions:
            kv.allocate(s, 8)                    # both prefill privately
            kv.commit(s, 8)
        kv.register_prefix(0, prompt)            # slot 0 publishes first
        kv.register_prefix(1, prompt)            # slot 1 retires its copies
        assert kv.owned_pages(1) == kv.owned_pages(0)
        assert [kv.refcount(p) for p in kv.owned_pages(0)] == [2, 2]
        assert kv.stats["dedup_reclaimed"] == 2
        assert kv.free_pages == 6                # private pages returned

    def test_parked_prefix_survives_free_and_rematches(self):
        kv = make(enable_sharing=True)
        prompt = list(range(20, 28))
        kv.allocate(0, 8)
        kv.commit(0, 8)
        kv.register_prefix(0, prompt)
        pages = kv.owned_pages(0)
        kv.free_slot(0)                          # wave 1 fully finished
        assert kv.cached_pages == 2 and kv.free_pages == 4
        assert kv.used_pages == 0                # parked pages aren't "used"
        m = kv.match_prefix(1, prompt + [1, 2])  # wave 2, same system prompt
        assert m == 8 and kv.owned_pages(1) == pages
        assert kv.cached_pages == 0              # un-parked by the attach

    def test_pressure_evicts_parked_subtree(self):
        kv = make(num_pages=3, enable_sharing=True)
        kv.allocate(0, 8)
        kv.commit(0, 8)
        kv.register_prefix(0, [5] * 8)
        kv.free_slot(0)                          # both pages parked
        assert kv.available_pages == 3 and kv.free_pages == 1
        assert len(kv.allocate(1, 12)) == 3      # needs the parked pages too
        assert kv.free_pages == 0 and kv.cached_pages == 0
        assert kv.registered_pages == 0          # no dangling trie entries
        assert kv.stats["evictions"] == 2

    def test_out_of_pages_accounts_for_cow_split(self):
        kv = make(num_pages=3, enable_sharing=True)
        prompt = list(range(60, 72))             # 12 tokens = whole pool
        kv.allocate(0, 12)
        kv.commit(0, 12)
        kv.register_prefix(0, prompt)
        m = kv.match_prefix(1, list(prompt))     # 2 full + partial page 3
        assert m == 11
        # growing slot 1 to 12 forces a COW split of the shared boundary
        # page, and the pool has nothing left to split into
        assert not kv.can_grow(1, 12)
        with pytest.raises(OutOfPages):
            kv.allocate(1, 12)
        assert kv.refcount(kv.owned_pages(0)[2]) == 2   # no side effects

    def test_defrag_remaps_trie_and_parked_pages(self):
        kv = make(enable_sharing=True)
        kv.allocate(0, 8)                        # filler at pages 1, 2
        prompt = list(range(40, 48))
        kv.allocate(1, 8)                        # pages 3, 4
        kv.commit(1, 8)
        kv.register_prefix(1, prompt)
        kv.free_slot(0)                          # holes at 1, 2
        kv.free_slot(1)                          # 3, 4 parked in the cache
        assert kv.defrag() == [(3, 1), (4, 2)]
        assert kv.cached_pages == 2 and kv.free_pages == 4
        # the compacted prefix cache is still matchable at its new ids
        m = kv.match_prefix(0, prompt + [1])
        assert m == 8 and kv.owned_pages(0) == (1, 2)


class TestTruncateOnSharedSlot:
    """Regression (repro.spec rollback x prefix sharing): truncate on a slot
    whose pages are shared must only *drop references* — the pre-sharing
    path freed dropped pages unconditionally, which would have recycled KV
    still backing another slot's prefix."""

    def _shared_pair(self):
        kv = make(num_pages=8, enable_sharing=True)     # ps=4
        prompt = list(range(10, 22))                    # 3 full pages
        kv.allocate(0, 12)
        kv.commit(0, 12)
        kv.register_prefix(0, prompt)
        assert kv.match_prefix(1, list(prompt)) == 11   # shares all 3 pages
        return kv, prompt

    def test_truncate_keeps_pages_other_slots_reference(self):
        kv, _ = self._shared_pair()
        p1, p2, p3 = kv.owned_pages(0)
        free_before = kv.free_pages
        # speculative rollback on slot 1 past the shared page 3
        assert kv.truncate(1, 5) == []           # nothing left live use
        assert kv.free_pages == free_before      # nothing recycled
        assert kv.refcount(p3) == 1              # slot 0's reference survives
        assert kv.refcount(p1) == 2 and kv.refcount(p2) == 2
        assert kv.owned_pages(0) == (p1, p2, p3)         # victim untouched
        assert kv.owned_pages(1) == (p1, p2)
        assert kv.length(0) == 12 and kv.length(1) == 5

    def test_write_after_rollback_cow_splits_kept_shared_page(self):
        kv, _ = self._shared_pair()
        p1, p2, _ = kv.owned_pages(0)
        kv.truncate(1, 5)                        # rollback into shared p2
        # the write that follows the rollback must not mutate p2 in place
        kv.allocate(1, 6)
        kv.commit(1, 6)
        dst = kv.owned_pages(1)[1]
        assert dst != p2 and kv.refcount(p2) == 1 and kv.refcount(dst) == 1
        assert kv.pop_page_copies() == [(p2, dst)]
        assert kv.owned_pages(0)[1] == p2        # slot 0 still reads p2

    def test_truncate_to_zero_then_reshare(self):
        kv, prompt = self._shared_pair()
        pages = kv.owned_pages(0)
        kv.truncate(1, 0)                        # full rollback, all shared
        assert [kv.refcount(p) for p in pages] == [1, 1, 1]
        assert kv.owned_pages(1) == () and kv.free_pages == 5
        kv.free_slot(1)
        # the cache is intact: a fresh admission shares the same pages
        assert kv.match_prefix(1, list(prompt)) == 11
        assert kv.owned_pages(1) == pages


# property-style (module level: the _hyp fallback wraps tests as zero-arg
# functions, so these cannot be class methods)
@settings(max_examples=20, deadline=None)
@given(page_size=st.integers(1, 8), seed=st.integers(0, 9999))
def test_truncate_append_interleaving(page_size, seed):
    """Random append/truncate/free interleavings hold the allocator
    invariants: exact page counts, no double ownership, null-page
    block-table tails, conserved pool size."""
    rng = random.Random(seed)
    kv = PagedKVCache(slots=2, num_pages=12, page_size=page_size)
    lengths = [0, 0]
    for _ in range(40):
        slot = rng.randrange(2)
        op = rng.random()
        if op < 0.5:                            # append
            n = lengths[slot] + rng.randint(1, 2 * page_size)
            if kv.can_grow(slot, n):
                kv.allocate(slot, n)
                kv.commit(slot, n)
                lengths[slot] = n
        elif op < 0.9:                          # rollback
            n = rng.randint(0, lengths[slot])
            kv.truncate(slot, n)
            lengths[slot] = n
        else:                                   # release
            kv.free_slot(slot)
            lengths[slot] = 0
        assert kv.used_pages + kv.free_pages == kv.num_pages
        owned_all = [p for s in range(2) for p in kv.owned_pages(s)]
        assert len(set(owned_all)) == len(owned_all)
        assert NULL_PAGE not in owned_all
        for s in range(2):
            assert kv.length(s) == lengths[s]
            n_pages = len(kv.owned_pages(s))
            assert n_pages == kv.pages_for(lengths[s])
            assert tuple(kv.block_tables[s, :n_pages]) == kv.owned_pages(s)
            assert (kv.block_tables[s, n_pages:] == NULL_PAGE).all()


# -- stateful model check for prefix sharing ---------------------------------
#
# Drives random interleavings of admit (match_prefix) / append (allocate +
# simulated device write + commit + register_prefix) / truncate / free_slot /
# defrag against a pure-python reference model:
#
#   * ``pool``  — a host copy of the device page pool (token per (page,
#     offset) cell), updated exactly the way the engine updates the real
#     pools: writes after allocate, COW copies from pop_page_copies before
#     any write, defrag moves applied in order;
#   * ``toks``  — per-slot committed token history.
#
# After every operation the model asserts the full invariant set: refcount
# == number of referencing slots, free/parked/owned partition the physical
# pages exactly (no leaks, no double ownership), the null page is never
# owned, block tables mirror ownership with null tails, and — the sharing
# safety property — every committed token of every slot is readable from
# the pool through its block table, so no COW/defrag/eviction path can ever
# corrupt a neighbour's KV.

def _check_sharing_model(kv, pool, toks):
    ps = kv.page_size
    owned_sets = [set(kv.owned_pages(s)) for s in range(kv.slots)]
    owned_all = set().union(*owned_sets)
    assert NULL_PAGE not in owned_all
    for p in range(1, kv.num_pages + 1):
        assert kv.refcount(p) == sum(p in s for s in owned_sets), p
    free, parked = set(kv._free), set(kv._evictable)
    assert free.isdisjoint(parked) and free.isdisjoint(owned_all)
    assert parked.isdisjoint(owned_all)
    assert free | parked | owned_all == set(range(1, kv.num_pages + 1))
    assert kv.used_pages == len(owned_all)
    assert kv.cached_pages == len(parked)
    for s in range(kv.slots):
        n = kv.length(s)
        pages = kv.owned_pages(s)
        assert len(set(pages)) == len(pages)     # no duplicate refs per slot
        assert len(pages) == kv.pages_for(n)
        assert tuple(kv.block_tables[s, :len(pages)]) == pages
        assert (kv.block_tables[s, len(pages):] == NULL_PAGE).all()
        for pos in range(n):
            page = int(kv.block_tables[s, pos // ps])
            assert pool[page, pos % ps] == toks[s][pos], (s, pos, page)


@settings(max_examples=500, deadline=None)
@given(page_size=st.integers(1, 4), seed=st.integers(0, 10 ** 6))
def test_prefix_sharing_stateful_model(page_size, seed):
    rng = random.Random(seed)
    slots, num_pages, ps = 3, 8, page_size
    kv = PagedKVCache(slots=slots, num_pages=num_pages, page_size=ps,
                      enable_sharing=True)
    pool = np.full((kv.pool_pages, ps), -1, dtype=np.int64)
    toks = [[] for _ in range(slots)]            # committed + pending prompt
    active = [False] * slots
    # two "system prompts": admissions share one of these heads, so matches,
    # COW splits, retro-dedup and parked-cache rehits all occur naturally
    bases = [[rng.randrange(5) for _ in range(4 * ps)] for _ in range(2)]

    def drain_copies():
        for src, dst in kv.pop_page_copies():
            pool[dst] = pool[src]

    for _ in range(50):
        slot = rng.randrange(slots)
        if not active[slot]:                     # admit
            base = bases[rng.randrange(2)]
            prompt = (base[:rng.randint(0, len(base))]
                      + [rng.randrange(5) for _ in range(rng.randint(1, 2 * ps))])
            matched = kv.match_prefix(slot, prompt)
            assert 0 <= matched <= len(prompt) - 1
            toks[slot] = list(prompt)
            active[slot] = True
        else:
            op = rng.random()
            if op < 0.55:                        # append (prefill or decode)
                committed = kv.length(slot)
                if len(toks[slot]) <= committed:  # prompt drained: decode
                    toks[slot].extend(rng.randrange(5)
                                      for _ in range(rng.randint(1, ps)))
                target = min(len(toks[slot]),
                             committed + rng.randint(1, 2 * ps))
                if target > committed and kv.can_grow(slot, target):
                    kv.allocate(slot, target)
                    drain_copies()               # engine: before any write
                    for pos in range(committed, target):
                        page = int(kv.block_tables[slot, pos // ps])
                        pool[page, pos % ps] = toks[slot][pos]
                    kv.commit(slot, target)
                    kv.register_prefix(slot, toks[slot])
            elif op < 0.75:                      # speculative rollback
                n = rng.randint(0, kv.length(slot))
                kv.truncate(slot, n)
                toks[slot] = toks[slot][:n]
            elif op < 0.9:                       # request finished
                kv.free_slot(slot)
                toks[slot] = []
                active[slot] = False
            else:                                # compaction
                for src, dst in kv.defrag():
                    pool[dst] = pool[src]
        _check_sharing_model(kv, pool, toks)

    # teardown: no leaks once every request is gone
    for s in range(slots):
        kv.free_slot(s)
        toks[s] = []
    _check_sharing_model(kv, pool, toks)
    assert kv.used_pages == 0
    assert kv.available_pages == kv.num_pages
    assert all(kv.refcount(p) == 0 for p in range(1, kv.num_pages + 1))
    # draining the whole pool evicts every parked page and empties the trie
    kv.allocate(0, kv.num_pages * ps)
    assert kv.free_pages == 0 and kv.cached_pages == 0
    assert kv.registered_pages == 0
    kv.free_slot(0)
    assert kv.free_pages == kv.num_pages
