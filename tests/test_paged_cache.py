"""PagedKVCache allocator: alloc/free/defrag/truncate bookkeeping,
null-page invariants, OutOfPages semantics.  Pure host logic — no model,
no jax (the device-side int8 scale-slot consistency of rollback is covered
in tests/test_spec.py)."""
import random

import numpy as np
import pytest

from _hyp import given, settings, st  # hypothesis, or deterministic fallback
from repro.serve.paged_cache import NULL_PAGE, OutOfPages, PagedKVCache


def make(slots=2, num_pages=6, page_size=4, **kw):
    return PagedKVCache(slots=slots, num_pages=num_pages, page_size=page_size,
                        **kw)


class TestAllocate:
    def test_pages_grow_with_tokens(self):
        kv = make()
        assert kv.allocate(0, 3) != []          # 3 tokens -> 1 page
        assert kv.allocate(0, 4) == []          # still fits the same page
        assert len(kv.allocate(0, 5)) == 1      # crosses into page 2
        assert kv.owned_pages(0) == (1, 2)
        assert kv.used_pages == 2 and kv.free_pages == 4

    def test_null_page_never_allocated(self):
        kv = make(slots=3, num_pages=6)
        for slot in range(3):
            kv.allocate(slot, 2 * kv.page_size)
        owned = [p for s in range(3) for p in kv.owned_pages(s)]
        assert NULL_PAGE not in owned
        assert sorted(owned) == list(range(1, 7))
        # unallocated block-table entries stay at the null page
        kv2 = make()
        kv2.allocate(0, 1)
        assert kv2.block_tables[0, 1:].tolist() == [NULL_PAGE] * (
            kv2.max_pages_per_slot - 1)

    def test_pool_pages_includes_null(self):
        assert make(num_pages=6).pool_pages == 7

    def test_out_of_pages_has_no_side_effects(self):
        kv = make(slots=2, num_pages=3, page_size=4)
        kv.allocate(0, 8)                       # 2 pages
        before = (kv.owned_pages(1), kv.free_pages, kv.block_tables.copy())
        with pytest.raises(OutOfPages):
            kv.allocate(1, 8)                   # needs 2, only 1 free
        assert kv.owned_pages(1) == before[0]
        assert kv.free_pages == before[1]
        np.testing.assert_array_equal(kv.block_tables, before[2])

    def test_max_pages_per_slot_cap(self):
        kv = make(num_pages=6, max_pages_per_slot=2)
        assert kv.max_tokens_per_slot() == 8
        with pytest.raises(OutOfPages):
            kv.allocate(0, 9)
        assert kv.can_grow(0, 8) and not kv.can_grow(0, 9)


class TestFree:
    def test_free_slot_returns_everything(self):
        kv = make()
        kv.allocate(0, 10)
        kv.commit(0, 10)
        n = kv.free_slot(0)
        assert n == 3 and kv.free_pages == 6 and kv.length(0) == 0
        assert kv.owned_pages(0) == ()
        assert (kv.block_tables[0] == NULL_PAGE).all()

    def test_freed_pages_are_reusable(self):
        kv = make(slots=2, num_pages=2, page_size=4)
        kv.allocate(0, 8)
        with pytest.raises(OutOfPages):
            kv.allocate(1, 4)
        kv.free_slot(0)
        assert kv.allocate(1, 8)                # the whole pool again

    def test_commit_tracks_lengths_and_utilization(self):
        kv = make()
        kv.allocate(0, 5)
        kv.commit(0, 5)
        assert kv.length(0) == 5
        assert kv.utilization() == pytest.approx(2 / 6)
        v = kv.view()
        assert v.lengths[0] == 5 and v.block_tables[0, 0] == 1


class TestTruncate:
    """`truncate` is speculative decoding's rollback primitive
    (repro.spec): verify writes K+1 candidates, then the rejected suffix is
    discarded by truncating to the accepted length."""

    def test_truncate_across_page_boundary(self):
        kv = make()                             # page_size = 4
        kv.allocate(0, 12)                      # pages 1, 2, 3
        kv.commit(0, 12)
        before = kv.owned_pages(0)
        freed = kv.truncate(0, 5)               # keep 2 pages (tokens 0..4)
        assert kv.length(0) == 5
        assert kv.owned_pages(0) == before[:2]
        assert freed == [before[2]]
        assert kv.free_pages == 4
        assert tuple(kv.block_tables[0, :2]) == before[:2]
        assert (kv.block_tables[0, 2:] == NULL_PAGE).all()

    def test_truncate_within_page_keeps_it(self):
        kv = make()
        kv.allocate(0, 8)
        kv.commit(0, 8)
        assert kv.truncate(0, 6) == []          # 6 tokens still needs 2 pages
        assert kv.length(0) == 6 and len(kv.owned_pages(0)) == 2

    def test_truncate_commits_uncommitted_writes(self):
        # the speculative flow: allocate for K+1 candidate writes, verify,
        # then truncate straight to the accepted length (never committing
        # the worst case)
        kv = make()
        kv.allocate(0, 4)
        kv.commit(0, 4)
        kv.allocate(0, 4 + 5)                   # K+1 = 5 candidate tokens
        kv.truncate(0, 6)                       # 2 candidates survived
        assert kv.length(0) == 6 and len(kv.owned_pages(0)) == 2

    def test_truncate_to_zero_then_free_slot(self):
        kv = make()
        kv.allocate(0, 10)
        kv.commit(0, 10)
        kv.truncate(0, 0)
        assert kv.length(0) == 0 and kv.owned_pages(0) == ()
        assert kv.free_pages == kv.num_pages
        assert (kv.block_tables[0] == NULL_PAGE).all()
        assert kv.free_slot(0) == 0             # no double free
        assert kv.free_pages == kv.num_pages
        assert len(kv.allocate(1, 6 * kv.page_size)) == 6  # all reusable

    def test_truncate_beyond_capacity_raises_without_side_effects(self):
        kv = make()
        kv.allocate(0, 4)
        kv.commit(0, 4)
        before = (kv.owned_pages(0), kv.free_pages, kv.length(0))
        with pytest.raises(ValueError):
            kv.truncate(0, 5)                   # only 1 page allocated
        with pytest.raises(ValueError):
            kv.truncate(0, -1)
        assert (kv.owned_pages(0), kv.free_pages, kv.length(0)) == before

    def test_freed_pages_are_rerentable(self):
        kv = make(slots=2, num_pages=3, page_size=4)
        kv.allocate(0, 12)
        kv.commit(0, 12)
        freed = kv.truncate(0, 4)
        got = kv.allocate(1, 8)
        assert sorted(got) == sorted(freed)
        assert set(got).isdisjoint(kv.owned_pages(0))


class TestDefrag:
    def test_compacts_live_pages_to_low_ids(self):
        kv = make(slots=3, num_pages=9)
        for s in range(3):
            kv.allocate(s, 2 * kv.page_size)    # pages 1..6
        kv.free_slot(1)                         # holes at 3, 4
        moves = kv.defrag()
        assert moves                            # something moved
        live = sorted(p for s in range(3) for p in kv.owned_pages(s))
        assert live == [1, 2, 3, 4]             # dense prefix
        # block tables mirror the new ids
        for s in (0, 2):
            assert tuple(kv.block_tables[s, :2]) == kv.owned_pages(s)
        # every destination was free before its source released (sequential
        # application on the device pools is safe)
        assert all(dst < src for src, dst in moves)

    def test_noop_when_already_dense(self):
        kv = make()
        kv.allocate(0, 2 * kv.page_size)
        assert kv.defrag() == []

    def test_free_list_consistent_after_defrag(self):
        kv = make(slots=2, num_pages=4)
        kv.allocate(0, 4)
        kv.allocate(1, 4)
        kv.free_slot(0)
        kv.defrag()
        # all 3 free pages allocatable again, none colliding with live ones
        got = kv.allocate(0, 3 * kv.page_size)
        assert len(got) == 3
        assert set(got).isdisjoint(kv.owned_pages(1))


# property-style (module level: the _hyp fallback wraps tests as zero-arg
# functions, so these cannot be class methods)
@settings(max_examples=20, deadline=None)
@given(page_size=st.integers(1, 8), seed=st.integers(0, 9999))
def test_truncate_append_interleaving(page_size, seed):
    """Random append/truncate/free interleavings hold the allocator
    invariants: exact page counts, no double ownership, null-page
    block-table tails, conserved pool size."""
    rng = random.Random(seed)
    kv = PagedKVCache(slots=2, num_pages=12, page_size=page_size)
    lengths = [0, 0]
    for _ in range(40):
        slot = rng.randrange(2)
        op = rng.random()
        if op < 0.5:                            # append
            n = lengths[slot] + rng.randint(1, 2 * page_size)
            if kv.can_grow(slot, n):
                kv.allocate(slot, n)
                kv.commit(slot, n)
                lengths[slot] = n
        elif op < 0.9:                          # rollback
            n = rng.randint(0, lengths[slot])
            kv.truncate(slot, n)
            lengths[slot] = n
        else:                                   # release
            kv.free_slot(slot)
            lengths[slot] = 0
        assert kv.used_pages + kv.free_pages == kv.num_pages
        owned_all = [p for s in range(2) for p in kv.owned_pages(s)]
        assert len(set(owned_all)) == len(owned_all)
        assert NULL_PAGE not in owned_all
        for s in range(2):
            assert kv.length(s) == lengths[s]
            n_pages = len(kv.owned_pages(s))
            assert n_pages == kv.pages_for(lengths[s])
            assert tuple(kv.block_tables[s, :n_pages]) == kv.owned_pages(s)
            assert (kv.block_tables[s, n_pages:] == NULL_PAGE).all()
