"""Data pipeline, serve engine, optimizers, compression (local parts),
roofline parser units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.data import DataCursor, Prefetcher, SyntheticLMSource
from repro.models import build_model
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update)
from repro.parallel.compression import dequantize_int8, quantize_int8
from repro.parallel.sharding import ParallelContext
from repro.roofline.analysis import (RooflineTerms, extrapolate,
                                     parse_collectives)
from repro.serve import Request, ServeEngine


# ---------------------------------------------------------------------- data
def test_prefetcher_orders_batches():
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLMSource(cfg, ShapeSpec("t", 16, 2, "train"))
    cur = DataCursor(step=3)
    pf = Prefetcher(src, cur, depth=2)
    b3 = next(pf)
    b4 = next(pf)
    pf.close()
    np.testing.assert_array_equal(b3["tokens"], src.batch_at(3)["tokens"])
    np.testing.assert_array_equal(b4["tokens"], src.batch_at(4)["tokens"])
    assert cur.step == 5


def test_vlm_batch_has_vision_and_masked_labels():
    cfg = get_config("internvl2-1b", smoke=True)
    src = SyntheticLMSource(cfg, ShapeSpec("t", 32, 2, "train"))
    b = src.batch_at(0)
    assert b["vision_embeds"].shape == (2, cfg.vision_tokens, cfg.d_model)
    assert b["labels"].shape == (2, 32)
    assert b["tokens"].shape == (2, 32 - cfg.vision_tokens)


# ------------------------------------------------------------------ optimizers
def _quad_params():
    return {"w": jnp.array([3.0, -2.0]), "b": {"x": jnp.full((4, 4), 1.5)}}


def test_adamw_converges_on_quadratic():
    params = _quad_params()
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: p.astype(jnp.float32), params)  # d/dp p^2/2
        params, state = adamw_update(grads, state, lr=0.05, weight_decay=0.0)
    assert max(float(jnp.abs(p.astype(jnp.float32)).max())
               for p in jax.tree.leaves(params)) < 0.2


def test_adafactor_converges_and_state_is_factored():
    params = {"w": jnp.ones((32, 16)) * 2.0}
    state = adafactor_init(params)
    assert state.vr["w"].shape == (32,)
    assert state.vc["w"].shape == (16,)
    for _ in range(300):
        grads = params
        params, state = adafactor_update(grads, state, params, lr=0.05)
    assert float(jnp.abs(params["w"]).max()) < 0.3


# ----------------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
def test_property_quantization_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (513,)) * scale
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s, x.shape) - x)
    # per-block bound: scale = max/127
    assert float(err.max()) <= float(jnp.abs(x).max()) / 127 + 1e-5


# -------------------------------------------------------------------- roofline
HLO_SAMPLE = """
  %all-reduce = f32[16,4096]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[1024,512]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(%z), replica_groups=[32,8]<=[256], to_apply=%add
  %cp = bf16[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %not-a-collective = f32[2] add(%a, %b)
"""


def test_parse_collectives_kinds_and_bytes():
    stats = parse_collectives(HLO_SAMPLE)
    assert stats.count == 4
    ar = 2 * (15 / 16) * 16 * 4096 * 4
    ag = (3 / 4) * 1024 * 512 * 2
    rs = 7 * 64 * 64 * 4
    cp = 128 * 2
    assert stats.by_kind["all-reduce"] == pytest.approx(ar)
    assert stats.by_kind["all-gather"] == pytest.approx(ag)
    assert stats.by_kind["reduce-scatter"] == pytest.approx(rs)
    assert stats.by_kind["collective-permute"] == pytest.approx(cp)


def test_extrapolate_linear():
    assert extrapolate(10.0, 14.0, depth=5) == pytest.approx(10 + 4 * 4)
    assert extrapolate(10.0, 9.0, depth=5) == pytest.approx(10.0)  # clamped


def test_roofline_dominant():
    t = RooflineTerms(flops=197e12, hbm_bytes=1, wire_bytes=1, chips=256)
    assert t.dominant == "compute" and t.t_compute == pytest.approx(1.0)
    t = RooflineTerms(flops=1, hbm_bytes=819e9, wire_bytes=1, chips=256)
    assert t.dominant == "memory"


# ---------------------------------------------------------------------- serve
def test_serve_engine_drains_all_requests():
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, ParallelContext(None), slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=[1 + i, 2], max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) >= 4 for r in reqs)


def test_serve_engine_isolation_between_slots():
    """Same prompt gives the same output regardless of co-batched traffic."""
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))

    def run(prompts):
        eng = ServeEngine(bundle, params, ParallelContext(None), slots=2, max_seq=64)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4) for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return [r.output for r in reqs]

    solo = run([[5, 6, 7]])[0]
    pair = run([[5, 6, 7], [9, 9, 1]])[0]
    assert solo == pair
