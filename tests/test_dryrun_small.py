"""Dry-run machinery tests at small scale (16 host devices, subprocess):
lower+compile a representative subset of (arch x shape) cells on a 4x4 mesh
through the exact run_cell protocol used at 512 chips, plus pure-function
tests of the depth-extrapolation configs."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import ARCHS
from repro.configs.base import depth_units, with_depth

HERE = os.path.dirname(__file__)


def test_with_depth_structure():
    cfg = ARCHS["llama4-maverick-400b-a17b"]
    d1 = with_depth(cfg, 1)
    assert d1.num_layers == 2 and not d1.scan_layers  # one moe_every block
    assert depth_units(cfg) == 24
    z = ARCHS["zamba2-1.2b"]
    assert with_depth(z, 2).num_layers == 12
    assert depth_units(z) == 6
    w = ARCHS["whisper-large-v3"]
    assert with_depth(w, 1).encoder_layers == 1
    assert depth_units(w) == 32


def test_depth_configs_keep_family_shapes():
    for cfg in ARCHS.values():
        d2 = with_depth(cfg, 2)
        assert d2.d_model == cfg.d_model
        assert d2.vocab_size == cfg.vocab_size
        assert d2.family == cfg.family


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=16 "
                               "--xla_disable_hlo_passes=all-reduce-promotion")
    import sys
    sys.path.insert(0, "src")
    import jax
    import repro.launch.dryrun as dr
    import repro.launch.mesh as mesh_mod
    # shrink the production mesh to 4x4 for the test
    mesh_mod.make_production_mesh = lambda multi_pod=False: (
        jax.make_mesh((2, 2, 4), ("pod", "data", "model")) if multi_pod
        else jax.make_mesh((4, 4), ("data", "model")))
    dr.make_production_mesh = mesh_mod.make_production_mesh
    for arch, shape in {cells}:
        res = dr.run_cell(arch, shape, multi_pod={multi}, fast=True)
        assert res["status"] == "ok", res
        assert res["flops_per_device"] > 0
        print("CELL", arch, shape, res["dominant"], flush=True)
    print("SUBPROC_OK")
""")


def run_cells(cells, multi=False, timeout=520):
    code = SUBPROC.format(cells=cells, multi=multi)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env,
                          cwd=os.path.join(HERE, ".."))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SUBPROC_OK" in proc.stdout


@pytest.mark.slow
def test_dryrun_protocol_dense_train_small_mesh():
    run_cells([("llama3-8b", "train_4k")])


@pytest.mark.slow
def test_dryrun_protocol_moe_decode_small_mesh():
    # MoE EP's shard_map is fully manual since the TP-serving PR, so this
    # no longer trips the jax<0.5 partial-manual abort (known_failures.md)
    run_cells([("arctic-480b", "decode_32k")])


@pytest.mark.slow
def test_dryrun_protocol_multipod_small_mesh():
    run_cells([("chatglm3-6b", "train_4k")], multi=True)
