"""Async streaming front end (repro.serve.server) + SLO-aware admission.

Two layers:

* ``TestSloAdmission`` — pure host-side scheduler policy: TTFT-class
  priority, the aged anti-starvation bound (a throughput request waits at
  most ``starvation_limit`` queue-jumps under saturating TTFT load), and
  the single-class degeneration to exact FIFO.  No model, no asyncio.
* ``TestAsyncFrontend`` — the asyncio server over the real (smoke) paged
  engine: per-token streaming, batch-loop token identity, deterministic
  SLO admission order, idle park/wake, early stop, per-request metrics,
  and the contiguous slot engine through the same duck-typed driver.
"""
import asyncio

import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.parallel import ParallelContext
from repro.serve import (SLO_THROUGHPUT, SLO_TTFT, AsyncServeFrontend,
                         FifoScheduler, PagedServeEngine, Request,
                         ServeEngine)

PCTX = ParallelContext(None)


def _req(rid, slo=SLO_THROUGHPUT):
    return Request(rid=rid, prompt=[1], max_new_tokens=4, slo=slo)


class TestSloAdmission:
    def test_ttft_jumps_the_queue(self):
        s = FifoScheduler(prefill_chunk=4)
        slow, fast = _req(0), _req(1, SLO_TTFT)
        s.submit(slow)
        s.submit(fast)
        (_, first), = s.admit([0])
        assert first is fast
        assert slow.skips == 1                  # the jump aged the waiter

    def test_throughput_wait_is_bounded(self):
        """Under saturating TTFT load a throughput request is force-admitted
        after exactly ``starvation_limit`` queue-jumps — no livelock."""
        limit = 3
        s = FifoScheduler(prefill_chunk=4, starvation_limit=limit)
        slow = _req(-1)
        s.submit(slow)
        admitted = []
        for i in range(2 * limit):              # always a TTFT rival waiting
            s.submit(_req(i, SLO_TTFT))
            (_, req), = s.admit([0])
            admitted.append(req)
        assert admitted.index(slow) == limit
        # the rivals it jumped still drain afterwards, in FIFO order
        assert [r.rid for r in admitted[limit + 1:]] == [limit, limit + 1]

    def test_single_class_is_exact_fifo(self):
        s = FifoScheduler(prefill_chunk=4)
        reqs = [_req(i) for i in range(5)]
        for r in reqs:
            s.submit(r)
        order = []
        while s.waiting:
            order.extend(r for _, r in s.admit([0]))
        assert order == reqs
        assert all(r.skips == 0 for r in reqs)  # no aging without jumps

    def test_ttft_class_is_fifo_within_itself(self):
        s = FifoScheduler(prefill_chunk=4)
        a, b = _req(0, SLO_TTFT), _req(1, SLO_TTFT)
        s.submit(a)
        s.submit(b)
        assert [r for _, r in s.admit([0, 1])] == [a, b]

    def test_starvation_limit_validated(self):
        with pytest.raises(ValueError, match="starvation_limit"):
            FifoScheduler(prefill_chunk=4, starvation_limit=0)


# --------------------------------------------------------------- async server
@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    return bundle, bundle.init_params(jax.random.PRNGKey(0))


def _paged(llama, **kw):
    bundle, params = llama
    kw.setdefault("slots", 2)
    return PagedServeEngine(bundle, params, PCTX, page_size=8, num_pages=16,
                            prefill_chunk=8, **kw)


def _prompt(i, n=5):
    return [1 + i] + [2] * (n - 1)


class TestAsyncFrontend:
    def test_streams_tokens_before_request_finishes(self, llama):
        async def go():
            async with AsyncServeFrontend(_paged(llama)) as front:
                stream = await front.submit(_prompt(0), max_new_tokens=6)
                first = await stream.__anext__()
                # per-token latency is one tick, not one request lifetime
                assert not stream.request.done
                rest = await stream.drain()
                return [first] + rest
        out = asyncio.run(go())
        assert len(out) == 6

    def test_outputs_identical_to_batch_drain_loop(self, llama):
        prompts = [_prompt(i) for i in range(4)]

        eng = _paged(llama)
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        batch_out = [r.output for r in reqs]

        async def go():
            async with AsyncServeFrontend(_paged(llama)) as front:
                streams = [await front.submit(p, max_new_tokens=6)
                           for p in prompts]
                return [await s.drain() for s in streams]
        assert asyncio.run(go()) == batch_out

    def test_ttft_request_admitted_first_and_none_starve(self, llama):
        # submit() never yields to the event loop, so all four requests are
        # queued before the driver's first tick: with one slot, admission
        # order is fully determined by the SLO policy
        async def go():
            eng = _paged(llama, slots=1)
            async with AsyncServeFrontend(eng) as front:
                slow = [await front.submit(_prompt(i), max_new_tokens=4)
                        for i in range(3)]
                fast = await front.submit(_prompt(9), max_new_tokens=4,
                                          slo=SLO_TTFT)
                await asyncio.gather(fast.drain(), *(s.drain() for s in slow))
                return fast, slow
        fast, slow = asyncio.run(go())
        assert fast.request.admit_seq == 0      # jumped all three
        assert [s.request.admit_seq for s in slow] == [1, 2, 3]
        assert all(s.request.done for s in [fast] + slow)
        assert fast.metrics()["queue_jumped"] == 0
        assert all(s.metrics()["queue_jumped"] == 1 for s in slow)

    def test_driver_parks_idle_and_wakes_on_submit(self, llama):
        async def go():
            async with AsyncServeFrontend(_paged(llama)) as front:
                first = await (await front.submit(_prompt(0),
                                                  max_new_tokens=4)).drain()
                # engine fully drained: the driver is parked on its event;
                # a fresh submission must wake it
                await asyncio.sleep(0)
                second = await front.generate(_prompt(1), max_new_tokens=4)
                return first, second
        first, second = asyncio.run(go())
        assert len(first) == 4 and len(second) == 4

    def test_stop_ends_streams_with_partial_output(self, llama):
        async def go():
            front = await AsyncServeFrontend(_paged(llama)).start()
            stream = await front.submit(_prompt(0), max_new_tokens=64)
            got = [await stream.__anext__()]    # wait for the first token
            await front.stop()                  # shut down mid-request
            got += await stream.drain()         # ends on the stop sentinel
            return stream, got
        stream, got = asyncio.run(go())
        assert 1 <= len(got) < 64
        assert got == stream.request.output[:len(got)]

    def test_request_metrics_populated(self, llama):
        async def go():
            async with AsyncServeFrontend(_paged(llama)) as front:
                stream = await front.submit(_prompt(0), max_new_tokens=5)
                await stream.drain()
                return stream.metrics()
        m = asyncio.run(go())
        assert m["tokens"] == 5 and m["prefill_tokens"] == 5
        assert m["slo"] == SLO_THROUGHPUT
        assert m["ttft_s"] > 0 and m["latency_s"] >= m["ttft_s"]
        assert m["preemptions"] == 0

    def test_submit_requires_started_frontend(self, llama):
        async def go():
            front = AsyncServeFrontend(_paged(llama))
            with pytest.raises(RuntimeError, match="not started"):
                await front.submit(_prompt(0))
        asyncio.run(go())

    def test_drives_contiguous_slot_engine(self, llama):
        bundle, params = llama
        async def go():
            eng = ServeEngine(bundle, params, PCTX, slots=2, max_seq=32)
            async with AsyncServeFrontend(eng) as front:
                return await asyncio.gather(
                    front.generate(_prompt(0), max_new_tokens=4),
                    front.generate(_prompt(1), max_new_tokens=4))
        outs = asyncio.run(go())
        assert [len(o) for o in outs] == [4, 4]
