"""Encoding-level tests against paper Fig. 3 / Fig. 4."""
import pytest

from repro.core import isa


def test_match_values_fig4():
    # MATCH words from Fig. 4 (funct5 in bits 31:27, OP-FP opcode 0x53).
    assert isa.MATCH_FMUL_S == 0x10000053
    assert isa.MATCH_FMAC_S == 0x60000053
    assert isa.MATCH_RFMAC_S == 0x68000053
    assert isa.MATCH_RFSMAC_S == 0x70000053


def test_match_is_subset_of_mask():
    # A MATCH may only set bits that its MASK filters.
    for mask, match in (
        (isa.MASK_FMUL_S, isa.MATCH_FMUL_S),
        (isa.MASK_FMAC_S, isa.MATCH_FMAC_S),
        (isa.MASK_RFMAC_S, isa.MATCH_RFMAC_S),
        (isa.MASK_RFSMAC_S, isa.MATCH_RFSMAC_S),
    ):
        assert match & ~mask == 0


def test_encode_decode_roundtrip():
    assert isa.decode(isa.encode_fmul_s(rd=15, rs1=14, rs2=13)) == "fmul.s"
    assert isa.decode(isa.encode_fmac_s(rd=15, rs1=14, rs2=13)) == "fmac.s"
    assert isa.decode(isa.encode_rfmac_s(rs1=14, rs2=13)) == "rfmac.s"
    assert isa.decode(isa.encode_rfsmac_s(rd=15)) == "rfsmac.s"


def test_no_encoding_overlap():
    """Unique funct5 values => the four instructions never alias (paper: 'no
    overlap with existing instructions')."""
    words = [
        isa.encode_fmul_s(1, 2, 3),
        isa.encode_fmac_s(1, 2, 3),
        isa.encode_rfmac_s(2, 3),
        isa.encode_rfsmac_s(1),
    ]
    names = {isa.decode(w) for w in words}
    assert len(names) == 4


def test_rfmac_has_no_rd_field():
    w = isa.encode_rfmac_s(rs1=7, rs2=9)
    assert (w >> 7) & 0x1F == 0  # rd bits zero
    assert isa.matches(w, isa.MASK_RFMAC_S, isa.MATCH_RFMAC_S)


def test_rfsmac_has_no_source_fields():
    w = isa.encode_rfsmac_s(rd=11)
    assert (w >> 15) & 0x1F == 0 and (w >> 20) & 0x1F == 0
    assert isa.matches(w, isa.MASK_RFSMAC_S, isa.MATCH_RFSMAC_S)


def test_instr_availability_per_isa():
    assert isa.instr_allowed(isa.Kind.FMAC, isa.Isa.BASELINE)
    assert not isa.instr_allowed(isa.Kind.FMAC, isa.Isa.RV64F)
    assert not isa.instr_allowed(isa.Kind.FMAC, isa.Isa.RV64R)
    assert isa.instr_allowed(isa.Kind.RFMAC, isa.Isa.RV64R)
    assert not isa.instr_allowed(isa.Kind.RFMAC, isa.Isa.BASELINE)
    assert isa.instr_allowed(isa.Kind.FMUL, isa.Isa.RV64F)
