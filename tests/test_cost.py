"""repro.cost: hardware profiles, kernel/graph pricing, autotune pruning
parity, and the whole-graph schedule cache.

The contracts under test are the ones the rest of the repo leans on:

* pricing is strictly monotone in HBM traffic at fixed FLOPs (so pruning
  can rank by traffic, the paper's metric);
* a pruned sweep picks the exhaustive winner on every quick-suite tune
  space (or a config the model prices identically / timing can't
  distinguish within the recorded spread);
* the graph signature is a pure function of graph *structure* — stable
  across re-traces, sensitive to shape changes — so cached schedules can
  never be replayed onto a different geometry;
* cost-driven pass selection reproduces the fixed default pipeline's
  graph (drop decisions coincide with no-op rewrites), which is what
  keeps the serving matrix token-identical with the cost model on.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench import ConfigCache, all_specs, autotune, set_default_cache
from repro.bench.autotune import resolve_timing, time_stats
from repro.bench.config import BlockConfig, scoped_cache
from repro.bench.registry import get_spec
from repro.cost import (OVERLAP_LEAK, HardwareProfile, all_profiles,
                        candidate_passes, estimate_graph, estimate_kernel,
                        get_profile, graph_signature, lookup_schedule,
                        plan_graph, rank_candidates, select_passes,
                        store_schedule)
from repro.graph.passes import run_passes
from repro.graph.trace import trace

QUICK_SHAPES = {
    "apr_matmul": {"m": 64, "k": 128, "n": 64},
    "apr_matmul_fused": {"m": 64, "k": 128, "n": 64},
    "quant_matmul": {"m": 64, "k": 128, "n": 64},
    "quant_matmul_fused": {"m": 64, "k": 128, "n": 64},
    "apr_conv": {"b": 1, "h": 8, "w": 8, "c": 4, "hf": 3, "wf": 3,
                 "m": 8, "stride": 1, "padding": 1},
    "apr_conv_fused": {"b": 1, "h": 8, "w": 8, "c": 4, "hf": 3, "wf": 3,
                       "m": 8, "stride": 1, "padding": 1},
    "flash_decode": {"b": 2, "hq": 4, "hkv": 2, "d": 32, "s": 128},
    "flash_decode_paged": {"b": 2, "hq": 4, "hkv": 2, "d": 32,
                           "pages": 4, "ps": 32},
    "mamba2": {"b": 1, "t": 32, "h": 2, "p": 8, "n": 8},
    "rwkv6": {"b": 1, "t": 32, "h": 2, "d": 8},
}


@pytest.fixture
def cache(tmp_path):
    c = ConfigCache(tmp_path / "tune_cache.json")
    set_default_cache(c)
    yield c
    set_default_cache(None)


class TestProfiles:
    def test_default_and_registry(self):
        assert get_profile().name == "tpu_v5e"
        assert {"tpu_v5e", "cpu_interpret"} <= set(all_profiles())

    def test_env_selects(self, monkeypatch):
        monkeypatch.setenv("REPRO_HW_PROFILE", "cpu_interpret")
        assert get_profile().name == "cpu_interpret"
        # explicit name outranks the env
        assert get_profile("tpu_v5e").name == "tpu_v5e"

    def test_unknown_profile_raises(self):
        with pytest.raises(KeyError):
            get_profile("not_a_chip")

    def test_ridge_intensity(self):
        p = get_profile("tpu_v5e")
        assert p.ridge_intensity == pytest.approx(p.peak_flops / p.hbm_bw)


class TestKernelModel:
    def test_monotone_in_traffic(self):
        """More HBM traffic at fixed FLOPs must always price higher — the
        ordering pruning relies on (roofline max() alone would tie on the
        compute-bound side; the OVERLAP_LEAK term breaks it)."""
        spec = get_spec("apr_matmul")
        shape = QUICK_SHAPES["apr_matmul"]
        ests = [estimate_kernel(spec, shape, cfg)
                for cfg in spec.candidates(shape)]
        by_traffic = sorted(ests, key=lambda e: e.hbm_bytes)
        for a, b in zip(by_traffic, by_traffic[1:]):
            if b.hbm_bytes > a.hbm_bytes:
                assert b.predicted_s > a.predicted_s
            else:
                assert b.predicted_s == pytest.approx(a.predicted_s)

    def test_profile_scales_prediction(self):
        spec = get_spec("apr_matmul")
        shape = QUICK_SHAPES["apr_matmul"]
        cfg = spec.candidates(shape)[0]
        fast = estimate_kernel(spec, shape, cfg, profile=get_profile("tpu_v5e"))
        slow = estimate_kernel(spec, shape, cfg,
                               profile=get_profile("cpu_interpret"))
        assert slow.predicted_s > fast.predicted_s
        assert slow.profile == "cpu_interpret"

    def test_vmem_overflow_penalised(self):
        """A config whose tile working set exceeds the profile's VMEM must
        price worse than the same traffic without the spill."""
        spec = get_spec("apr_matmul")
        shape = {"m": 512, "k": 512, "n": 512}
        cfg = BlockConfig.make(block_m=256, block_n=256, block_k=512)
        tiny = dataclasses.replace(get_profile("tpu_v5e"), name="tiny_vmem",
                                   vmem_bytes=64 * 1024)
        ok = estimate_kernel(spec, shape, cfg)
        spilled = estimate_kernel(spec, shape, cfg, profile=tiny)
        assert ok.vmem_ok and not spilled.vmem_ok
        assert spilled.predicted_s > ok.predicted_s

    def test_rank_is_stable_and_complete(self):
        spec = get_spec("apr_matmul")
        shape = QUICK_SHAPES["apr_matmul"]
        cands = spec.candidates(shape)
        ranked = rank_candidates(spec, shape, cands)
        assert sorted(c.to_dict().items() for c, _ in ranked) \
            == sorted(c.to_dict().items() for c in cands)
        costs = [est.predicted_s for _, est in ranked]
        assert costs == sorted(costs)


class TestPruningParity:
    @pytest.mark.parametrize("kernel", sorted(QUICK_SHAPES))
    def test_pruned_matches_exhaustive(self, kernel, cache):
        """On every quick tune space the pruned sweep must select the
        exhaustive winner — identically, or a config the model prices
        within 1% (a genuine tie: either is a legitimate winner), or one
        whose measured time is within the runs' recorded timer spread."""
        spec = get_spec(kernel)
        shape = QUICK_SHAPES[kernel]
        cands = spec.candidates(shape)[:4]
        k = max(1, len(cands) // 2)
        ex = autotune(spec, shape, cache=cache, max_candidates=4)
        pr = autotune(spec, shape, cache=cache, max_candidates=4,
                      prune_top_k=k)
        assert ex.ok and pr.ok
        assert pr.pruned_from == len(cands)
        assert pr.n_timed <= k < pr.pruned_from
        assert pr.predicted_us is not None
        if pr.config == ex.config:
            return
        pred = {cfg: est.predicted_us
                for cfg, est in rank_candidates(spec, shape, cands)}
        tied = abs(pred[pr.config] - pred[ex.config]) \
            <= 0.01 * max(pred[pr.config], pred[ex.config])
        within_noise = abs(pr.us - ex.us) <= pr.spread_us + ex.spread_us
        assert tied or within_noise, (
            f"pruned {pr.config.to_dict()} vs exhaustive "
            f"{ex.config.to_dict()}: neither a predicted tie nor within "
            f"timer spread")

    def test_exhaustive_records_no_pruning(self, cache):
        spec = get_spec("apr_matmul")
        res = autotune(spec, QUICK_SHAPES["apr_matmul"], cache=cache)
        assert res.pruned_from is None
        assert res.n_timed == res.n_candidates - len(res.rejected)


class TestTiming:
    def test_env_overrides(self, monkeypatch):
        assert resolve_timing() == (3, 1)
        monkeypatch.setenv("REPRO_BENCH_ITERS", "7")
        monkeypatch.setenv("REPRO_BENCH_WARMUP", "0")
        assert resolve_timing() == (7, 0)
        # explicit args outrank the env
        assert resolve_timing(2, 5) == (2, 5)
        monkeypatch.setenv("REPRO_BENCH_ITERS", "junk")
        assert resolve_timing()[0] == 3

    def test_time_stats_spread(self, monkeypatch):
        monkeypatch.setenv("REPRO_BENCH_ITERS", "5")
        calls = []
        med, spread = time_stats(lambda: calls.append(1))
        assert len(calls) == 5 + 1     # default warmup 1 still applies
        assert med >= 0.0 and spread >= 0.0


def _mlp(x):
    w1 = jnp.ones((16, 32), jnp.float32)
    w2 = jnp.ones((32, 16), jnp.float32)
    b = jnp.arange(32, dtype=jnp.float32)
    h = jax.nn.relu(x @ w1 + b)
    return h @ w2


class TestSignature:
    def test_stable_across_retrace(self):
        x = jnp.ones((4, 16), jnp.float32)
        g1 = trace(_mlp, x, name="mlp")
        g2 = trace(_mlp, x, name="mlp")
        assert graph_signature(g1) == graph_signature(g2)

    def test_shape_sensitive(self):
        g1 = trace(_mlp, jnp.ones((4, 16), jnp.float32), name="mlp")
        g2 = trace(_mlp, jnp.ones((8, 16), jnp.float32), name="mlp")
        assert graph_signature(g1) != graph_signature(g2)

    def test_fusion_changes_signature(self):
        g = trace(_mlp, jnp.ones((4, 16), jnp.float32), name="mlp")
        sig = graph_signature(g)
        run_passes(g)
        assert graph_signature(g) != sig


class TestGraphModel:
    def test_fusion_reduces_predicted_traffic(self):
        x = jnp.ones((4, 16), jnp.float32)
        g = trace(_mlp, x, name="mlp")
        before = estimate_graph(g)
        run_passes(g)
        after = estimate_graph(g)
        assert after.intermediate_traffic < before.intermediate_traffic
        assert after.predicted_s < before.predicted_s
        assert before.flops == after.flops  # fusion moves bytes, not math

    def test_select_matches_default_pipeline(self):
        """Cost-driven selection must rebuild exactly the fixed pipeline's
        graph: a dropped pass is one that would not have changed the graph
        anyway (fusion only fires on a strict traffic win)."""
        x = jnp.ones((4, 16), jnp.float32)
        g_cost = trace(_mlp, x, name="mlp")
        g_fix = trace(_mlp, x, name="mlp")
        decision = select_passes(g_cost)
        run_passes(g_fix)
        assert graph_signature(g_cost) == graph_signature(g_fix)
        assert set(decision.passes) <= set(candidate_passes())
        kept = {d.name for d in decision.decisions if d.kept}
        assert kept == set(decision.passes)
        assert decision.traffic_reduction >= 1.0
        assert "keep" in decision.report()


class TestScheduleCache:
    def test_round_trip(self, cache):
        x = jnp.ones((4, 16), jnp.float32)
        g = trace(_mlp, x, name="mlp")
        sig = graph_signature(g)
        assert lookup_schedule(sig, cache) is None
        decision = select_passes(g, signature=sig)
        store_schedule(decision, cache)
        assert lookup_schedule(sig, cache) == decision.passes

    def test_stale_vocab_is_a_miss(self, cache):
        from repro.cost.schedule import _BACKEND, _DTYPE, SCHEDULE_KERNEL
        cfg = BlockConfig.make(renamed_pass=1)  # not the current registry
        cache.store(SCHEDULE_KERNEL, "sig", _DTYPE, _BACKEND, cfg)
        assert lookup_schedule("sig", cache) is None

    def test_plan_graph_hits_cache(self, cache):
        x = jnp.ones((4, 16), jnp.float32)
        with scoped_cache(cache):
            first = plan_graph(trace(_mlp, x, name="mlp"))
            second = plan_graph(trace(_mlp, x, name="mlp"))
        assert not first.cached and second.cached
        assert second.passes == first.passes
        assert second.fused.intermediate_traffic \
            == first.fused.intermediate_traffic

    def test_cost_model_off_skips_schedule(self, cache, monkeypatch):
        from repro.graph import compile_fn
        x = jnp.ones((4, 16), jnp.float32)
        with scoped_cache(cache):
            ex = compile_fn(_mlp, x)
            assert ex.schedule is not None
            monkeypatch.setenv("REPRO_COST_MODEL", "off")
            ex_off = compile_fn(_mlp, x)
        assert ex_off.schedule is None
        np.testing.assert_allclose(np.asarray(ex(x)), np.asarray(ex_off(x)),
                                   rtol=1e-6)
