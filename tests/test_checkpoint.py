"""Checkpoint manager: atomicity, retention, async, cursor round-trip."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import adamw_init


def make_state():
    params = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((2,))}}
    return (params, adamw_init(params))


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save(7, state, metadata={"cursor": {"step": 7, "seed": 0}})
    assert mgr.latest_step() == 7
    restored, meta = mgr.restore(target=state)
    assert meta["cursor"]["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
                 state, restored)
    # NamedTuple structure preserved
    assert type(restored[1]).__name__ == "AdamWState"


def test_retention_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = make_state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save_async(11, state, metadata={"cursor": {"step": 11, "seed": 0}})
    mgr.wait()
    restored, meta = mgr.restore(target=state)
    assert meta["cursor"]["step"] == 11


def test_crash_leaves_previous_checkpoint_intact(tmp_path):
    """Stage dirs (.tmp-*) are invisible to latest_step / restore."""
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save(1, state)
    os.makedirs(os.path.join(str(tmp_path), ".tmp-dead-123"))  # simulated crash
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(target=state)
    assert restored is not None


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore()
