"""Per-shard page-pool lockstep: the TP engine's sharded-KV contract.

Under tensor parallelism (`repro.parallel.tp`, docs/parallel.md) the paged
KV *pools* are sharded over the KV-head axis while everything that decides
page identity stays host-side and single-source: ONE ``PagedKVCache``
holds the block tables, refcounts, prefix trie, COW queue and defrag plan,
and every page-copy op it emits (COW split before a write, defrag move) is
applied to all shard pools in the same order — the engine jits one
``_copy_page`` whose ``out_shardings`` pin the pools in place, so a copy
is N independent local copies, never a gather.

The safety property that makes this sound: *no copy stream can make the
shards diverge*, because shards are only ever written (a) at freshly
committed (page, offset) cells addressed through the shared block table,
or (b) by whole-page copies replicated to every shard.  This test drives
random admit / append / truncate / free / defrag / COW interleavings
through one allocator steering ``N`` model pools that hold shard-distinct
content (``token * N + shard``), and asserts after every op that each
shard reads back exactly its own encoding of every committed token of
every slot through the shared block table — same pages, same copies, no
cross-shard bleed — plus the refcount/partition invariants and a
leak-free teardown.

Runs under ``tests/_hyp.py`` (hypothesis ``ci``/``ci-random`` profiles,
or the deterministic fallback shim when hypothesis is absent).
"""
import random

import numpy as np

from _hyp import given, settings, st  # hypothesis, or deterministic fallback
from repro.serve.paged_cache import NULL_PAGE, PagedKVCache

N_SHARDS = 4


def _check_shard_lockstep(kv, pools, toks):
    """Full invariant set + the lockstep property over every shard pool."""
    ps = kv.page_size
    owned_sets = [set(kv.owned_pages(s)) for s in range(kv.slots)]
    owned_all = set().union(*owned_sets)
    assert NULL_PAGE not in owned_all
    for p in range(1, kv.num_pages + 1):
        assert kv.refcount(p) == sum(p in s for s in owned_sets), p
    free, parked = set(kv._free), set(kv._evictable)
    assert free.isdisjoint(parked) and free.isdisjoint(owned_all)
    assert parked.isdisjoint(owned_all)
    assert free | parked | owned_all == set(range(1, kv.num_pages + 1))
    assert kv.used_pages == len(owned_all)
    for s in range(kv.slots):
        n = kv.length(s)
        pages = kv.owned_pages(s)
        assert len(set(pages)) == len(pages)
        assert len(pages) == kv.pages_for(n)
        assert tuple(kv.block_tables[s, :len(pages)]) == pages
        assert (kv.block_tables[s, len(pages):] == NULL_PAGE).all()
        for pos in range(n):
            page = int(kv.block_tables[s, pos // ps])
            want = toks[s][pos]
            for shard, pool in enumerate(pools):
                got = pool[page, pos % ps]
                assert got == want * N_SHARDS + shard, (
                    f"shard {shard} diverged at slot {s} pos {pos} "
                    f"page {page}: read {got}, want {want * N_SHARDS + shard}")


@settings(max_examples=300, deadline=None)
@given(page_size=st.integers(1, 4), seed=st.integers(0, 10 ** 6))
def test_shard_pools_stay_in_lockstep(page_size, seed):
    rng = random.Random(seed)
    slots, num_pages, ps = 3, 8, page_size
    kv = PagedKVCache(slots=slots, num_pages=num_pages, page_size=ps,
                      enable_sharing=True)
    pools = [np.full((kv.pool_pages, ps), -1, dtype=np.int64)
             for _ in range(N_SHARDS)]
    toks = [[] for _ in range(slots)]
    active = [False] * slots
    # shared "system prompt" heads so prefix hits, COW splits and retro-dedup
    # all occur; every hit makes multiple slots read the SAME physical page
    # on every shard, which is exactly where a lockstep bug would surface
    bases = [[rng.randrange(5) for _ in range(4 * ps)] for _ in range(2)]

    def drain_copies():
        # the engine's jitted _copy_page: one (src, dst) op, N local copies
        for src, dst in kv.pop_page_copies():
            for pool in pools:
                pool[dst] = pool[src]

    def write(slot, committed, target):
        # shard-distinct encoding: a write lands on every shard's pool at
        # the same (page, offset) but with per-shard content, like the
        # head-sharded K/V slices of one token
        for pos in range(committed, target):
            page = int(kv.block_tables[slot, pos // ps])
            for shard, pool in enumerate(pools):
                pool[page, pos % ps] = toks[slot][pos] * N_SHARDS + shard

    for _ in range(50):
        slot = rng.randrange(slots)
        if not active[slot]:                     # admit
            base = bases[rng.randrange(2)]
            prompt = (base[:rng.randint(0, len(base))]
                      + [rng.randrange(5) for _ in range(rng.randint(1, 2 * ps))])
            kv.match_prefix(slot, prompt)
            toks[slot] = list(prompt)
            active[slot] = True
        else:
            op = rng.random()
            if op < 0.55:                        # append (prefill or decode)
                committed = kv.length(slot)
                if len(toks[slot]) <= committed:
                    toks[slot].extend(rng.randrange(5)
                                      for _ in range(rng.randint(1, ps)))
                target = min(len(toks[slot]),
                             committed + rng.randint(1, 2 * ps))
                if target > committed and kv.can_grow(slot, target):
                    kv.allocate(slot, target)
                    drain_copies()               # engine: before any write
                    write(slot, committed, target)
                    kv.commit(slot, target)
                    kv.register_prefix(slot, toks[slot])
            elif op < 0.75:                      # speculative rollback
                n = rng.randint(0, kv.length(slot))
                kv.truncate(slot, n)
                toks[slot] = toks[slot][:n]
            elif op < 0.9:                       # request finished
                kv.free_slot(slot)
                toks[slot] = []
                active[slot] = False
            else:                                # compaction
                for src, dst in kv.defrag():
                    for pool in pools:
                        pool[dst] = pool[src]
        _check_shard_lockstep(kv, pools, toks)

    # teardown: every slot released, nothing leaked on any shard
    for s in range(slots):
        kv.free_slot(s)
        toks[s] = []
    _check_shard_lockstep(kv, pools, toks)
    assert kv.used_pages == 0
    assert kv.available_pages == kv.num_pages
    assert all(kv.refcount(p) == 0 for p in range(1, kv.num_pages + 1))
