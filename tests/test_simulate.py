"""Table-III reproduction tests: structure of the codegen (Fig. 1) and
enhancement percentages against the paper's published numbers."""
import pytest

from repro.core import calibration
from repro.core.isa import Isa, Kind
from repro.core.program import CodegenParams, ConvLayer, mac_body, rfsmac_block
from repro.core.simulate import enhancement, simulate_model
from repro.core.workloads import MODELS, total_macs

CG = calibration.CODEGEN


def kind_count(stream, kind):
    return sum(1 for i in stream if i.kind == kind)


class TestFig1InstructionMix:
    def test_rv64f_inner_has_three_flw_one_fsw_two_fp_ops(self):
        body = mac_body(Isa.RV64F, CG)
        assert kind_count(body, Kind.FLW) == 4  # 3 array loads + 1 spill reload
        assert kind_count(body, Kind.FSW) == 2  # spill + partial-sum store
        assert kind_count(body, Kind.FMUL) == 1
        assert kind_count(body, Kind.FADD) == 1

    def test_baseline_inner_single_fmac(self):
        body = mac_body(Isa.BASELINE, CG)
        assert kind_count(body, Kind.FLW) == 3
        assert kind_count(body, Kind.FSW) == 1
        assert kind_count(body, Kind.FMAC) == 1
        assert kind_count(body, Kind.FMUL) == 0

    def test_rv64r_inner_two_loads_no_store(self):
        """Paper: 'R-extension reduces half of the memory-related
        instructions' — no Output reference in the inner loop at all."""
        body = mac_body(Isa.RV64R, CG)
        assert kind_count(body, Kind.FLW) == 2
        assert kind_count(body, Kind.FSW) == 0
        assert kind_count(body, Kind.RFMAC) == 1
        assert kind_count(body, Kind.DIV) == 0  # no j/S,k/S in the hot loop

    def test_div_count_per_isa(self):
        assert kind_count(mac_body(Isa.RV64F, CG), Kind.DIV) == 4   # 2 refs x 2
        assert kind_count(mac_body(Isa.BASELINE, CG), Kind.DIV) == 2
        assert kind_count(mac_body(Isa.RV64R, CG), Kind.DIV) == 0

    def test_rfsmac_epilogue(self):
        blk = rfsmac_block(CG)
        assert kind_count(blk, Kind.RFSMAC) == 1
        assert kind_count(blk, Kind.FSW) == 1


class TestWorkloads:
    def test_lenet_macs(self):
        assert total_macs(MODELS["lenet"]()) == 416_520

    def test_resnet20_macs(self):
        m = total_macs(MODELS["resnet20"]())
        assert 40_000_000 < m < 41_500_000

    def test_mobilenet_macs(self):
        m = total_macs(MODELS["mobilenet_v1"]())
        assert 44_000_000 < m < 48_000_000


PAPER = {
    # model -> isa -> (runtime_s, IC, IPC, mem, L1)
    "lenet": {
        Isa.RV64F: (0.066, 44_310_154, 0.666, 19_288_578, 23_071_838),
        Isa.BASELINE: (0.048, 35_792_547, 0.740, 16_043_778, 19_841_884),
        Isa.RV64R: (0.032, 27_010_675, 0.847, 12_045_594, 15_449_482),
    },
    "resnet20": {
        Isa.RV64F: (6.210, 4_103_496_569, 0.661, 1_795_154_166, 2_103_847_934),
        Isa.BASELINE: (4.413, 3_246_429_938, 0.736, 1_468_652_534, 1_736_203_748),
        Isa.RV64R: (2.691, 2_352_965_745, 0.874, 1_062_330_923, 1_289_180_424),
    },
    "mobilenet_v1": {
        Isa.RV64F: (7.035, 4_923_965_486, 0.700, 2_130_037_330, 2_599_414_994),
        Isa.BASELINE: (5.255, 4_122_177_959, 0.784, 1_824_588_370, 2_222_467_107),
        Isa.RV64R: (3.720, 3_307_689_859, 0.889, 1_453_124_800, 1_813_851_904),
    },
}


class TestTableIII:
    @pytest.mark.parametrize("model", list(PAPER))
    @pytest.mark.parametrize("isa", [Isa.RV64F, Isa.BASELINE, Isa.RV64R])
    def test_absolute_metrics_within_band(self, model, isa):
        """LeNet (the calibration target) within ~5%; the predicted
        ResNet-20 / MobileNet rows within 25% absolute (their *relative*
        enhancements are within 7 points — see below).  The residual comes
        from model-specific -O0 code shapes (1x1/depthwise loop nests) that
        the single calibrated template cannot see."""
        m = simulate_model(model, isa)
        rt, ic, ipc, mem, l1 = PAPER[model][isa]
        band = 0.06 if model == "lenet" else 0.25
        assert abs(m.instructions - ic) / ic < band
        assert abs(m.ipc - ipc) / ipc < 0.12
        assert abs(m.mem_instrs - mem) / mem < band + 0.07
        assert abs(m.l1_accesses - l1) / l1 < band + 0.07
        assert abs(m.runtime_s - rt) / rt < band + 0.10

    @pytest.mark.parametrize("model", list(PAPER))
    def test_orderings(self, model):
        f = simulate_model(model, Isa.RV64F)
        b = simulate_model(model, Isa.BASELINE)
        r = simulate_model(model, Isa.RV64R)
        assert f.instructions > b.instructions > r.instructions
        assert f.mem_instrs > b.mem_instrs > r.mem_instrs
        assert f.runtime_s > b.runtime_s > r.runtime_s
        assert f.ipc < b.ipc < r.ipc

    @pytest.mark.parametrize("model", list(PAPER))
    def test_enhancement_percentages_close_to_paper(self, model):
        """The paper's headline claims, within 7 percentage points."""
        paper_enh = {
            ("lenet", "F"): (52.05, 39.04, 27.13),
            ("lenet", "B"): (34.05, 24.54, 14.43),
            ("resnet20", "F"): (56.66, 42.66, 32.30),
            ("resnet20", "B"): (39.02, 27.52, 18.85),
            ("mobilenet_v1", "F"): (47.12, 32.82, 27.04),
            ("mobilenet_v1", "B"): (29.21, 19.76, 13.34),
        }
        r = simulate_model(model, Isa.RV64R)
        for base_isa, key in ((Isa.RV64F, "F"), (Isa.BASELINE, "B")):
            base = simulate_model(model, base_isa)
            e = enhancement(base, r)
            rt_p, ic_p, ipc_p = paper_enh[(model, key)]
            assert abs(e["runtime"] - rt_p) < 7.0
            assert abs(e["IC"] - ic_p) < 7.0
            assert abs(e["IPC"] - ipc_p) < 7.0

    def test_overall_headline_numbers(self):
        """Paper abstract: RV64R vs RV64F ~29% IPC gain, ~34% fewer memory
        accesses; vs baseline 15% IPC / 22% memory."""
        ipc_f, ipc_b, mem_f, mem_b = [], [], [], []
        for model in PAPER:
            f = simulate_model(model, Isa.RV64F)
            b = simulate_model(model, Isa.BASELINE)
            r = simulate_model(model, Isa.RV64R)
            ipc_f.append(enhancement(f, r)["IPC"])
            ipc_b.append(enhancement(b, r)["IPC"])
            mem_f.append(enhancement(f, r)["l1_accesses"])
            mem_b.append(enhancement(b, r)["l1_accesses"])
        avg = lambda xs: sum(xs) / len(xs)
        assert abs(avg(ipc_f) - 28.82) < 7.0
        assert abs(avg(ipc_b) - 15.54) < 7.0
        assert abs(avg(mem_f) - 33.99) < 10.0
        assert abs(avg(mem_b) - 22.09) < 10.0
