"""Fault-tolerance runtime: retry, restore-on-failure, straggler detection,
heartbeat, data-cursor replay."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.data import DataCursor, SyntheticLMSource
from repro.runtime import (FaultInjector, Heartbeat, StragglerDetector,
                           TrainController)


def counting_step(fail_on=()):
    def step(state, batch, step_idx):
        return state + 1, {"loss": float(100 - state)}
    return step


def test_run_completes_and_checkpoints(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    ctl = TrainController(counting_step(), ckpt, ckpt_every=5)
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLMSource(cfg, ShapeSpec("t", 16, 2, "train"))
    state, report = ctl.run(jnp.zeros(()), src, DataCursor(), 12)
    assert report.steps_completed == 12
    assert int(state) == 12
    assert ckpt.latest_step() == 10


def test_transient_failure_retried(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))
    inj = FaultInjector(fail_steps=(3,))
    ctl = TrainController(counting_step(), ckpt, ckpt_every=100,
                          max_retries=1, injector=inj)
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLMSource(cfg, ShapeSpec("t", 16, 2, "train"))
    state, report = ctl.run(jnp.zeros(()), src, DataCursor(), 6)
    assert int(state) == 6          # no step lost
    assert report.restarts == 0     # retry, not restore


def test_fatal_failure_restores_from_checkpoint(tmp_path):
    ckpt = CheckpointManager(str(tmp_path))

    calls = {"n": 0}

    def step(state, batch, step_idx):
        calls["n"] += 1
        if step_idx == 7 and calls["n"] < 20:  # fails repeatedly at step 7
            if calls.setdefault("fails", 0) < 2:
                calls["fails"] = calls.get("fails", 0) + 1
                raise RuntimeError("boom")
        return state + 1, {"loss": 0.0}

    ctl = TrainController(step, ckpt, ckpt_every=5, max_retries=0)
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLMSource(cfg, ShapeSpec("t", 16, 2, "train"))
    state, report = ctl.run(jnp.zeros(()), src, DataCursor(), 10)
    assert report.restarts >= 1
    # state is consistent with the number of *committed* steps after replay
    assert int(state) == 10


def test_straggler_detector_flags_sustained_outliers():
    det = StragglerDetector(window=16, threshold=3.0, sustained=3)
    for _ in range(12):
        assert not det.observe(0.10)
    flagged = [det.observe(1.0) for _ in range(4)]
    assert any(flagged)


def test_straggler_tolerates_noise():
    det = StragglerDetector(window=16, threshold=3.0, sustained=3)
    rng = np.random.default_rng(0)
    flags = [det.observe(0.1 + 0.01 * rng.random()) for _ in range(50)]
    assert not any(flags)


def test_heartbeat_staleness(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"))
    assert hb.is_stale(0.5)
    hb.beat(3, loss=1.0)
    assert not hb.is_stale(5.0)
    assert hb.read()["step"] == 3


def test_data_cursor_determinism():
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLMSource(cfg, ShapeSpec("t", 16, 2, "train"))
    b1 = src.batch_at(5)
    b2 = src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
