"""Speculative decoding (repro.spec): verify-plan policy units, proposer
units, draft-pair validation, greedy token-identity against the plain paged
engine (ngram + model drafts, bf16 + int8 KV, under preemption), and the
device-side int8 scale-slot consistency of PagedKVCache rollback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_draft_config
from repro.models import build_model, check_draft_pair
from repro.parallel import ParallelContext
from repro.serve import PagedServeEngine, Request
from repro.serve.paged_cache import PagedKVCache
from repro.serve.scheduler import DECODING, FifoScheduler
from repro.spec import (DraftProposer, ModelDraft, NgramDraft,
                        SpeculativeServeEngine)

PCTX = ParallelContext(None)


def _trace(n=3, prompt_len=8, max_new=10):
    return [Request(rid=i,
                    prompt=[1 + i] + [2 + (j % 5) for j in range(prompt_len - 1)],
                    max_new_tokens=max_new)
            for i in range(n)]


def _drain_outputs(engine, reqs):
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs]


@pytest.fixture(scope="module")
def target():
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    return bundle, params


@pytest.fixture(scope="module")
def reference_outputs(target):
    bundle, params = target
    eng = PagedServeEngine(bundle, params, PCTX, slots=2)
    return _drain_outputs(eng, _trace())


# ----------------------------------------------------------- policy units
class TestVerifyPlan:
    def _decoding(self, n, max_new=32, output_len=1):
        reqs = _trace(n, max_new=max_new)
        for i, r in enumerate(reqs):
            r.state = DECODING
            r.admit_seq = i
            r.output = list(range(output_len))
        return reqs

    def test_full_k_without_budget(self):
        s = FifoScheduler(prefill_chunk=4)
        plan = s.verify_plan(self._decoding(3), spec_k=4)
        assert [(r.admit_seq, k) for r, k in plan] == [(0, 4), (1, 4), (2, 4)]

    def test_k_capped_by_remaining_quota(self):
        s = FifoScheduler(prefill_chunk=4)
        reqs = self._decoding(1, max_new=8, output_len=6)  # 2 tokens left
        (req, k), = s.verify_plan(reqs, spec_k=4)
        assert k == 1                       # k+1 emitted tokens <= remaining

    def test_budget_rows_in_admission_order(self):
        s = FifoScheduler(prefill_chunk=4, verify_budget=7)
        plan = s.verify_plan(self._decoding(3), spec_k=4)
        # 5 rows to the oldest, 2 to the next (k=1), none left for the third
        assert [(r.admit_seq, k) for r, k in plan] == [(0, 4), (1, 1)]

    def test_zero_k_degenerates_to_plain_rows(self):
        s = FifoScheduler(prefill_chunk=4)
        plan = s.verify_plan(self._decoding(2), spec_k=0)
        assert [k for _, k in plan] == [0, 0]


class TestNgramDraft:
    def test_repeats_last_token_without_match(self):
        d = NgramDraft()
        assert d._continue([5, 6, 7], 3) == [7, 7, 7]

    def test_copies_continuation_of_longest_match(self):
        d = NgramDraft(max_n=3)
        #         match [8, 9] -> continuation 10, 11
        hist = [1, 8, 9, 10, 11, 3, 8, 9]
        assert d._continue(hist, 2) == [10, 11]

    def test_self_extends_short_continuation(self):
        d = NgramDraft(max_n=2)
        hist = [4, 4]                       # match at the history tail
        assert d._continue(hist, 3) == [4, 4, 4]

    def test_period_two_cycle(self):
        d = NgramDraft(max_n=3)
        hist = [1, 2, 1, 2, 1]
        assert d._continue(hist, 4) == [2, 1, 2, 1]

    def test_empty_for_zero_k(self):
        assert NgramDraft()._continue([1, 2], 0) == []


class TestDraftPair:
    def test_registered_pair_validates(self):
        tgt = get_config("llama3-8b", smoke=True)
        draft = get_draft_config("llama3-8b", smoke=True)
        assert draft is not None and draft.vocab_size == tgt.vocab_size
        check_draft_pair(tgt, draft)        # no raise

    def test_vocab_mismatch_rejected(self):
        tgt = get_config("llama3-8b", smoke=True)
        bad = get_config("chatglm3-6b", smoke=False)  # different vocab
        with pytest.raises(ValueError, match="tokenizer"):
            check_draft_pair(tgt, bad)

    def test_unpaged_family_rejected(self):
        tgt = get_config("llama3-8b", smoke=True)
        ssm = get_config("rwkv6-3b", smoke=True)
        with pytest.raises(ValueError, match="paged"):
            check_draft_pair(tgt, ssm)

    def test_unregistered_target_has_no_draft(self):
        assert get_draft_config("whisper-large-v3") is None

    def test_explicit_name_does_not_resolve_pairings(self):
        # a target arch given as an explicit draft name must NOT silently
        # resolve to its paired draft
        assert get_draft_config("llama3-8b", pairing=False) is None
        draft = get_draft_config("llama3-8b-draft", smoke=True, pairing=False)
        assert draft is not None and draft.num_layers == 1


# ------------------------------------------------- engine behavior
# (the greedy token-identity sweep — ngram/model drafts x int8 KV x prefix
# sharing — lives in tests/test_engine_identity.py; this class keeps the
# spec-specific behaviors: metrics accounting, draft-cache lockstep,
# preemption mid-speculation, and the spec_k=0 degenerate case)
class TestSpeculativeEngine:
    def test_ngram_spec_metrics_accounting(self, target):
        bundle, params = target
        eng = SpeculativeServeEngine(bundle, params, PCTX, slots=2, spec_k=3)
        reqs = _trace()
        _drain_outputs(eng, reqs)
        m = eng.metrics
        assert m.spec_steps > 0
        assert 0 <= m.draft_accepted <= m.draft_proposed
        assert 0.0 <= m.acceptance_rate <= 1.0
        # every verify step emits at least the target's own token
        assert m.decode_tokens >= m.spec_steps
        assert {"acceptance_rate", "tokens_per_step",
                "spec_decode_tps"} <= m.summary().keys()
        # per-request accounting (Request.spec_* fields) sums to the
        # engine aggregates, so neither side can silently drift
        assert sum(r.spec_steps for r in reqs) == m.spec_steps
        assert sum(r.draft_proposed for r in reqs) == m.draft_proposed
        assert sum(r.draft_accepted for r in reqs) == m.draft_accepted
        assert all(0.0 <= r.acceptance_rate <= 1.0 for r in reqs)

    def test_model_draft_outputs_identical_to_plain(self, target,
                                                    reference_outputs):
        bundle, params = target
        draft_cfg = get_draft_config("llama3-8b", smoke=True)
        draft_bundle = build_model(draft_cfg)
        draft_params = draft_bundle.init_params(jax.random.PRNGKey(1))
        eng = SpeculativeServeEngine(
            bundle, params, PCTX, slots=2, spec_k=2,
            draft_bundle=draft_bundle, draft_params=draft_params)
        assert _drain_outputs(eng, _trace()) == reference_outputs
        assert isinstance(eng.draft, ModelDraft)
        # the draft cache stayed in lockstep and was released on finish
        assert all(eng.draft.kv.length(s) == 0 for s in range(2))

    def test_identical_under_preemption(self, target, reference_outputs):
        # a pool too small for 3 concurrent requests forces preemption and
        # recompute mid-speculation; outputs must still match the
        # uncontended plain engine
        bundle, params = target
        eng = SpeculativeServeEngine(bundle, params, PCTX, slots=2, spec_k=3,
                                     page_size=4, num_pages=8)
        assert _drain_outputs(eng, _trace()) == reference_outputs
        assert eng.metrics.preemptions > 0

    def test_spec_k_zero_matches_plain(self, target, reference_outputs):
        bundle, params = target
        eng = SpeculativeServeEngine(bundle, params, PCTX, slots=2, spec_k=0)
        assert _drain_outputs(eng, _trace()) == reference_outputs
        assert eng.metrics.draft_proposed == 0

    def test_draft_and_bundle_are_exclusive(self, target):
        bundle, params = target
        with pytest.raises(ValueError, match="not both"):
            SpeculativeServeEngine(
                bundle, params, PCTX, slots=2, draft=NgramDraft(),
                draft_bundle=bundle, draft_params=params)


# --------------------------------- recurrent-state rollback (state cache)
class _WrongDraft(DraftProposer):
    """Adversarial proposer: always proposes a constant (almost certainly
    wrong) token, so every verify tick rejects at position 0 and must roll
    the slot back — KV pages truncated AND the paired state checkpoint
    restored.  Maximum rollback pressure, zero acceptance."""

    def __init__(self, token: int = 3):
        self.token = token

    def propose(self, plan):
        return {slot: [self.token] * k for slot, _req, k in plan}


class TestRecurrentStateRollback:
    """Spec-decode rollback on recurrent-state families: rejecting drafted
    tokens must restore the pre-draft state snapshot atomically with the
    KV page truncation (the zamba2 hybrid is the point — one
    ``_truncate_slot`` call rolls back attention pages and mamba state
    together), leaving greedy outputs identical to the plain paged
    engine's at any acceptance rate."""

    def _family_pair(self, arch):
        bundle = build_model(get_config(arch, smoke=True))
        params = bundle.init_params(jax.random.PRNGKey(0))
        plain = PagedServeEngine(bundle, params, PCTX, slots=2, page_size=8,
                                 num_pages=16)
        return bundle, params, _drain_outputs(plain, _trace())

    @pytest.mark.parametrize("arch", ["rwkv6-3b", "zamba2-1.2b"])
    def test_all_rejected_rolls_back_state_identically(self, arch):
        bundle, params, reference = self._family_pair(arch)
        eng = SpeculativeServeEngine(bundle, params, PCTX, slots=2,
                                     page_size=8, num_pages=16, spec_k=3,
                                     draft=_WrongDraft())
        reqs = _trace()
        assert _drain_outputs(eng, reqs) == reference
        # every tick rejected its proposals and restored a checkpoint
        # (except a request's final tick, which finishes the slot instead
        # of rolling it back)
        assert eng.metrics.draft_accepted == 0
        assert eng.state.stats["restores"] >= eng.metrics.spec_steps - len(reqs)
        assert eng.state.stats["restores"] > 0
        # rollback left nothing behind: pool drained leak-free on finish
        assert eng.state.used_slots == 0 and eng.kv.used_pages == 0

    def test_hybrid_rollback_is_atomic_kv_and_state(self):
        """Mixed acceptance on the hybrid: the ngram draft accepts some
        proposals and rejects others on this repetitive trace, so slots
        repeatedly land mid-ring — outputs must still match plain, and
        both pools must roll back in lockstep (any KV/state skew would
        desynchronize the attention and mamba halves of the next tick and
        change tokens)."""
        bundle, params, reference = self._family_pair("zamba2-1.2b")
        eng = SpeculativeServeEngine(bundle, params, PCTX, slots=2,
                                     page_size=8, num_pages=16, spec_k=3)
        assert _drain_outputs(eng, _trace()) == reference
        assert eng.state.stats["restores"] > 0
        assert eng.state.used_slots == 0 and eng.kv.used_pages == 0


# ------------------------------------- device-side rollback (int8 scales)
def test_int8_scale_slots_consistent_after_rollback(target):
    """Speculative rollback on an int8 KV cache: write a committed prefix,
    write rejected candidates over the next positions, truncate, then write
    the accepted continuation — the logits must be bit-identical to a run
    that never wrote the rejected tokens, i.e. every (page slot, head)
    scale stays paired with its payload across the rewrite."""
    bundle, params = target
    fn = jax.jit(lambda p, c, t, l, n, bt: bundle.decode_paged(
        p, c, t, l, n, bt, PCTX))
    page_size, chunk = 4, 4

    def prefill(kv, cache, toks, pos):
        kv.allocate(0, pos + len(toks))
        padded = list(toks) + [0] * (chunk - len(toks))
        logits, cache = fn(params, cache,
                           jnp.asarray([padded], jnp.int32),
                           jnp.asarray([pos], jnp.int32),
                           jnp.asarray([len(toks)], jnp.int32),
                           jnp.asarray(kv.block_tables[0:1]))
        kv.commit(0, pos + len(toks))
        return np.asarray(logits[0, :len(toks)]), cache

    def run(with_rejected):
        kv = PagedKVCache(slots=1, num_pages=8, page_size=page_size)
        cache = bundle.init_paged_cache(kv.pool_pages, page_size,
                                        kv_dtype="int8")
        _, cache = prefill(kv, cache, [5, 6, 7, 8], 0)     # committed prefix
        if with_rejected:
            # rejected candidates cross a page boundary, then roll back
            _, cache = prefill(kv, cache, [9, 10, 11], 4)
            kv.truncate(0, 4)
        logits, cache = prefill(kv, cache, [12, 13], 4)    # accepted path
        return logits

    np.testing.assert_array_equal(run(with_rejected=True),
                                  run(with_rejected=False))
