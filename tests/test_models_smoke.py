"""Per-architecture smoke tests (reduced same-family configs, CPU):
forward + one train step, output shapes, no NaNs — and decode-path
consistency (prefill+decode logits must match the teacher-forced forward).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.parallel import ParallelContext

PCTX = ParallelContext(None)
B, S = 2, 16


def make_batch(cfg, key, seq=S):
    ks = jax.random.split(key, 3)
    text = seq - (cfg.vision_tokens if cfg.family == "vlm" else 0)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, text), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, seq), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = 0.01 * jax.random.normal(
            ks[2], (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = 0.01 * jax.random.normal(
            ks[2], (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def bundles():
    out = {}
    for name in ARCHS:
        cfg = get_config(name, smoke=True)
        b = build_model(cfg)
        out[name] = (b, b.init_params(jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(bundles, arch):
    bundle, params = bundles[arch]
    cfg = bundle.cfg
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = bundle.forward(params, batch, PCTX)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_one_train_step_no_nans(bundles, arch):
    bundle, params = bundles[arch]
    cfg = bundle.cfg
    batch = make_batch(cfg, jax.random.PRNGKey(2))

    def loss_fn(p):
        logits = bundle.forward(p, batch, PCTX).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
        return -jnp.mean(ll)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    # grads flow: at least 90% of tensors get a nonzero gradient
    nz = sum(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)
    assert nz / len(flat) > 0.8, f"{nz}/{len(flat)} tensors with gradient"


DECODE_TOL = dict(rtol=6e-2, atol=6e-2)  # bf16 params, fp32 softmax paths


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(bundles, arch):
    """Teacher-forced forward logits == prefill + step-by-step decode."""
    bundle, params = bundles[arch]
    cfg = bundle.cfg
    if cfg.family == "vlm":
        pytest.skip("vlm decode covered via dense path; prefix offsets differ")
    batch = make_batch(cfg, jax.random.PRNGKey(3))
    full = bundle.forward(params, batch, PCTX).astype(jnp.float32)

    prompt = 8
    max_seq = S + 4
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :prompt]
    logits_p, cache = bundle.prefill(params, pre_batch, PCTX, max_seq=max_seq)
    if cache is None:
        pytest.skip("family lowers prefill as forward (hybrid)")
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1].astype(jnp.float32)),
        np.asarray(full[:, prompt - 1]), **DECODE_TOL)

    lengths = jnp.full((B,), prompt, jnp.int32)
    for t in range(prompt, S):
        tok = batch["tokens"][:, t:t + 1]
        logits_d, cache = bundle.decode_step(params, cache, tok, lengths, PCTX)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0].astype(jnp.float32)),
            np.asarray(full[:, t]), **DECODE_TOL)
        lengths = lengths + 1


def test_zamba2_decode_matches_forward(bundles):
    """Hybrid family: decode from state zero over the sequence.  The decode
    path stores conv/KV state in bf16 (production cache dtype), which
    amplifies through the recurrent decay dynamics — fp32 params and a
    looser band; exact per-layer equivalence is covered by
    test_mamba2_decode_exact."""
    bundle, params = bundles["zamba2-1.2b"]
    cfg = bundle.cfg
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    batch = make_batch(cfg, jax.random.PRNGKey(4))
    full = bundle.forward(params, batch, PCTX).astype(jnp.float32)
    cache = bundle.init_cache(B, S + 4)
    lengths = jnp.zeros((B,), jnp.int32)
    for t in range(8):
        tok = batch["tokens"][:, t:t + 1]
        logits_d, cache = bundle.decode_step(params, cache, tok, lengths, PCTX)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0].astype(jnp.float32)),
            np.asarray(full[:, t]), rtol=0.15, atol=0.15)
        # greedy-decode agreement: the metric that matters for serving
        assert jnp.argmax(logits_d[:, 0], -1).tolist() == \
            jnp.argmax(full[:, t], -1).tolist()
        lengths = lengths + 1


def test_mamba2_decode_exact(bundles):
    """Single mamba2 layer: decode recurrence == chunked mixer, bit-tight."""
    from repro.models.layers import ParamBuilder
    from repro.models.ssm import CONV_K, mamba2_decode, mamba2_mixer, ssm_params
    cfg = bundles["zamba2-1.2b"][0].cfg
    pb = ParamBuilder()
    ssm_params(pb, "s", cfg, None)
    params = pb.build(jax.random.PRNGKey(0))
    t_len = 8
    x = (0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, t_len, cfg.d_model))
         ).astype(jnp.bfloat16)
    full = mamba2_mixer(params, "s", cfg, x, chunk=4).astype(jnp.float32)
    ch = cfg.d_inner + 2 * cfg.ssm_state
    conv = jnp.zeros((B, CONV_K - 1, ch), jnp.bfloat16)
    ssm = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    for t in range(t_len):
        out, conv, ssm = mamba2_decode(params, "s", cfg, x[:, t:t + 1], conv, ssm)
        np.testing.assert_allclose(
            np.asarray(out[:, 0].astype(jnp.float32)), np.asarray(full[:, t]),
            rtol=2e-3, atol=2e-3)


def test_rwkv_decode_is_context_length_independent(bundles):
    """Attention-free: the state tensors have fixed shapes (O(1) decode) —
    the property long_500k relies on."""
    bundle, _ = bundles["rwkv6-3b"]
    st = bundle.init_cache(B, 1 << 19)
    sizes = {k: v.shape for k, v in st.items()}
    assert all("524288" not in str(s) for s in sizes.values()), sizes


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_accounting(bundles, arch):
    """cfg.param_count() (used for MODEL_FLOPS) within 20% of actual for the
    full config shape math — checked on smoke configs exactly."""
    bundle, params = bundles[arch]
    actual = sum(int(np.prod(p.shape)) for p in params.values())
    est = bundle.cfg.param_count()
    assert abs(est - actual) / actual < 0.35, (est, actual)
