"""Paged serving engine + scheduler: FIFO/budget/preemption policy units,
paged-vs-contiguous token identity, oversubscription, metrics."""
import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.parallel import ParallelContext
from repro.serve import PagedServeEngine, Request, ServeEngine
from repro.serve.scheduler import DECODING, PREFILLING, FifoScheduler

PCTX = ParallelContext(None)


# ------------------------------------------------------------ scheduler unit
class TestFifoScheduler:
    def _reqs(self, n, prompt_len=10):
        return [Request(rid=i, prompt=[1] * prompt_len) for i in range(n)]

    def test_admission_is_fifo(self):
        s = FifoScheduler(prefill_chunk=4)
        reqs = self._reqs(4)
        for r in reqs:
            s.submit(r)
        placed = s.admit([7, 3])
        assert [(slot, r.rid) for slot, r in placed] == [(7, 0), (3, 1)]
        assert [r.rid for r in s.waiting] == [2, 3]
        assert all(r.state == PREFILLING for _, r in placed)
        # admission order is recorded for preemption/planning
        assert placed[0][1].admit_seq < placed[1][1].admit_seq

    def test_preempted_requeues_at_front(self):
        s = FifoScheduler(prefill_chunk=4)
        reqs = self._reqs(3)
        for r in reqs:
            s.submit(r)
        (slot, victim), = s.admit([0])
        victim.output = [42]
        victim.prefill_pos = 7
        s.requeue_preempted(victim)
        assert [r.rid for r in s.waiting] == [0, 1, 2]
        assert victim.prefill_pos == 0 and victim.preemptions == 1
        # recompute covers prompt + already-generated tokens
        assert victim.prefill_tokens() == victim.prompt + [42]

    def test_prefill_plan_respects_budget_and_order(self):
        s = FifoScheduler(prefill_chunk=4, prefill_budget=10)
        reqs = self._reqs(4, prompt_len=6)
        for r in reqs:
            s.submit(r)
        placed = s.admit([0, 1, 2, 3])
        plan = s.prefill_plan([r for _, r in placed])
        # admission order; 4 + 4 + 2 = 10-token budget, 4th request starved
        assert [(r.rid, n) for r, n in plan] == [(0, 4), (1, 4), (2, 2)]

    def test_prefill_plan_final_partial_chunk(self):
        s = FifoScheduler(prefill_chunk=8)
        (req,) = self._reqs(1, prompt_len=6)
        s.submit(req)
        s.admit([0])
        req.prefill_pos = 4
        assert s.prefill_plan([req]) == [(req, 2)]

    def test_preemption_victim_is_youngest(self):
        s = FifoScheduler(prefill_chunk=4)
        reqs = self._reqs(3)
        for r in reqs:
            s.submit(r)
        active = [r for _, r in s.admit([0, 1, 2])]
        assert s.preemption_victim(active).rid == 2
        assert s.preemption_victim(active, exclude=active[2]).rid == 1
        assert s.preemption_victim([]) is None


# ----------------------------------------------------------- engine (smoke)
@pytest.fixture(scope="module")
def llama():
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    return bundle, bundle.init_params(jax.random.PRNGKey(0))


def _trace(n, prompt_len=5, max_new=6):
    return [Request(rid=i, prompt=[1 + i] + [2] * (prompt_len - 1),
                    max_new_tokens=max_new) for i in range(n)]


def _run(engine, reqs):
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    return [r.output for r in reqs]


def test_oversubscription_preempts_and_recomputes_identically(llama):
    """Scheduler fairness under page pressure: a pool too small for the
    offered load must still drain every request, via youngest-first
    preemption, without changing any request's tokens."""
    bundle, params = llama
    tight = _trace(5, prompt_len=6, max_new=8)
    eng = PagedServeEngine(bundle, params, PCTX, slots=4, page_size=4,
                           num_pages=10, prefill_chunk=4)
    tight_out = _run(eng, tight)
    assert eng.metrics.preemptions > 0
    assert all(r.done for r in tight)
    roomy = _trace(5, prompt_len=6, max_new=8)
    eng2 = PagedServeEngine(bundle, params, PCTX, slots=4, page_size=4,
                            num_pages=64, prefill_chunk=4)
    roomy_out = _run(eng2, roomy)
    assert eng2.metrics.preemptions == 0
    assert tight_out == roomy_out
    # FIFO fairness: completion order follows submit order
    finish = [r.finished_at for r in tight]
    assert finish == sorted(finish)

def test_submit_rejects_request_larger_than_pool(llama):
    bundle, params = llama
    eng = PagedServeEngine(bundle, params, PCTX, slots=2, page_size=4,
                           num_pages=4, prefill_chunk=4)
    with pytest.raises(ValueError, match="exceeds per-request capacity"):
        eng.submit(Request(rid=0, prompt=[1] * 10, max_new_tokens=10))


def test_submit_rejects_empty_prompt(llama):
    """An empty prompt would never be planned by prefill_plan (zero tokens
    to cache), leaving the request PREFILLING forever — reject at submit."""
    bundle, params = llama
    eng = PagedServeEngine(bundle, params, PCTX, slots=2, page_size=4,
                           num_pages=4, prefill_chunk=4)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, prompt=[], max_new_tokens=4))
    slot_eng = ServeEngine(bundle, params, PCTX, slots=2, max_seq=32)
    with pytest.raises(ValueError, match="empty prompt"):
        slot_eng.submit(Request(rid=0, prompt=[], max_new_tokens=4))


def test_engine_metrics_accounting(llama):
    bundle, params = llama
    reqs = _trace(3, prompt_len=5, max_new=4)
    eng = PagedServeEngine(bundle, params, PCTX, slots=3, page_size=8,
                           num_pages=12, prefill_chunk=4)
    for r in reqs:
        eng.submit(r)
    m = eng.run_until_drained()
    assert m.requests_done == 3
    assert m.prefill_tokens == sum(len(r.prompt) for r in reqs)
    # first token comes from prefill logits; the rest from decode ticks
    assert m.decode_tokens == sum(len(r.output) - 1 for r in reqs)
    assert len(m.ttfts) == 3 and all(t > 0 for t in m.ttfts)
    assert m.prefill_time_s > 0 and m.decode_time_s > 0
    assert 0 < m.peak_page_utilization <= 1
    # every request's pages flushed back on completion
    assert eng.kv.used_pages == 0
    s = m.summary()
    assert s["requests_done"] == 3 and s["preemptions"] == 0


def test_paged_engine_rejects_unsupported_families():
    """Audio (enc-dec) has neither paged KV nor a state pool — rejected;
    recurrent-state families (e.g. zamba2) construct via the state cache."""
    cfg = get_config("whisper-large-v3", smoke=True)
    bundle = build_model(cfg)
    assert not bundle.supports_paged_serving
    with pytest.raises(ValueError, match="no paged"):
        PagedServeEngine(bundle, None, PCTX)
    with pytest.raises(ValueError, match="no paged"):
        bundle.init_paged_cache(8, 8)

    zcfg = get_config("zamba2-1.2b", smoke=True)
    zbundle = build_model(zcfg)
    assert not zbundle.supports_paged_kv       # pages live inside the
    assert zbundle.supports_paged_state        # combined hybrid contract
    assert zbundle.supports_paged_serving
    zparams = zbundle.init_params(jax.random.PRNGKey(0))
    eng = PagedServeEngine(zbundle, zparams, PCTX, slots=2, page_size=8,
                           num_pages=8, prefill_chunk=4)
    assert eng.state is not None and eng.state.pool_slots == 2 + 2 * 2
    with pytest.raises(ValueError, match="prefix_sharing"):
        PagedServeEngine(zbundle, zparams, PCTX, slots=2, page_size=8,
                         num_pages=8, prefix_sharing=True)


def test_request_lifecycle_states(llama):
    """queued -> prefilling -> decoding -> done, one tick at a time."""
    bundle, params = llama
    eng = PagedServeEngine(bundle, params, PCTX, slots=1, page_size=8,
                           num_pages=8, prefill_chunk=4)
    req = Request(rid=0, prompt=[1] * 8, max_new_tokens=3)
    eng.submit(req)
    eng.step()                  # admit + first 4-token chunk
    assert req.state == PREFILLING and req.prefill_pos == 4
    eng.step()                  # final chunk -> first token -> decoding
    assert req.state == DECODING and len(req.output) >= 1
    while not req.done:
        eng.step()
    assert len(req.output) == 3 and eng.kv.used_pages == 0
