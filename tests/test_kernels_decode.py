"""flash_decode: GQA shapes, partial lengths, chunk sweep, properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels.flash_decode import decode_attention_ref, flash_decode

TOL = dict(rtol=3e-4, atol=3e-4)


def make(b, s, hq, hkv, d, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, hq, d), jnp.float32)
    k = jax.random.normal(k2, (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(k3, (b, s, hkv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 4, 4, 64),    # MHA
    (2, 256, 8, 2, 64),    # GQA 4:1
    (1, 128, 32, 8, 64),   # llama3-style 4:1 at 32 heads
    (2, 128, 14, 2, 64),   # internvl2 ratio 7:1
])
def test_decode_matches_ref(b, s, hq, hkv, d):
    q, k, v = make(b, s, hq, hkv, d)
    lengths = jnp.full((b,), s, jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=64)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_partial_lengths_masked():
    q, k, v = make(3, 256, 8, 2, 32, seed=1)
    lengths = jnp.array([256, 57, 1], jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=64)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("chunk", [32, 64, 128, 256])
def test_chunk_invariance(chunk):
    """Output must not depend on the APR chunking of the reduction."""
    q, k, v = make(1, 256, 4, 1, 32, seed=2)
    lengths = jnp.array([200], jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=chunk)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_bfloat16():
    q, k, v = make(1, 128, 8, 2, 64, seed=3)
    q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    lengths = jnp.array([128], jnp.int32)
    out = flash_decode(q, k, v, lengths)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


@settings(max_examples=10, deadline=None)
@given(length=st.integers(1, 128), seed=st.integers(0, 100))
def test_property_softmax_convexity(length, seed):
    """Attention output lies in the convex hull of V rows: max|out| <=
    max|v| over the valid prefix."""
    q, k, v = make(1, 128, 4, 1, 32, seed=seed)
    lengths = jnp.array([length], jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=32)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v[:, :length]))) + 1e-4
