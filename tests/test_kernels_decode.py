"""flash_decode (contiguous + paged): GQA shapes, partial lengths, chunk
sweep, block-table gathering, properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels.flash_decode import (decode_attention_ref, flash_decode,
                                        flash_decode_paged, gather_pages,
                                        paged_decode_attention_ref)

TOL = dict(rtol=3e-4, atol=3e-4)


def make(b, s, hq, hkv, d, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, hq, d), jnp.float32)
    k = jax.random.normal(k2, (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(k3, (b, s, hkv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 4, 4, 64),    # MHA
    (2, 256, 8, 2, 64),    # GQA 4:1
    (1, 128, 32, 8, 64),   # llama3-style 4:1 at 32 heads
    (2, 128, 14, 2, 64),   # internvl2 ratio 7:1
])
def test_decode_matches_ref(b, s, hq, hkv, d):
    q, k, v = make(b, s, hq, hkv, d)
    lengths = jnp.full((b,), s, jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=64)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_partial_lengths_masked():
    q, k, v = make(3, 256, 8, 2, 32, seed=1)
    lengths = jnp.array([256, 57, 1], jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=64)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("chunk", [32, 64, 128, 256])
def test_chunk_invariance(chunk):
    """Output must not depend on the APR chunking of the reduction."""
    q, k, v = make(1, 256, 4, 1, 32, seed=2)
    lengths = jnp.array([200], jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=chunk)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_bfloat16():
    q, k, v = make(1, 128, 8, 2, 64, seed=3)
    q, k, v = q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    lengths = jnp.array([128], jnp.int32)
    out = flash_decode(q, k, v, lengths)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# ------------------------------------------------------------------- paged
def make_paged(b, hq, hkv, d, p_pool, ps, p_max, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(k1, (b, hq, d), jnp.float32)
    kp = jax.random.normal(k2, (p_pool, ps, hkv, d), jnp.float32)
    vp = jax.random.normal(k3, (p_pool, ps, hkv, d), jnp.float32)
    # each row draws distinct pages from 1..p_pool-1 in shuffled order
    perm = jax.random.permutation(k4, jnp.arange(1, p_pool))
    bt = perm[:b * p_max].reshape(b, p_max).astype(jnp.int32)
    return q, kp, vp, bt


def test_paged_matches_gather_ref():
    q, kp, vp, bt = make_paged(2, 8, 2, 32, p_pool=13, ps=16, p_max=3)
    lengths = jnp.array([48, 21], jnp.int32)
    out = flash_decode_paged(q, kp, vp, lengths, bt, chunk=16)
    ref = paged_decode_attention_ref(q, kp, vp, lengths, bt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_paged_matches_contiguous_flash_decode():
    """With the pages gathered back to a contiguous layout, the paged and
    contiguous kernels are the same computation."""
    q, kp, vp, bt = make_paged(2, 4, 4, 32, p_pool=9, ps=16, p_max=4)
    lengths = jnp.array([64, 50], jnp.int32)
    out_paged = flash_decode_paged(q, kp, vp, lengths, bt, chunk=16)
    k = gather_pages(kp, bt)
    v = gather_pages(vp, bt)
    out_contig = flash_decode(q, k, v, lengths, chunk=16)
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_contig),
                               **TOL)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_paged_chunk_invariance(chunk):
    """Output must not depend on the within-page APR chunking."""
    q, kp, vp, bt = make_paged(1, 4, 1, 32, p_pool=5, ps=16, p_max=4, seed=2)
    lengths = jnp.array([37], jnp.int32)
    out = flash_decode_paged(q, kp, vp, lengths, bt, chunk=chunk)
    ref = paged_decode_attention_ref(q, kp, vp, lengths, bt)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_paged_null_page_padding_masked():
    """Block-table entries past the allocated pages point at the null page;
    whatever lives there must not leak into the output."""
    q, kp, vp, bt = make_paged(2, 4, 2, 16, p_pool=9, ps=8, p_max=4, seed=3)
    bt = bt.at[:, 2:].set(0)                 # only 2 real pages per row
    poisoned = kp.at[0].set(1e3)             # garbage in the null page
    vpois = vp.at[0].set(1e3)
    lengths = jnp.array([16, 9], jnp.int32)  # within the 2 real pages
    out = flash_decode_paged(q, poisoned, vpois, lengths, bt, chunk=8)
    ref = paged_decode_attention_ref(q, kp, vp, lengths, bt.at[:, 2:].set(1))
    # ref uses clean pages at the padded slots: identical output proves the
    # poisoned null page never contributed
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_paged_zero_length_row_returns_zeros():
    q, kp, vp, bt = make_paged(2, 4, 2, 16, p_pool=5, ps=8, p_max=2, seed=4)
    lengths = jnp.array([16, 0], jnp.int32)
    out = flash_decode_paged(q, kp, vp, lengths, bt, chunk=8)
    assert float(jnp.abs(out[1]).max()) == 0.0
    ref = paged_decode_attention_ref(q, kp, vp, lengths, bt)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]), **TOL)


@settings(max_examples=10, deadline=None)
@given(length=st.integers(1, 128), seed=st.integers(0, 100))
def test_property_softmax_convexity(length, seed):
    """Attention output lies in the convex hull of V rows: max|out| <=
    max|v| over the valid prefix."""
    q, k, v = make(1, 128, 4, 1, 32, seed=seed)
    lengths = jnp.array([length], jnp.int32)
    out = flash_decode(q, k, v, lengths, chunk=32)
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v[:, :length]))) + 1e-4
