"""Pipeline-model unit tests: hazards, rented R_EX stage, APR forwarding."""
import pytest

from repro.core.isa import Instr, Isa, Kind
from repro.core.pipeline import (
    APR,
    PipelineParams,
    simulate,
    steady_state_cycles,
)

P = PipelineParams(load_use_penalty=1, branch_penalty=2, jump_penalty=1,
                   int_mul_latency=2, int_div_latency=12, fp_latency=8)


def alu(dst, *srcs):
    return Instr(Kind.ALU, dst=dst, srcs=srcs)


def test_no_hazard_ipc_is_one():
    stream = [alu(f"r{i}") for i in range(100)]
    res, _ = simulate(stream, P)
    assert res.cycles == 100
    assert res.ipc == 1.0


def test_load_use_stall():
    stream = [Instr(Kind.LOAD, dst="a", srcs=("sp",)), alu("b", "a")]
    res, _ = simulate(stream, P)
    assert res.stall_cycles == P.load_use_penalty


def test_load_then_independent_no_stall():
    stream = [Instr(Kind.LOAD, dst="a", srcs=("sp",)), alu("b", "c")]
    res, _ = simulate(stream, P)
    assert res.stall_cycles == 0


def test_taken_branch_penalty():
    stream = [Instr(Kind.BRANCH, srcs=(), taken=True), alu("a")]
    res, _ = simulate(stream, P)
    assert res.flush_cycles == P.branch_penalty


def test_untaken_branch_free():
    stream = [Instr(Kind.BRANCH, srcs=(), taken=False), alu("a")]
    res, _ = simulate(stream, P)
    assert res.flush_cycles == 0


def test_fp_latency_exposed_on_dependent_fp():
    stream = [
        Instr(Kind.FMUL, dst="f0", srcs=("f1", "f2")),
        Instr(Kind.FADD, dst="f3", srcs=("f0", "f4")),
    ]
    res, _ = simulate(stream, P)
    assert res.stall_cycles == P.fp_latency - 1


def test_store_does_not_stall_on_data():
    """Store buffer: fsw right after fmul does not expose FP latency."""
    stream = [
        Instr(Kind.FMUL, dst="f0", srcs=("f1", "f2")),
        Instr(Kind.FSW, srcs=("f0", "addr")),
    ]
    res, _ = simulate(stream, P)
    assert res.stall_cycles == 0


def test_rfmac_back_to_back_no_stall():
    """Paper Fig. 2: APR forwarding in R_EX => consecutive rfmac at full rate."""
    stream = [Instr(Kind.RFMAC, srcs=("f1", "f2")) for _ in range(50)]
    res, _ = simulate(stream, P)
    assert res.stall_cycles == 0
    assert res.cycles == 50


def test_fmac_register_accumulator_would_stall():
    """Contrast: baseline fmac accumulating in a register exposes FP latency
    on every iteration — the RAW hazard the APR eliminates (paper §II-A)."""
    stream = [Instr(Kind.FMAC, dst="f5", srcs=("f5", "f1", "f2")) for _ in range(10)]
    res, _ = simulate(stream, P)
    assert res.stall_cycles == 9 * (P.fp_latency - 1)


def test_rfsmac_waits_for_inflight_rfmac():
    stream = [
        Instr(Kind.RFMAC, srcs=("f1", "f2")),
        Instr(Kind.RFSMAC, dst="f5"),
    ]
    res, _ = simulate(stream, P)
    # APR ready 2 cycles after the rfmac issues; rfsmac reads it in ID.
    assert res.stall_cycles == 1


def test_steady_state_matches_full_sim_small_loop():
    block = [
        Instr(Kind.LOAD, dst="a", srcs=("sp",)),
        alu("b", "a"),
        Instr(Kind.JUMP),
    ]
    cyc = steady_state_cycles(block, P)
    # full simulation of many reps divided by reps converges to the same rate
    stream = block * 300
    res, _ = simulate(stream, P)
    assert abs(res.cycles / 300 - cyc) < 0.1


def test_rented_pipeline_throughput_vs_baseline_chain():
    """One MAC/cycle through EX+R_EX vs one MAC/fp_latency for a register-
    accumulating fmac chain: the rented pipeline's throughput claim."""
    r_stream = [Instr(Kind.RFMAC, srcs=(f"a{i}", f"b{i}")) for i in range(64)]
    b_stream = [Instr(Kind.FMAC, dst="acc", srcs=("acc", f"a{i}", f"b{i}")) for i in range(64)]
    r_res, _ = simulate(r_stream, P)
    b_res, _ = simulate(b_stream, P)
    assert r_res.cycles == 64
    assert b_res.cycles > 64 * (P.fp_latency - 2)
