"""Sharding-rule unit tests: logical axes -> PartitionSpec, ZeRO-1 specs,
batch specs, cache specs — pure functions, no devices needed."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, input_specs
from repro.models import build_model
from repro.parallel.sharding import (ParallelContext, logical_to_spec,
                                     param_specs, zero1_spec)
from repro.train.step import cache_spec


class FakeMesh:
    """Just enough mesh for spec-level tests (no devices)."""
    def __init__(self, shape):
        self._shape = shape
        self.axis_names = tuple(shape)

    @property
    def shape(self):
        return self._shape


def ctx(pod=False):
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if pod
                    else {"data": 16, "model": 16})
    return ParallelContext(mesh=mesh, dp_axes=("pod", "data") if pod else ("data",))


def test_tp_axes_map_to_model():
    c = ctx()
    assert logical_to_spec(("embed", "ff"), c) == P(None, "model")
    assert logical_to_spec(("heads", "embed"), c) == P("model", None)
    assert logical_to_spec(("vocab", "embed"), c) == P("model", None)


def test_kv_heads_replicated_when_not_divisible():
    c = ctx()
    assert logical_to_spec(("embed", "kv_heads"), c, kv_heads=4) == P(None, None)
    assert logical_to_spec(("embed", "kv_heads"), c, kv_heads=32) == P(None, "model")


def test_experts_on_data_axis():
    c = ctx()
    assert logical_to_spec(("layers", "experts", "embed", "ff"), c) == \
        P(None, "data", None, "model")


def test_zero1_shards_first_free_divisible_dim():
    c = ctx()
    assert zero1_spec(P(None, "model"), (4096, 14336), c) == P("data", "model")
    # already data-sharded (experts): untouched
    assert zero1_spec(P("data", None), (128, 64), c) == P("data", None)
    # nothing divisible: untouched
    assert zero1_spec(P(None,), (31,), c) == P(None,)


def test_dp_degree_and_batch_spec():
    c = ctx(pod=True)
    assert c.dp_degree == 32
    assert c.batch_spec(extra_dims=1) == P(("pod", "data"), None)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_cover_every_leaf(arch):
    cfg = ARCHS[arch]
    bundle = build_model(cfg)
    logical = bundle.logical_axes()
    specs = param_specs(logical, ctx(), kv_heads=cfg.num_kv_heads)
    abstract = bundle.abstract_params()
    assert set(specs) == set(abstract)
    for name, spec in specs.items():
        shape = abstract[name].shape
        assert len(spec) <= len(shape), name
        # every sharded dim must divide the mesh axis size
        for dim, entry in zip(shape, list(spec)):
            if entry == "model":
                assert dim % 16 == 0, (arch, name, shape, spec)
            if entry == "data":
                assert dim % 16 == 0, (arch, name, shape, spec)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    cfg = ARCHS[arch]
    from repro.configs.shapes import applicable
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        pytest.skip("long_500k inapplicable (full attention)")
    c = ctx()
    specs = input_specs(cfg, shape)
    cspec = cache_spec(cfg, c, specs["cache"])
    for key, leaf in specs["cache"].items():
        sp = cspec[key]
        for dim, entry in zip(leaf.shape, list(sp)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= c.mesh.shape[a]
            assert dim % total == 0, (arch, shape_name, key, leaf.shape, sp)


def test_padded_vocab_divides_tp():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab % 16 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 256
