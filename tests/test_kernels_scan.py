"""rwkv6 + mamba2 chunked-scan kernels vs jnp-scan oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels.mamba2 import mamba2_ref, mamba2_ssd
from repro.kernels.rwkv6 import rwkv6_ref, rwkv6_wkv

TOL = dict(rtol=3e-4, atol=3e-4)


def rwkv_inputs(b, t, h, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, d)) * 0.5 for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, d))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    return r, k, v, w, u


@pytest.mark.parametrize("b,t,h,d", [(1, 64, 1, 8), (2, 128, 2, 16), (1, 96, 4, 32)])
def test_rwkv6_matches_ref(b, t, h, d):
    r, k, v, w, u = rwkv_inputs(b, t, h, d)
    out = rwkv6_wkv(r, k, v, w, u, chunk=32)
    ref = rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("chunk", [16, 32, 64, 128])
def test_rwkv6_chunk_invariance(chunk):
    r, k, v, w, u = rwkv_inputs(1, 128, 2, 8, seed=1)
    out = rwkv6_wkv(r, k, v, w, u, chunk=chunk)
    ref = rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@settings(max_examples=8, deadline=None)
@given(t=st.integers(8, 64), seed=st.integers(0, 50))
def test_rwkv6_property(t, seed):
    r, k, v, w, u = rwkv_inputs(1, t, 1, 8, seed=seed)
    out = rwkv6_wkv(r, k, v, w, u, chunk=16)
    ref = rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-3)


def mamba_inputs(b, t, h, p, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, t, h, p)) * 0.5
    bb = jax.random.normal(ks[1], (b, t, n)) * 0.5
    c = jax.random.normal(ks[2], (b, t, n)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, t, h)))
    a = -jnp.abs(jax.random.normal(ks[4], (h,))) - 0.1
    d = jnp.full((h,), 0.5)
    return x, bb, c, dt, a, d


@pytest.mark.parametrize("b,t,h,p,n", [(1, 64, 1, 8, 16), (2, 64, 2, 16, 16), (1, 32, 4, 8, 64)])
def test_mamba2_matches_ref(b, t, h, p, n):
    args = mamba_inputs(b, t, h, p, n)
    out = mamba2_ssd(*args, chunk=16)
    ref = mamba2_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_mamba2_chunk_invariance(chunk):
    args = mamba_inputs(1, 64, 2, 8, 16, seed=2)
    out = mamba2_ssd(*args, chunk=chunk)
    ref = mamba2_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_mamba2_decay_property():
    """With dt -> 0 the state never accumulates: y == D*x exactly."""
    x, bb, c, dt, a, d = mamba_inputs(1, 32, 1, 4, 8, seed=3)
    dt = jnp.zeros_like(dt)
    out = mamba2_ssd(x, bb, c, dt, a, d, chunk=16)
    expected = d[None, None, :, None] * x
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5)


def test_rwkv6_state_isolation_across_batch():
    """Changing batch row 1 must not change row 0's outputs (state is
    per-sequence — no APR leakage across grid cells)."""
    r, k, v, w, u = rwkv_inputs(2, 32, 1, 8, seed=4)
    out1 = rwkv6_wkv(r, k, v, w, u, chunk=16)
    r2 = r.at[1].set(r[1] * 2.0)
    out2 = rwkv6_wkv(r2, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]), rtol=1e-6, atol=1e-6)
    assert not np.allclose(np.asarray(out1[1]), np.asarray(out2[1]))
