"""Hypothesis compatibility shim: real hypothesis when installed, otherwise
a minimal deterministic fallback so the property tests still collect *and
run* (tier-1 must not depend on packages the image lacks).

With real hypothesis two profiles are registered:

* ``ci`` (default) — derandomized (fixed seed per test, so the gate job
  never flakes on a fresh failing example) with ``deadline=None`` (CI
  machines stall unpredictably);
* ``ci-random`` — fresh random exploration every run, for the non-blocking
  smoke job (``HYPOTHESIS_PROFILE=ci-random``); a failure there surfaces a
  new counterexample without breaking the gate.

Select with the ``HYPOTHESIS_PROFILE`` environment variable.

The fallback implements exactly the subset the suite uses:

* ``given(**kwargs)`` with keyword strategies — the wrapped test runs over a
  fixed number of pseudo-random draws from a seeded RNG (deterministic
  across runs, so failures are reproducible),
* ``settings(max_examples=..., deadline=...)`` — sets the number of draws,
  capped by the ``REPRO_HYP_MAX_EXAMPLES`` env var (default 50) so a test
  asking for hundreds of examples stays cheap locally; export a larger cap
  to run the full sweep without hypothesis installed,
* ``strategies.integers(lo, hi)`` / ``floats(lo, hi)`` / ``sampled_from(seq)``,
* ``REPRO_HYP_SEED=random`` randomizes the fallback RNG (the chosen seed is
  printed so a failure stays reproducible); any other value is used as the
  seed directly.

Usage in test modules::

    from _hyp import given, settings, st
"""
from __future__ import annotations

import os

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True

    settings.register_profile("ci", derandomize=True, deadline=None)
    settings.register_profile("ci-random", derandomize=False, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5   # default draws when a test sets no max_examples

    def _fallback_cap() -> int:
        return int(os.environ.get("REPRO_HYP_MAX_EXAMPLES", "50"))

    def _fallback_seed(default: str):
        """Deterministic per-test seed, unless REPRO_HYP_SEED overrides it
        (the value ``random`` draws — and prints — a fresh seed)."""
        env = os.environ.get("REPRO_HYP_SEED")
        if env is None:
            return default
        if env == "random":
            seed = random.SystemRandom().randrange(2 ** 32)
            print(f"_hyp fallback: REPRO_HYP_SEED=random -> seed {seed} "
                  f"(export REPRO_HYP_SEED={seed} to reproduce)")
            return seed
        return int(env)

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            choices = list(seq)
            return _Strategy(lambda rng: rng.choice(choices))

    st = _Strategies()

    def given(**strategies):
        def decorate(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                n = min(n, _fallback_cap())
                seed = _fallback_seed(f"{fn.__module__}.{fn.__name__}")
                rng = random.Random(seed)
                for _ in range(n):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return decorate

    def settings(max_examples=None, **_ignored):
        def decorate(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return decorate
