"""Hypothesis compatibility shim: real hypothesis when installed, otherwise
a minimal deterministic fallback so the property tests still collect *and
run* (tier-1 must not depend on packages the image lacks).

The fallback implements exactly the subset the suite uses:

* ``given(**kwargs)`` with keyword strategies — the wrapped test runs over a
  fixed number of pseudo-random draws from a seeded RNG (deterministic
  across runs, so failures are reproducible),
* ``settings(max_examples=..., deadline=...)`` — caps the number of draws,
* ``strategies.integers(lo, hi)`` / ``floats(lo, hi)`` / ``sampled_from(seq)``.

Usage in test modules::

    from _hyp import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5  # keep the deterministic sweep CI-cheap

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            choices = list(seq)
            return _Strategy(lambda rng: rng.choice(choices))

    st = _Strategies()

    def given(**strategies):
        def decorate(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                n = min(n, _FALLBACK_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return decorate

    def settings(max_examples=None, **_ignored):
        def decorate(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return decorate
