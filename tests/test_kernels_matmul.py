"""apr_matmul: shape/dtype sweeps + hypothesis properties vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.core.apr import reduction_hbm_traffic, traffic_reduction
from repro.kernels.apr_matmul import accumulator_traffic_bytes, apr_matmul, matmul_ref

TOL = dict(rtol=2e-4, atol=2e-4)


def rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),
    (128, 384, 256),
    (64, 128, 128),
    (8, 128, 128),
    (100, 300, 120),     # unaligned -> padding path
    (1, 128, 257),
    (130, 129, 131),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_apr_matmul_matches_ref(m, k, n, dtype):
    x, y = rand((m, k), dtype, 0), rand((k, n), dtype, 1)
    out = apr_matmul(x, y)
    ref = matmul_ref(x, y)
    tol = TOL if dtype == jnp.float32 else dict(rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol)


@pytest.mark.parametrize("residency", ["apr", "hbm"])
def test_residencies_agree(residency):
    x, y = rand((128, 512, ), jnp.float32, 2).reshape(128, 512), rand((512, 128), jnp.float32, 3)
    out = apr_matmul(x, y, residency=residency)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(x, y)), **TOL)


@pytest.mark.parametrize("blocks", [(128, 128, 128), (64, 128, 128), (128, 128, 256)])
def test_block_shape_sweep(blocks):
    bm, bn, bk = blocks
    x, y = rand((256, 512), jnp.float32, 4), rand((512, 256), jnp.float32, 5)
    out = apr_matmul(x, y, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(x, y)), **TOL)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96), k=st.integers(1, 160), n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_property_matches_oracle(m, k, n, seed):
    x, y = rand((m, k), jnp.float32, seed), rand((k, n), jnp.float32, seed + 1)
    out = apr_matmul(x, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(matmul_ref(x, y)),
                               rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(0.0, 8.0), m=st.integers(1, 64))
def test_property_linearity(scale, m):
    """Matmul is linear: (s*x) @ y == s * (x @ y) — an invariant the blocked
    APR accumulation must preserve."""
    x, y = rand((m, 128), jnp.float32, 7), rand((128, 64), jnp.float32, 8)
    lhs = apr_matmul(x * scale, y)
    rhs = apr_matmul(x, y) * scale
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-3, atol=1e-3)


class TestAccumulatorTraffic:
    """Level-B analogue of Table III's memory columns."""

    def test_apr_writes_once(self):
        assert reduction_hbm_traffic(100, 10, 2, "apr") == 200

    def test_hbm_scales_with_steps(self):
        assert reduction_hbm_traffic(100, 10, 2, "hbm") == 10 * 2 * 4 * 100 + 200

    def test_traffic_reduction_grows_with_k(self):
        r1 = traffic_reduction(128 * 128, 4)
        r2 = traffic_reduction(128 * 128, 64)
        assert 0 < r1 < r2 < 1

    def test_matmul_traffic_accounting(self):
        apr = accumulator_traffic_bytes(1024, 1024, 8192, 512, "apr")
        hbm = accumulator_traffic_bytes(1024, 1024, 8192, 512, "hbm")
        # 16 K-steps: baseline moves 16x8B per element vs 2B once.
        assert hbm / apr == (16 * 2 * 4 * 1024 * 1024 + apr) / apr
