"""repro.bench: config cache round-trip, the autotuner's correctness gate,
and a smoke sweep on a tiny matmul shape."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench import (BlockConfig, ConfigCache, all_specs, autotune,
                         get_spec, resolve_config, set_default_cache)
from repro.bench.registry import KernelSpec, TuneSpace
from repro.kernels.apr_matmul import apr_matmul, matmul_ref


@pytest.fixture
def cache(tmp_path):
    c = ConfigCache(tmp_path / "tune_cache.json")
    set_default_cache(c)
    yield c
    set_default_cache(None)  # restore the env-derived default for other tests


class TestBlockConfig:
    def test_make_is_order_insensitive_and_hashable(self):
        a = BlockConfig.make(block_m=64, block_k=128)
        b = BlockConfig.make(block_k=128, block_m=64)
        assert a == b and hash(a) == hash(b)
        assert a["block_m"] == 64 and a.get("missing") is None

    def test_replace_merges(self):
        a = BlockConfig.make(block_m=64, block_k=128)
        b = a.replace(block_m=256, chunk=32)
        assert b.to_dict() == {"block_m": 256, "block_k": 128, "chunk": 32}


class TestCacheRoundTrip:
    def test_write_read_same_config(self, cache, tmp_path):
        cfg = BlockConfig.make(block_m=64, block_n=128, block_k=256)
        cache.store("apr_matmul", "k128_m64_n64", "float32", "cpu", cfg,
                    metrics={"us": 12.5})
        # fresh object re-reads the JSON from disk
        reloaded = ConfigCache(tmp_path / "tune_cache.json")
        assert reloaded.lookup("apr_matmul", "k128_m64_n64", "float32",
                               "cpu") == cfg
        # miss on any key component
        assert reloaded.lookup("apr_matmul", "k128_m64_n64", "float32",
                               "tpu") is None
        raw = json.loads((tmp_path / "tune_cache.json").read_text())
        assert raw["version"] == 1
        entry = raw["entries"]["apr_matmul|k128_m64_n64|float32|cpu"]
        assert entry["config"] == cfg.to_dict()
        assert entry["metrics"]["us"] == 12.5

    def test_resolve_priority(self, cache):
        default = BlockConfig.make(block_m=128, block_n=128)
        args = ("apr_matmul", "key", "float32", "cpu")
        # nothing tuned: heuristic default wins
        assert resolve_config(*args, default=default)["block_m"] == 128
        # tuned entry overrides the default
        cache.store(*args, BlockConfig.make(block_m=64))
        assert resolve_config(*args, default=default)["block_m"] == 64
        # explicit caller kwarg beats the tuned entry
        got = resolve_config(*args, default=default,
                             explicit={"block_m": 256, "block_n": None})
        assert got["block_m"] == 256 and got["block_n"] == 128


def _broken_matmul_spec():
    """A spec where one candidate (block_m=13) computes wrong numbers."""
    base = get_spec("apr_matmul")

    def run(args, cfg, interpret):
        out = apr_matmul(*args, interpret=interpret)
        if cfg["block_m"] == 13:
            out = out + 1.0  # deliberately-wrong candidate
        return out

    return KernelSpec(
        name="broken_matmul",
        make_inputs=base.make_inputs,
        run=run,
        ref=lambda args: matmul_ref(*args),
        tune_space=lambda shape: TuneSpace.make(block_m=(13, 128)),
        default_config=base.default_config,
        shape_key=base.shape_key,
        flops=base.flops,
        hbm_bytes=lambda shape, cfg: 0,
        rtol=1e-4, atol=1e-4,
    )


class TestCorrectnessGate:
    def test_rejects_wrong_candidate(self, cache):
        spec = _broken_matmul_spec()
        res = autotune(spec, {"m": 16, "k": 32, "n": 16}, cache=cache,
                       iters=1, warmup=0)
        assert res.ok
        assert res.config["block_m"] == 128       # wrong candidate excluded
        assert len(res.rejected) == 1
        bad_cfg, reason = res.rejected[0]
        assert bad_cfg["block_m"] == 13 and "err" in reason
        # the wrong config never lands in the cache
        stored = cache.lookup("broken_matmul", res.shape_key, "float32",
                              res.backend)
        assert stored is not None and stored["block_m"] == 128


class TestSmokeSweep:
    def test_tiny_matmul_sweep_and_cache_pickup(self, cache):
        spec = get_spec("apr_matmul")
        shape = {"m": 16, "k": 64, "n": 16}
        res = autotune(spec, shape, cache=cache, max_candidates=2,
                       iters=1, warmup=0)
        assert res.ok and res.n_candidates == 2
        assert res.us > 0 and res.gflops > 0 and res.hbm_bytes > 0
        # the public wrapper now resolves the tuned winner for this shape
        x = jnp.ones((16, 64), jnp.float32)
        y = jnp.ones((64, 16), jnp.float32)
        np.testing.assert_allclose(np.asarray(apr_matmul(x, y)),
                                   np.asarray(matmul_ref(x, y)),
                                   rtol=1e-5, atol=1e-5)

    def test_all_families_registered(self):
        assert set(all_specs()) >= {"apr_matmul", "apr_matmul_fused",
                                    "apr_conv", "apr_conv_fused",
                                    "flash_decode", "flash_decode_paged",
                                    "mamba2", "rwkv6", "quant_matmul",
                                    "quant_matmul_fused"}
        # every family produces at least one candidate for its quick shape
        quick = {
            "apr_matmul": {"m": 16, "k": 64, "n": 16},
            "apr_matmul_fused": {"m": 16, "k": 64, "n": 16},
            "quant_matmul_fused": {"m": 16, "k": 64, "n": 16},
            "apr_conv": {"b": 1, "h": 6, "w": 6, "c": 2, "hf": 3, "wf": 3,
                         "m": 4, "stride": 1, "padding": 1},
            "apr_conv_fused": {"b": 1, "h": 6, "w": 6, "c": 2, "hf": 3,
                               "wf": 3, "m": 4, "stride": 1, "padding": 1},
            "flash_decode": {"b": 1, "hq": 2, "hkv": 1, "d": 16, "s": 64},
            "flash_decode_paged": {"b": 1, "hq": 2, "hkv": 1, "d": 16,
                                   "pages": 2, "ps": 32},
            "mamba2": {"b": 1, "t": 32, "h": 1, "p": 4, "n": 4},
            "rwkv6": {"b": 1, "t": 32, "h": 1, "d": 4},
        }
        for name, shape in quick.items():
            assert all_specs()[name].candidates(shape), name

    def test_paged_decode_sweep_validates_and_caches(self, cache):
        """flash_decode_paged autotunes like the other families: candidates
        are gated against the gather-then-attend oracle and the winner lands
        in the shared cache under its own family name."""
        spec = get_spec("flash_decode_paged")
        shape = {"b": 2, "hq": 4, "hkv": 2, "d": 16, "pages": 2, "ps": 32}
        res = autotune(spec, shape, cache=cache, iters=1, warmup=0)
        assert res.ok and not res.rejected
        assert shape["ps"] % res.config["chunk"] == 0
        assert cache.lookup("flash_decode_paged", res.shape_key, "float32",
                            res.backend) == res.config


class TestScopedCache:
    def test_scoped_cache_nests_and_restores(self, tmp_path):
        """resolve_config consults the innermost scoped cache, then falls
        back to the process default when no scope is active."""
        from repro.bench import scoped_cache

        key = ("apr_matmul", "scopekey", "float32", "cpu")
        default = BlockConfig.make(block_m=512)
        inner = ConfigCache(tmp_path / "inner.json")
        inner.store(*key, BlockConfig.make(block_m=64))
        outer = ConfigCache(tmp_path / "outer.json")
        outer.store(*key, BlockConfig.make(block_m=128))
        assert resolve_config(*key, default=default)["block_m"] == 512
        with scoped_cache(outer):
            assert resolve_config(*key, default=default)["block_m"] == 128
            with scoped_cache(inner):
                assert resolve_config(*key, default=default)["block_m"] == 64
            assert resolve_config(*key, default=default)["block_m"] == 128
        assert resolve_config(*key, default=default)["block_m"] == 512
        # scoped_cache(None) is a no-op wrapper (engines without an
        # explicit tune_cache path)
        with scoped_cache(None):
            assert resolve_config(*key, default=default)["block_m"] == 512


def test_two_engine_tune_caches_coexist(tmp_path):
    """Regression for the PR-2 ``set_default_cache`` last-engine-wins
    footgun: an engine's ``tune_cache`` is now scoped to that engine, so
    two engines with different tuned profiles (here: different dtypes'
    winners for the same decode shape) resolve independently — the second
    engine's construction must not redirect the first engine's kernels."""
    from repro.bench.config import active_cache, default_cache
    from repro.bench.config import scoped_cache as scope
    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel import ParallelContext
    from repro.serve import ServeEngine

    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    backend = __import__("jax").default_backend()
    a_path, b_path = tmp_path / "bf16.json", tmp_path / "f32.json"
    a = ConfigCache(a_path)
    b = ConfigCache(b_path)
    eng_a = ServeEngine(bundle, None, ParallelContext(None),
                        tune_cache=str(a_path))
    eng_b = ServeEngine(bundle, None, ParallelContext(None),
                        tune_cache=str(b_path))
    # one decode shape, two engines tuned at different dtypes
    key_shape = ("flash_decode", "anyshape")
    eng_a.tune_cache.store(*key_shape, "bfloat16", backend,
                           BlockConfig.make(chunk=64))
    eng_b.tune_cache.store(*key_shape, "float32", backend,
                           BlockConfig.make(chunk=128))
    default = BlockConfig.make(chunk=512)
    # each engine's scope resolves its own winner...
    with scope(eng_a.tune_cache):
        assert active_cache() is eng_a.tune_cache
        got = resolve_config(*key_shape, "bfloat16", backend, default=default)
        assert got["chunk"] == 64
        # ...and misses the other engine's dtype entirely (no bleed)
        got = resolve_config(*key_shape, "float32", backend, default=default)
        assert got["chunk"] == 512
    with scope(eng_b.tune_cache):
        got = resolve_config(*key_shape, "float32", backend, default=default)
        assert got["chunk"] == 128
    # constructing engine B never touched the process-wide default
    assert default_cache().lookup(*key_shape, "bfloat16", backend) is None
    assert default_cache().lookup(*key_shape, "float32", backend) is None
