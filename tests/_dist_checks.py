"""Multi-device correctness checks (run in a subprocess with 8 host devices;
invoked by test_distributed.py).  Each check prints 'CHECK <name> OK'."""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    "--xla_disable_hlo_passes=all-reduce-promotion")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.parallel.compat import set_mesh
from repro.checkpoint import CheckpointManager
from repro.models import build_model
from repro.models.moe import moe_block
from repro.parallel.compression import (compressed_value_and_grad,
                                        dequantize_int8, quantize_int8)
from repro.parallel.pipeline_parallel import pipeline_apply
from repro.parallel.sharding import make_context
from repro.runtime.elastic import build_mesh, remesh_restore
from repro.train.step import TrainHyper, assemble_shardings, init_optimizer, make_train_step


def check_moe_ep_matches_local():
    """MoE with shard_map all-to-all EP == single-device routing math."""
    mesh = build_mesh(8, model_parallel=2)
    pctx = make_context(mesh)
    cfg = get_config("arctic-480b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    moe_p = {k[len("blk.0."):]: v[0] for k, v in params.items()
             if k.startswith("blk.0.moe")}
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)

    local = moe_block(moe_p, "moe", cfg, x, None)
    with set_mesh(mesh):
        xs = jax.device_put(x, NamedSharding(mesh, P(("data",), None, None)))
        dist = jax.jit(lambda p, v: moe_block(p, "moe", cfg, v, pctx))(moe_p, xs)
    err = float(jnp.max(jnp.abs(local.astype(jnp.float32) - dist.astype(jnp.float32))))
    # identical math up to all-to-all reordering of bf16 adds
    assert err < 0.15, f"moe mismatch {err}"
    # token conservation: mean outputs comparable
    assert abs(float(local.mean()) - float(dist.mean())) < 1e-2
    print("CHECK moe_ep OK", err)


def check_pipeline_parallel():
    """4-stage GPipe == sequential layer application, fwd and grad."""
    mesh = jax.make_mesh((4,), ("stage",))
    L, M, mb, d = 4, 6, 3, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (L, d, d), jnp.float32) * 0.3

    def layer(w, x):
        return jnp.tanh(x @ w)

    xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d), jnp.float32)
    out_pp = pipeline_apply(layer, ws, xs, mesh)
    ref = xs
    for i in range(L):
        ref = jax.vmap(lambda x: layer(ws[i], x))(ref)
    np.testing.assert_allclose(np.asarray(out_pp), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    # gradients flow through ppermute
    def loss_pp(ws):
        return jnp.sum(pipeline_apply(layer, ws, xs, mesh) ** 2)

    def loss_ref(ws):
        r = xs
        for i in range(L):
            r = layer(ws[i], r)
        return jnp.sum(r ** 2)

    g_pp = jax.grad(loss_pp)(ws)
    g_ref = jax.grad(loss_ref)(ws)
    np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)
    print("CHECK pipeline_parallel OK")


def check_compression():
    """int8 quant roundtrip error bound + compressed cross-pod grads close
    to exact grads."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s, x.shape) - x))
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 127 + 1e-6

    mesh = build_mesh(8, model_parallel=2, pods=2)
    pctx = make_context(mesh)
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 255),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 255),
    }
    from repro.train.step import _loss_fn
    import functools
    loss_fn = functools.partial(_loss_fn, bundle, pctx)
    with set_mesh(mesh):
        l_exact, g_exact = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
        l_c, g_c = jax.jit(lambda p, b: compressed_value_and_grad(
            loss_fn, p, b, pctx, enabled=True))(params, batch)
    assert abs(float(l_exact) - float(l_c)) < 1e-2
    rel = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
           / (float(jnp.max(jnp.abs(a.astype(jnp.float32)))) + 1e-9)
           for a, b in zip(jax.tree.leaves(g_exact), jax.tree.leaves(g_c))]
    assert max(rel) < 0.15, f"compressed grads too far: {max(rel)}"
    print("CHECK compression OK", max(rel))


def check_elastic_remesh():
    """Train 3 steps on 8 devices, checkpoint, restore onto 4 devices and
    continue — losses keep decreasing."""
    import tempfile
    cfg = get_config("llama3-8b", smoke=True)
    bundle = build_model(cfg)
    shape = ShapeSpec("t", 32, 8, "train")

    def setup(mesh):
        pctx = make_context(mesh)
        params = bundle.init_params(jax.random.PRNGKey(0))
        opt = init_optimizer(cfg, params)
        pspecs, opt_fn, _ = assemble_shardings(bundle, pctx)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        osh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_fn(opt),
                           is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, psh)
        opt = jax.tree.map(jax.device_put, opt, osh)
        step = jax.jit(make_train_step(bundle, pctx, TrainHyper(peak_lr=1e-2, warmup=1)))
        return params, opt, step, (pspecs, opt_fn)

    from repro.data import SyntheticLMSource
    src = SyntheticLMSource(cfg, shape)

    mesh8 = build_mesh(8, model_parallel=2)
    params, opt, step, (pspecs, opt_fn) = setup(mesh8)
    losses = []
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        params, opt, m = step(params, opt, batch, jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        ckpt.save(3, (params, opt), metadata={"cursor": {"step": 3, "seed": 0}})

        mesh4 = build_mesh(4, model_parallel=2)
        pctx4 = make_context(mesh4)
        opt_abs = jax.eval_shape(lambda p: init_optimizer(cfg, p),
                                 bundle.abstract_params())
        spec_tree = (pspecs, opt_fn(opt_abs))
        (params4, opt4), meta, pctx4 = remesh_restore(
            ckpt, (params, opt), spec_tree, mesh4)
        assert meta["cursor"]["step"] == 3
        step4 = jax.jit(make_train_step(bundle, pctx4, TrainHyper(peak_lr=1e-2, warmup=1)))
        for i in range(3, 6):
            batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
            params4, opt4, m = step4(params4, opt4, batch, jnp.asarray(i, jnp.int32))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    print("CHECK elastic_remesh OK", [round(l, 3) for l in losses])


CHECKS = {
    "moe_ep": check_moe_ep_matches_local,
    "pipeline_parallel": check_pipeline_parallel,
    "compression": check_compression,
    "elastic_remesh": check_elastic_remesh,
}

if __name__ == "__main__":
    names = sys.argv[1:] or list(CHECKS)
    for n in names:
        CHECKS[n]()
    print("ALL DIST CHECKS OK")
