#!/usr/bin/env python
"""Docs hygiene checker (run by the CI docs job and locally):

1. every intra-repo link in tracked markdown files resolves to an existing
   file (anchors are stripped; external http(s)/mailto links are skipped);
2. every ``src/repro/<package>`` is mentioned by name somewhere in README.md
   or docs/ — new subsystems must at least be placed on the repo map;
3. every Pallas kernel family (``src/repro/kernels/<family>``) is mentioned
   by name in README.md or docs/ — a new family must at least appear on the
   family list (and should earn a row in docs/paper_mapping.md);
4. every ``BENCH_*.json`` report at the repo root has its schema documented
   in benchmarks/README.md (mentioned by filename) — a new benchmark driver
   must document what it emits;
5. every ``src/repro/<package>`` is mentioned in docs/architecture.md
   specifically — the architecture map is the doc entry point and must not
   silently fall behind the package tree;
6. every fusion pass registered in ``src/repro/graph/passes.py`` (statically
   greppable ``@fusion_pass("name")`` decorators — this job runs without
   jax installed) is named in docs/graph.md — a new pass must at least be
   listed in the compiler guide;
7. every hardware profile registered in ``src/repro/roofline/hw.py``
   (statically greppable ``register_profile(HardwareProfile(name="..."``
   blocks) is named in docs/cost_model.md — a new chip must at least
   appear in the profile table;
8. the v2 ``BENCH_kernels.json`` cost-model fields (``predicted_us``,
   ``pruned_from``, ``spread_us``, ``prediction_error``, ``pruning_gate``)
   are described in benchmarks/README.md — the schema doc must not fall
   behind what the driver emits.

Exit code 0 = clean; 1 = problems (each printed on its own line).

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excludes images (![), captures the target up to ) or #
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def markdown_files():
    skip_dirs = {".git", ".github", "node_modules", "__pycache__"}
    for p in sorted(REPO.rglob("*.md")):
        if not any(part in skip_dirs for part in p.parts):
            yield p


def check_links() -> list:
    problems = []
    for md in markdown_files():
        for m in _LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or not target:
                continue
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                rel = md.relative_to(REPO)
                problems.append(f"{rel}: broken intra-repo link -> {target}")
    return problems


def _docs_text() -> str:
    text = (REPO / "README.md").read_text(encoding="utf-8")
    for md in sorted((REPO / "docs").glob("*.md")):
        text += md.read_text(encoding="utf-8")
    return text


def check_package_mentions() -> list:
    docs_text = _docs_text()
    problems = []
    for pkg in sorted(p for p in (REPO / "src" / "repro").iterdir()
                      if p.is_dir() and (p / "__init__.py").exists()):
        # a mention is the package name used as a path or module component
        pattern = re.compile(
            rf"(?:src/repro/|repro[./]){re.escape(pkg.name)}\b")
        if not pattern.search(docs_text):
            problems.append(
                f"src/repro/{pkg.name}: not mentioned in README.md or docs/ "
                "(add it to the repo map)")
    return problems


def check_kernel_family_mentions() -> list:
    docs_text = _docs_text()
    problems = []
    kernels = REPO / "src" / "repro" / "kernels"
    for fam in sorted(p for p in kernels.iterdir()
                      if p.is_dir() and (p / "__init__.py").exists()):
        # families are referred to by bare name (`apr_matmul`) or as a path
        if not re.search(rf"\b{re.escape(fam.name)}\b", docs_text):
            problems.append(
                f"src/repro/kernels/{fam.name}: family not mentioned in "
                "README.md or docs/ (add it to the kernel family list)")
    return problems


def check_bench_schema_docs() -> list:
    """Every repo-root BENCH_*.json must be named in benchmarks/README.md
    (where the schemas live)."""
    readme = REPO / "benchmarks" / "README.md"
    text = readme.read_text(encoding="utf-8") if readme.exists() else ""
    problems = []
    for report in sorted(REPO.glob("BENCH_*.json")):
        if report.name not in text:
            problems.append(
                f"{report.name}: schema not documented in "
                "benchmarks/README.md (mention the file and describe its "
                "fields)")
    return problems


def check_architecture_coverage() -> list:
    """docs/architecture.md is the doc entry point: every top-level
    src/repro package must be on its map."""
    arch = REPO / "docs" / "architecture.md"
    if not arch.exists():
        return ["docs/architecture.md: missing (it is the doc entry point; "
                "see README 'Project docs')"]
    text = arch.read_text(encoding="utf-8")
    problems = []
    for pkg in sorted(p for p in (REPO / "src" / "repro").iterdir()
                      if p.is_dir() and (p / "__init__.py").exists()):
        pattern = re.compile(
            rf"(?:src/repro/|repro[./]){re.escape(pkg.name)}\b")
        if not pattern.search(text):
            problems.append(
                f"src/repro/{pkg.name}: not on the docs/architecture.md "
                "map (add it to the dataflow section)")
    return problems


_FUSION_PASS = re.compile(r"@fusion_pass\(\s*[\"']([\w-]+)[\"']\s*\)")


def check_fusion_pass_docs() -> list:
    """Every registered fusion pass must be documented in docs/graph.md.
    Registrations are greppable by design (literal ``@fusion_pass("name")``
    decorators) so this check needs no jax import."""
    passes_py = REPO / "src" / "repro" / "graph" / "passes.py"
    if not passes_py.exists():
        return []
    names = _FUSION_PASS.findall(passes_py.read_text(encoding="utf-8"))
    guide = REPO / "docs" / "graph.md"
    if not guide.exists():
        return ["docs/graph.md: missing (the graph-compiler guide must "
                "document every registered fusion pass)"]
    text = guide.read_text(encoding="utf-8")
    return [f"src/repro/graph/passes.py: fusion pass `{name}` not "
            "documented in docs/graph.md"
            for name in names if not re.search(rf"\b{re.escape(name)}\b", text)]


_PROFILE_REG = re.compile(
    r"register_profile\(HardwareProfile\(\s*name=[\"']([\w-]+)[\"']")


def check_hw_profile_docs() -> list:
    """Every registered hardware profile must be in docs/cost_model.md's
    profile table.  Registrations are greppable by design (literal
    ``register_profile(HardwareProfile(name="..."`` blocks in hw.py)."""
    hw_py = REPO / "src" / "repro" / "roofline" / "hw.py"
    if not hw_py.exists():
        return []
    names = _PROFILE_REG.findall(hw_py.read_text(encoding="utf-8"))
    guide = REPO / "docs" / "cost_model.md"
    if not guide.exists():
        return ["docs/cost_model.md: missing (the cost-model guide must "
                "document every registered hardware profile)"]
    text = guide.read_text(encoding="utf-8")
    return [f"src/repro/roofline/hw.py: hardware profile `{name}` not "
            "documented in docs/cost_model.md"
            for name in names
            if not re.search(rf"\b{re.escape(name)}\b", text)]


#: fields the v2 BENCH_kernels.json schema added for the cost model
_BENCH_V2_FIELDS = ("predicted_us", "pruned_from", "spread_us",
                    "prediction_error", "pruning_gate")


def check_bench_v2_fields() -> list:
    """benchmarks/README.md must describe the cost-model fields the v2
    kernel report emits."""
    readme = REPO / "benchmarks" / "README.md"
    text = readme.read_text(encoding="utf-8") if readme.exists() else ""
    return [f"benchmarks/README.md: v2 BENCH_kernels.json field "
            f"`{field}` not documented"
            for field in _BENCH_V2_FIELDS if field not in text]


def main() -> int:
    problems = (check_links() + check_package_mentions()
                + check_kernel_family_mentions() + check_bench_schema_docs()
                + check_architecture_coverage() + check_fusion_pass_docs()
                + check_hw_profile_docs() + check_bench_v2_fields())
    for p in problems:
        print(p)
    if problems:
        print(f"\n{len(problems)} docs problem(s)", file=sys.stderr)
        return 1
    n_md = len(list(markdown_files()))
    print(f"docs OK ({n_md} markdown files, all intra-repo links resolve, "
          "all src/repro packages + kernel families documented, all "
          "BENCH_*.json schemas described, architecture map complete, "
          "all fusion passes in docs/graph.md, all hardware profiles + "
          "v2 bench fields in the cost-model docs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
