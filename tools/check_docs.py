#!/usr/bin/env python
"""Docs hygiene checker (run by the CI docs job and locally):

1. every intra-repo link in tracked markdown files resolves to an existing
   file (anchors are stripped; external http(s)/mailto links are skipped);
2. every ``src/repro/<package>`` is mentioned by name somewhere in README.md
   or docs/ — new subsystems must at least be placed on the repo map;
3. every Pallas kernel family (``src/repro/kernels/<family>``) is mentioned
   by name in README.md or docs/ — a new family must at least appear on the
   family list (and should earn a row in docs/paper_mapping.md).

Exit code 0 = clean; 1 = problems (each printed on its own line).

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — excludes images (![), captures the target up to ) or #
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def markdown_files():
    skip_dirs = {".git", ".github", "node_modules", "__pycache__"}
    for p in sorted(REPO.rglob("*.md")):
        if not any(part in skip_dirs for part in p.parts):
            yield p


def check_links() -> list:
    problems = []
    for md in markdown_files():
        for m in _LINK.finditer(md.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or not target:
                continue
            resolved = (md.parent / target).resolve()
            if not resolved.exists():
                rel = md.relative_to(REPO)
                problems.append(f"{rel}: broken intra-repo link -> {target}")
    return problems


def _docs_text() -> str:
    text = (REPO / "README.md").read_text(encoding="utf-8")
    for md in sorted((REPO / "docs").glob("*.md")):
        text += md.read_text(encoding="utf-8")
    return text


def check_package_mentions() -> list:
    docs_text = _docs_text()
    problems = []
    for pkg in sorted(p for p in (REPO / "src" / "repro").iterdir()
                      if p.is_dir() and (p / "__init__.py").exists()):
        # a mention is the package name used as a path or module component
        pattern = re.compile(
            rf"(?:src/repro/|repro[./]){re.escape(pkg.name)}\b")
        if not pattern.search(docs_text):
            problems.append(
                f"src/repro/{pkg.name}: not mentioned in README.md or docs/ "
                "(add it to the repo map)")
    return problems


def check_kernel_family_mentions() -> list:
    docs_text = _docs_text()
    problems = []
    kernels = REPO / "src" / "repro" / "kernels"
    for fam in sorted(p for p in kernels.iterdir()
                      if p.is_dir() and (p / "__init__.py").exists()):
        # families are referred to by bare name (`apr_matmul`) or as a path
        if not re.search(rf"\b{re.escape(fam.name)}\b", docs_text):
            problems.append(
                f"src/repro/kernels/{fam.name}: family not mentioned in "
                "README.md or docs/ (add it to the kernel family list)")
    return problems


def main() -> int:
    problems = (check_links() + check_package_mentions()
                + check_kernel_family_mentions())
    for p in problems:
        print(p)
    if problems:
        print(f"\n{len(problems)} docs problem(s)", file=sys.stderr)
        return 1
    n_md = len(list(markdown_files()))
    print(f"docs OK ({n_md} markdown files, all intra-repo links resolve, "
          "all src/repro packages + kernel families documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
