#!/usr/bin/env python
"""Example smoke runner (CI step + local sanity check).

Runs the flagship examples as subprocesses with ``PYTHONPATH=src`` and
fails if any exits non-zero.  ``--quick`` passes each example its reduced
CI arguments (few training steps, LeNet-only demo) so the whole sweep
stays within a couple of minutes on CPU — the point is that the examples
*run*, not that they converge.

    python tools/run_examples.py --quick
    python tools/run_examples.py              # full-size examples
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: example -> (full args, --quick args)
EXAMPLES = {
    "examples/quickstart.py": ([], ["--steps", "6"]),
    "examples/edge_inference.py": ([], ["--quick"]),
}


def run_example(script: str, args: list, timeout: int) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, str(REPO / script)] + args
    print(f"$ {' '.join(cmd)}")
    t0 = time.time()
    proc = subprocess.run(cmd, env=env, timeout=timeout)
    print(f"-> exit {proc.returncode} in {time.time() - t0:.1f}s\n")
    return proc.returncode


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized arguments per example")
    ap.add_argument("--timeout", type=int, default=600,
                    help="per-example timeout in seconds")
    args = ap.parse_args()

    failures = []
    for script, (full, quick) in EXAMPLES.items():
        rc = run_example(script, quick if args.quick else full, args.timeout)
        if rc != 0:
            failures.append((script, rc))
    if failures:
        for script, rc in failures:
            print(f"FAIL: {script} exited {rc}", file=sys.stderr)
        return 1
    print(f"examples OK ({len(EXAMPLES)} ran)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
