"""End-to-end fault-tolerant training driver on a ~100M-param llama-family
model (CPU-sized by default; --m100 selects the full ~100M config).

Exercises the whole substrate: data pipeline w/ prefetch + cursor,
microbatched train step, AdamW(ZeRO-spec'd), async checkpointing, heartbeat,
straggler detection, restart-on-failure (inject one fault to prove it).

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --m100 --steps 300   # ~100M
"""
import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.data import DataCursor, Prefetcher, SyntheticLMSource
from repro.models import build_model
from repro.parallel.sharding import ParallelContext
from repro.checkpoint import CheckpointManager
from repro.runtime import FaultInjector, TrainController
from repro.train.step import TrainHyper, init_optimizer, make_train_step


def config(m100: bool) -> ModelConfig:
    base = get_config("llama3-8b", smoke=True)
    if not m100:
        return dataclasses.replace(base, num_layers=4, d_model=128, d_ff=512,
                                   num_heads=4, num_kv_heads=2, head_dim=32,
                                   vocab_size=2048, name="lm-8m")
    return dataclasses.replace(
        base, num_layers=8, d_model=768, d_ff=3072, num_heads=12,
        num_kv_heads=4, head_dim=64, vocab_size=32768, name="lm-100m")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--m100", action="store_true")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-fault", type=int, default=-1)
    args = ap.parse_args()

    cfg = config(args.m100)
    bundle = build_model(cfg)
    pctx = ParallelContext(None)
    n_params = sum(int(jnp.size(p)) for p in bundle.init_params(jax.random.PRNGKey(0)).values())
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    params = bundle.init_params(jax.random.PRNGKey(0))
    opt = init_optimizer(cfg, params)
    hyper = TrainHyper(peak_lr=3e-3, warmup=20, total_steps=args.steps,
                       num_microbatches=2)
    train_step = jax.jit(make_train_step(bundle, pctx, hyper))

    def step_fn(state, batch, step):
        p, o = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, metrics = train_step(p, o, batch, jnp.asarray(step, jnp.int32))
        return (p, o), metrics

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_lm_")
    ckpt = CheckpointManager(ckpt_dir)
    shape = ShapeSpec("train_lm", args.seq, args.batch, "train")
    source = SyntheticLMSource(cfg, shape)
    injector = None
    if args.inject_fault >= 0:
        injector = FaultInjector(fail_steps=(args.inject_fault,))
    controller = TrainController(
        step_fn, ckpt, ckpt_every=40, max_retries=0, injector=injector,
        heartbeat_path=os.path.join(ckpt_dir, "heartbeat.json"),
        on_straggle=lambda s, dt: print(f"  [straggler] step {s}: {dt:.2f}s"))

    state, report = controller.run((params, opt), source, DataCursor(),
                                   args.steps)
    first, last = report.losses[0], report.losses[-1]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(restarts={report.restarts})")
    assert last < first, "loss must decrease"
    print(f"checkpoints in {ckpt_dir}: steps {ckpt.all_steps()}")


if __name__ == "__main__":
    main()
