"""The paper's scenario, both levels at once.

Level B: run LeNet-5 / ResNet-20 / MobileNet-V1 inference through the
``repro.graph`` compiler — each forward is traced to an op graph, the
``repro.cost`` model picks the fusion schedule (each APR fusion pass kept
on a predicted traffic win; the per-pass audit is printed), and the fused
executor computes the logits; checked against the direct XLA forward,
with the planner's intermediate-HBM-bytes reduction printed per network.  For LeNet the conv reductions are additionally
cross-checked on the APR-resident Pallas kernel (interpret mode on CPU).

Level A: for the same three networks, print the reproduced Table III —
RV64F vs Baseline vs RV64R on the modelled 5-stage edge core.

    PYTHONPATH=src python examples/edge_inference.py [--quick] [--skip-pallas]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.isa import Isa
from repro.core.simulate import enhancement, simulate_model
from repro.cost import plan_graph
from repro.graph import GraphExecutor, memory_report, trace
from repro.models.cnn import CNNS


def run_level_b(skip_pallas: bool, quick: bool):
    print("=== Level B: CNN inference through the repro.graph compiler ===")
    names = ["lenet"] if quick else list(CNNS)
    for name in names:
        spec = CNNS[name]
        params = spec["params"](jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2,) + spec["input"])
        fwd = lambda xx: spec["forward"](params, xx, conv_impl="xla")
        t0 = time.time()
        logits_xla = fwd(x)
        t_xla = time.time() - t0

        # graph path: trace -> cost-driven fusion schedule -> fused executor
        graph = trace(fwd, x, name=name)
        schedule = plan_graph(graph, use_cache=False)
        unfused = memory_report(trace(fwd, x, name=name))
        fused = memory_report(graph)
        ex = GraphExecutor(graph)
        t0 = time.time()
        logits_graph = ex(x)
        t_graph = time.time() - t0
        err = float(jnp.max(jnp.abs(logits_graph - logits_xla)))
        assert err < 1e-3, (name, err)
        s = graph.summary()
        line = (f"{name:13s} logits {logits_xla.shape} "
                f"pred {np.asarray(jnp.argmax(logits_graph, -1))} "
                f"xla {t_xla*1e3:7.1f}ms graph {t_graph*1e3:7.1f}ms "
                f"maxerr {err:.2e}")
        print(line)
        print(f"{'':13s} fusion: {s['n_primitive_ops']} ops -> "
              f"{s['n_nodes']} clusters ({s['n_fused']} fused); "
              f"intermediate HBM bytes {unfused.intermediate_bytes} -> "
              f"{fused.intermediate_bytes} "
              f"({unfused.intermediate_bytes / max(fused.intermediate_bytes, 1):.2f}x)")
        # the cost model's whole-graph schedule audit (docs/cost_model.md)
        print("\n".join(f"{'':13s} {ln}"
                        for ln in schedule.report().splitlines()))
        if not skip_pallas and name == "lenet":  # interpret mode is slow; one net
            t0 = time.time()
            logits_apr = spec["forward"](params, x, conv_impl="pallas")
            t_apr = time.time() - t0
            err = float(jnp.max(jnp.abs(logits_apr - logits_xla)))
            print(f"{'':13s} apr-kernel {t_apr*1e3:7.1f}ms (interpret)  "
                  f"maxerr {err:.2e}")
            assert err < 1e-3


def run_level_a(quick: bool):
    print("\n=== Level A: reproduced Table III (per model) ===")
    hdr = f"{'model':13s} {'ISA':9s} {'runtime':>9s} {'IC':>13s} {'IPC':>6s} {'mem':>13s} {'L1':>13s}"
    print(hdr)
    models = ("lenet",) if quick else ("lenet", "resnet20", "mobilenet_v1")
    for model in models:
        rows = {isa: simulate_model(model, isa) for isa in Isa}
        for isa, m in rows.items():
            print(f"{model:13s} {isa.pretty:9s} {m.runtime_s:8.3f}s "
                  f"{m.instructions:13,} {m.ipc:6.3f} {m.mem_instrs:13,} "
                  f"{m.l1_accesses:13,}")
        e = enhancement(rows[Isa.RV64F], rows[Isa.RV64R])
        print(f"{'':13s} RV64R over RV64F: runtime -{e['runtime']:.1f}%  "
              f"IC -{e['IC']:.1f}%  IPC +{e['IPC']:.1f}%  mem -{e['mem_instrs']:.1f}%")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-pallas", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: LeNet only, no Pallas interpret pass")
    args = ap.parse_args()
    run_level_b(args.skip_pallas or args.quick, args.quick)
    run_level_a(args.quick)


if __name__ == "__main__":
    main()
