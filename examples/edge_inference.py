"""The paper's scenario, both levels at once.

Level B: run LeNet-5 / ResNet-20 / MobileNet-V1 inference in JAX with the
convolution reductions on the APR-resident Pallas kernel (interpret mode on
CPU), checked against the XLA conv path.

Level A: for the same three networks, print the reproduced Table III —
RV64F vs Baseline vs RV64R on the modelled 5-stage edge core.

    PYTHONPATH=src python examples/edge_inference.py [--skip-pallas]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.isa import Isa
from repro.core.simulate import enhancement, simulate_model
from repro.models.cnn import CNNS


def run_level_b(skip_pallas: bool):
    print("=== Level B: CNN inference on APR kernels ===")
    for name, spec in CNNS.items():
        params = spec["params"](jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2,) + spec["input"])
        t0 = time.time()
        logits_xla = spec["forward"](params, x, conv_impl="xla")
        t_xla = time.time() - t0
        line = (f"{name:13s} logits {logits_xla.shape} "
                f"pred {np.asarray(jnp.argmax(logits_xla, -1))} "
                f"xla {t_xla*1e3:7.1f}ms")
        if not skip_pallas and name == "lenet":  # interpret mode is slow; one net
            t0 = time.time()
            logits_apr = spec["forward"](params, x, conv_impl="pallas")
            t_apr = time.time() - t0
            err = float(jnp.max(jnp.abs(logits_apr - logits_xla)))
            line += f"  apr-kernel {t_apr*1e3:7.1f}ms (interpret)  maxerr {err:.2e}"
            assert err < 1e-3
        print(line)


def run_level_a():
    print("\n=== Level A: reproduced Table III (per model) ===")
    hdr = f"{'model':13s} {'ISA':9s} {'runtime':>9s} {'IC':>13s} {'IPC':>6s} {'mem':>13s} {'L1':>13s}"
    print(hdr)
    for model in ("lenet", "resnet20", "mobilenet_v1"):
        rows = {isa: simulate_model(model, isa) for isa in Isa}
        for isa, m in rows.items():
            print(f"{model:13s} {isa.pretty:9s} {m.runtime_s:8.3f}s "
                  f"{m.instructions:13,} {m.ipc:6.3f} {m.mem_instrs:13,} "
                  f"{m.l1_accesses:13,}")
        e = enhancement(rows[Isa.RV64F], rows[Isa.RV64R])
        print(f"{'':13s} RV64R over RV64F: runtime -{e['runtime']:.1f}%  "
              f"IC -{e['IC']:.1f}%  IPC +{e['IPC']:.1f}%  mem -{e['mem_instrs']:.1f}%")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-pallas", action="store_true")
    args = ap.parse_args()
    run_level_b(args.skip_pallas)
    run_level_a()


if __name__ == "__main__":
    main()
