"""End-to-end serving driver (the paper is an inference paper): batched
requests through the paged engine — FIFO admission, chunked prefill,
continuous decode batching over a paged KV cache — with per-request outputs
and the engine's own throughput/TTFT/page metrics.

    PYTHONPATH=src python examples/serve_batched.py --requests 6
    PYTHONPATH=src python examples/serve_batched.py --engine slot   # baseline
"""
import argparse

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.sharding import ParallelContext
from repro.serve import PagedServeEngine, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--engine", choices=("paged", "slot"), default="paged")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    pctx = ParallelContext(None)
    if args.engine == "paged":
        engine = PagedServeEngine(bundle, params, pctx, slots=args.slots,
                                  page_size=args.page_size,
                                  prefill_chunk=args.prefill_chunk)
    else:
        engine = ServeEngine(bundle, params, pctx, slots=args.slots,
                             max_seq=128)

    reqs = [Request(rid=i, prompt=[1 + i, 7, 3, 2], max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()

    for r in reqs:
        print(f"req {r.rid}: {len(r.output)} tokens -> {r.output[:8]}...")
    if isinstance(engine, PagedServeEngine):
        m = engine.metrics
        print(f"\n{args.requests} requests in {m.ticks} ticks, "
              f"{m.elapsed:.2f}s: prefill {m.prefill_tps:.1f} tok/s, "
              f"decode {m.decode_tps:.1f} tok/s, "
              f"ttft p50 {m.p50_ttft * 1e3:.0f}ms, "
              f"page util peak {m.peak_page_utilization:.0%}, "
              f"{m.preemptions} preemptions "
              f"(1 CPU core, smoke model)")
    else:
        total = sum(len(r.output) for r in reqs)
        print(f"\n{args.requests} requests, {total} tokens (slot engine, "
              "no metrics — use --engine paged)")


if __name__ == "__main__":
    main()
