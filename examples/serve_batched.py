"""End-to-end serving driver (the paper is an inference paper): batched
requests through the slot engine with continuous admission, per-request
outputs, and throughput accounting.

    PYTHONPATH=src python examples/serve_batched.py --requests 6
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.sharding import ParallelContext
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(bundle, params, ParallelContext(None),
                         slots=args.slots, max_seq=128)

    reqs = [Request(rid=i, prompt=[1 + i, 7, 3, 2], max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)

    t0 = time.time()
    ticks = 0
    while True:
        n = engine.step()
        ticks += 1
        if n == 0 and engine.pending.empty():
            break
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: {len(r.output)} tokens -> {r.output[:8]}...")
    print(f"\n{args.requests} requests, {total_tokens} tokens, "
          f"{ticks} engine ticks, {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s on 1 CPU core, smoke model)")


if __name__ == "__main__":
    main()
