"""Quickstart: train a tiny LM with the public API, watch the loss drop,
then greedy-decode from it.  Runs in ~1 minute on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps N]

``--steps`` trims the training loop (tools/run_examples.py --quick runs
this under CI with a handful of steps).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.data import SyntheticLMSource
from repro.models import build_model
from repro.parallel.sharding import ParallelContext
from repro.train.step import TrainHyper, init_optimizer, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30,
                    help="training steps (default 30; CI smoke uses fewer)")
    args = ap.parse_args()

    cfg = get_config("llama3-8b", smoke=True)   # reduced same-family config
    bundle = build_model(cfg)
    pctx = ParallelContext(None)

    params = bundle.init_params(jax.random.PRNGKey(0))
    opt = init_optimizer(cfg, params)
    shape = ShapeSpec("quickstart", seq_len=64, global_batch=8, kind="train")
    source = SyntheticLMSource(cfg, shape)

    step = jax.jit(make_train_step(bundle, pctx, TrainHyper(peak_lr=3e-3, warmup=5)))
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in source.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch, jnp.asarray(i, jnp.int32))
        if i % 5 == 0:
            print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}")

    # greedy decode a few tokens with the serving path
    cache = bundle.init_cache(batch=1, max_seq=32)
    lengths = jnp.zeros((1,), jnp.int32)
    tok = jnp.array([[1]], jnp.int32)
    out = []
    for _ in range(8):
        logits, cache = bundle.decode_step(params, cache, tok, lengths, pctx)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        lengths = lengths + 1
        out.append(int(tok[0, 0]))
    print("greedy sample:", out)


if __name__ == "__main__":
    main()
