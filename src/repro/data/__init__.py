from .pipeline import DataCursor, Prefetcher, SyntheticLMSource, TokenFileSource  # noqa: F401
