"""Deterministic, shard-aware synthetic token pipeline.

Production shape: every (step, dp_shard) pair maps to a unique, reproducible
batch slice via a counter-based RNG (threefry on (seed, step, shard)) — no
filesystem dependence, no coordination; a restarted/rescaled job replays
exactly from its checkpointed cursor.  Host-side prefetch overlaps batch
synthesis with the device step.

The same interface fronts a memmapped token corpus (``TokenFileSource``) for
the examples that train on real bytes.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeSpec


@dataclasses.dataclass
class DataCursor:
    """Checkpointable pipeline position."""
    step: int = 0
    seed: int = 0

    def to_dict(self):
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


class SyntheticLMSource:
    """Counter-based random tokens with a learnable bigram structure so loss
    actually decreases in the examples (next token = f(prev) + noise)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng((self.seed, step))
        b, s = shape.global_batch, shape.seq_len
        text = s - (cfg.vision_tokens if cfg.family == "vlm" else 0)
        v = cfg.vocab_size
        # structured stream: x_{t+1} = (a*x_t + c) % v with token noise
        a = 31, 17
        start = rng.integers(0, v, size=(b, 1))
        seq = [start]
        for _ in range(text - 1):
            nxt = (seq[-1] * 31 + 17) % v
            seq.append(nxt)
        tokens = np.concatenate(seq, axis=1).astype(np.int32)
        noise = rng.random((b, text)) < 0.05
        tokens = np.where(noise, rng.integers(0, v, size=(b, text)), tokens)
        labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        if cfg.family == "vlm":
            pad = np.zeros((b, cfg.vision_tokens), np.int32)
            labels = np.concatenate([pad, labels], axis=1)
        batch: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels.astype(np.int32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = rng.standard_normal(
                (b, cfg.vision_tokens, cfg.d_model), dtype=np.float32) * 0.02
        if cfg.family == "audio":
            batch["frames"] = rng.standard_normal(
                (b, cfg.encoder_frames, cfg.d_model), dtype=np.float32) * 0.02
        return batch


class TokenFileSource:
    """Memmapped uint16/uint32 token corpus, sharded round-robin."""

    def __init__(self, path: str, cfg: ModelConfig, shape: ShapeSpec,
                 dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.cfg, self.shape = cfg, shape

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b, s = self.shape.global_batch, self.shape.seq_len
        n = len(self.tokens) - (s + 1)
        idx = (np.arange(b) * 9973 + step * b) % n
        toks = np.stack([self.tokens[i:i + s + 1].astype(np.int32) for i in idx])
        toks = toks % self.cfg.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Host-side prefetch thread: hides batch synthesis behind the step."""

    def __init__(self, source, cursor: DataCursor, depth: int = 2):
        self.source = source
        self.cursor = cursor
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._next_step = cursor.step
        self._thread.start()

    def _run(self):
        step = self._next_step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.cursor.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
