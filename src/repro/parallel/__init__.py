from .sharding import (ParallelContext, make_context, logical_to_spec,  # noqa: F401
                       param_specs, zero1_spec)
from .tp import (TPPlan, make_serving_mesh, make_tp_context,  # noqa: F401
                 make_tp_decode_paged, per_device_bytes, plan_tp,
                 shard_tree, tp_cache_specs, tp_param_specs)
