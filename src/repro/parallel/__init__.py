from .sharding import (ParallelContext, make_context, logical_to_spec,  # noqa: F401
                       param_specs, zero1_spec)
