"""Tensor-parallel paged serving over a device mesh (ROADMAP item 1).

One :class:`~repro.serve.PagedServeEngine` drives N devices: attention
heads, MLP hidden dims, and (untied) lm_head vocab columns shard over a
1-D ``("model",)`` mesh, and every KV page pool shards over its kv-head
axis — *behind* the existing block-table contract, so the host allocator,
prefix cache, COW splits, and defrag in ``repro.serve.paged_cache`` stay
single-source: one block table drives N per-shard page pools in lockstep.

The decode/prefill step is a **fully-manual** ``shard_map`` region (manual
over every mesh axis — the only kind the image's jax 0.4.x compiles; see
``docs/known_failures.md``) wrapping the unmodified
:func:`repro.models.lm.lm_decode_paged` with a *local* ModelConfig whose
head/ff/kv-head counts are divided by the TP degree.  The two collectives
are explicit:

* :func:`repro.models.layers.tp_einsum` psums its f32 partial sums over
  ``model`` (attention output and MLP/MoE down projections) — activated by
  the :func:`~repro.models.layers.manual_tp` context the region body
  enters, and
* ``_lm_head`` all_gathers vocab-sharded logit columns before masking.

Everything else is per-head / per-channel local math, bit-identical to the
corresponding slice of the 1-device computation — which is why mesh greedy
tokens match the 1-device engine token-for-token (CI-gated by
``benchmarks/bench_parallel.py`` and ``tests/test_engine_identity.py``).

Sharding rules (``plan_tp``):

* ``num_heads`` and ``d_ff`` must divide the TP degree (hard requirement:
  their tp_einsum contractions are unconditionally psummed);
* KV heads shard when divisible, else **replicate** (the GQA fallback
  production TP uses — each shard then computes the full K/V projection
  and writes identical values to its full-size pool), requiring the local
  head count to still cover the GQA group structure;
* an untied lm_head vocab-shards when ``padded_vocab`` divides, else
  replicates; the embedding table always replicates (token gather stays
  local, and a tied head then emits full-width logits with no gather).

Dev/CI run on a simulated mesh::

    XLA_FLAGS=--xla_force_host_platform_device_count=4

See ``docs/parallel.md`` for the full guide.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat
from .sharding import ParallelContext


def make_tp_context(mesh: Mesh, tp_axis: str = "model") -> ParallelContext:
    """A serving ParallelContext for a tensor-parallel-only mesh: no data
    axes (one engine, one replica), every device a TP shard."""
    return ParallelContext(mesh=mesh, dp_axes=(), tp_axis=tp_axis)


def make_serving_mesh(n: int, tp_axis: str = "model") -> Mesh:
    """1-D TP mesh over the first ``n`` local devices."""
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"--mesh {n} needs {n} devices but only {len(devs)} are "
            "visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return Mesh(np.asarray(devs[:n]), (tp_axis,))


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """How one model shards over a TP-only mesh (see :func:`plan_tp`)."""
    degree: int
    local_cfg: Any               # ModelConfig with divided head/ff counts
    shard_kv: bool               # KV heads (and page pools) sharded?
    shard_vocab: bool            # untied lm_head vocab-sharded?


def plan_tp(cfg, degree: int) -> TPPlan:
    """Validate ``cfg`` against a TP degree and build the per-shard config.

    The local config keeps ``d_model`` and ``vocab_size`` (activations and
    logits are full-width at region boundaries) and pins ``head_dim`` so
    the divided head count cannot change the derived per-head dim.
    """
    if degree <= 1:
        return TPPlan(1, cfg, False, False)
    h, hkv, ff = cfg.num_heads, cfg.num_kv_heads, cfg.d_ff
    if h % degree:
        raise ValueError(
            f"tensor parallelism needs num_heads % mesh == 0 "
            f"(got {h} heads over {degree} shards)")
    if ff % degree:
        raise ValueError(
            f"tensor parallelism needs d_ff % mesh == 0 "
            f"(got d_ff={ff} over {degree} shards)")
    if cfg.dense_residual_ff and cfg.dense_residual_ff % degree:
        raise ValueError(
            f"tensor parallelism needs dense_residual_ff % mesh == 0 "
            f"(got {cfg.dense_residual_ff} over {degree} shards)")
    shard_kv = hkv % degree == 0
    local_hkv = hkv // degree if shard_kv else hkv
    if (h // degree) % local_hkv:
        raise ValueError(
            f"GQA layout unshardable: {h} query heads / {hkv} KV heads "
            f"over {degree} shards leaves {h // degree} local query heads "
            f"per {local_hkv} local KV heads (need a whole group per shard)")
    local_cfg = dataclasses.replace(
        cfg,
        num_heads=h // degree,
        num_kv_heads=local_hkv,
        head_dim=cfg.resolved_head_dim,
        d_ff=ff // degree,
        dense_residual_ff=cfg.dense_residual_ff // degree
        if cfg.dense_residual_ff else 0,
        name=f"{cfg.name}-tp{degree}",
    )
    shard_vocab = (not cfg.tie_embeddings) and cfg.padded_vocab % degree == 0
    return TPPlan(degree, local_cfg, shard_kv, shard_vocab)


# ---------------------------------------------------------------------------
# PartitionSpec trees.
# ---------------------------------------------------------------------------

#: logical param axes that shard over the TP axis unconditionally (their
#: tp_einsum contractions are always psummed inside the manual region)
_ALWAYS_TP = ("heads", "ff", "ssm_inner")


def tp_param_specs(params: Dict[str, Any], logical: Dict[str, Tuple],
                   plan: TPPlan, axis: str = "model") -> Dict[str, Any]:
    """Per-leaf PartitionSpecs for a param tree (plain arrays or int8
    :class:`~repro.quant.QuantizedTensor`s).

    Specs are resolved per *array leaf* so a QuantizedTensor's fp32 scale —
    same rank as its payload but with the contraction dim collapsed to 1 —
    replicates exactly the dims it cannot shard (a size-1 dim never
    shards) while staying aligned with the payload everywhere else.
    """
    specs: Dict[str, Any] = {}
    for name, val in params.items():
        log = logical[name]
        base = []
        for ax in log:
            if ax in _ALWAYS_TP:
                base.append(axis)
            elif ax == "kv_heads":
                base.append(axis if plan.shard_kv else None)
            elif ax == "vocab":
                # the embed table replicates (local token gather; tied head
                # emits full logits); only an untied lm_head vocab-shards
                base.append(axis if plan.shard_vocab and name != "embed"
                            else None)
            else:
                base.append(None)
        base_t = tuple(base)

        def leaf_spec(a, base_t=base_t, name=name):
            dims = []
            for i, ax in enumerate(base_t):
                if ax is None or a.shape[i] <= 1:
                    dims.append(None)
                    continue
                if a.shape[i] % plan.degree:
                    raise ValueError(
                        f"param {name!r} dim {i} ({a.shape[i]}) does not "
                        f"divide the TP degree {plan.degree}")
                dims.append(ax)
            return P(*dims)

        specs[name] = jax.tree.map(leaf_spec, val)
    return specs


#: axis index of the kv-head dim in every paged-cache leaf — payload pools
#: are (n_sb, me, pool_pages, page_size, hkv, dh) and int8 scale pools drop
#: only the trailing dh, so hkv sits at 4 in both
_KV_HEAD_AXIS = 4


def tp_cache_specs(cache: Dict[str, Any], plan: TPPlan,
                   axis: str = "model") -> Dict[str, Any]:
    """PartitionSpecs for the KV page pools: sharded over the kv-head axis
    when the plan shards KV heads, else replicated (each shard keeps a full
    pool and writes identical values — the GQA-replication fallback)."""
    def spec(a):
        if not plan.shard_kv:
            return P()
        dims = [None] * a.ndim
        dims[_KV_HEAD_AXIS] = axis
        return P(*dims)
    return jax.tree.map(spec, cache)


def shard_tree(tree: Any, mesh: Mesh, specs: Any) -> Any:
    """device_put every leaf with its NamedSharding (specs is a matching
    tree of PartitionSpecs; P flattens like a tuple on legacy jax, so the
    trees are zipped leaf-wise, not tree.mapped)."""
    leaves, treedef = jax.tree.flatten(tree)
    spec_leaves, _ = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves), (len(leaves), len(spec_leaves))
    out = [jax.device_put(a, NamedSharding(mesh, s))
           for a, s in zip(leaves, spec_leaves)]
    return jax.tree.unflatten(treedef, out)


def per_device_bytes(tree: Any) -> int:
    """Largest per-device byte footprint of a (possibly sharded) tree —
    the number BENCH_parallel.json reports per engine."""
    per: Dict[Any, int] = {}
    for a in jax.tree.leaves(tree):
        if hasattr(a, "addressable_shards") and a.addressable_shards:
            for sh in a.addressable_shards:
                per[sh.device] = per.get(sh.device, 0) + sh.data.nbytes
        else:
            per[None] = per.get(None, 0) + int(a.size) * a.dtype.itemsize
    return max(per.values()) if per else 0


# ---------------------------------------------------------------------------
# The TP decode/prefill step.
# ---------------------------------------------------------------------------


def make_tp_decode_paged(bundle, pctx: ParallelContext, plan: TPPlan,
                         param_specs, cache_specs):
    """Build the mesh variant of the engine's ``decode_paged`` entry point
    (same ``(params, cache, tokens, lengths, new_counts, block_tables)``
    contract, so decode T=1, chunked prefill T=chunk, and speculative
    verify T=K+1 all route through it unchanged).

    The body runs :func:`~repro.models.lm.lm_decode_paged` with the plan's
    *local* config under :func:`~repro.models.layers.manual_tp`; scalars,
    tokens, and block tables replicate (specs ``P()``), params and cache
    arrive pre-sliced per the spec trees.  The inner ParallelContext is
    mesh-free: inside a fully-manual region there is nothing left for
    GSPMD (or a nested shard_map) to do.
    """
    from ..models import lm
    from ..models.layers import manual_tp

    axis = pctx.tp_axis
    local_cfg = plan.local_cfg
    inner_pctx = ParallelContext(None)

    def body(params, cache, tokens, lengths, new_counts, block_tables):
        with manual_tp(axis, plan.degree):
            return lm.lm_decode_paged(params, local_cfg, inner_pctx, cache,
                                      tokens, lengths, new_counts,
                                      block_tables)

    return compat.shard_map(
        body, mesh=pctx.mesh,
        in_specs=(param_specs, cache_specs, P(), P(), P(), P()),
        out_specs=(P(), cache_specs),
        check_vma=False,
    )
