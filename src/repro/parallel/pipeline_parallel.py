"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Opt-in feature (the production dry-run uses DP x TP x EP, which fits every
assigned arch on v5e; PP is for deeper models on smaller-HBM parts — see
DESIGN.md §4).  Schedule: classic GPipe fill/steady/drain over
M microbatches and S stages (M + S - 1 ticks), expressed as a lax.scan of
ticks inside a shard_map that is manual over ``stage``; activations advance
between stages with ``lax.ppermute`` — the collective the roofline parser
accounts as pipeline traffic.  Backward is jax autodiff through the
schedule (ppermute transposes to the reverse rotation), giving GPipe's
fwd+bwd with recomputation when the layer_fn is checkpointed.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import compat


def pipeline_apply(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,          # leaves with leading dim = n_stages
    microbatches: jax.Array,    # (M, mb, ...) input microbatches
    mesh: Mesh,
    *,
    stage_axis: str = "stage",
) -> jax.Array:
    """Run ``layer_fn`` per stage over microbatches; returns (M, mb, ...)."""
    n_stages = mesh.shape[stage_axis]

    def staged(params_local, xs):
        s = jax.lax.axis_index(stage_axis)
        m = xs.shape[0]
        ticks = m + n_stages - 1
        rot = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def apply_stage(x):
            # each stage holds L/S layers (leading local dim after sharding)
            nloc = jax.tree.leaves(params_local)[0].shape[0]
            for i in range(nloc):
                x = layer_fn(jax.tree.map(lambda a: a[i], params_local), x)
            return x

        def tick(carry, t):
            state, outs = carry
            # stage 0 injects microbatch t during the fill/steady phase
            inject = xs[jnp.clip(t, 0, m - 1)]
            state = jnp.where(s == 0, inject, state)
            out = apply_stage(state)
            # last stage emits microbatch t-(S-1)
            idx = t - (n_stages - 1)
            valid = (s == n_stages - 1) & (idx >= 0) & (idx < m)
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, out.astype(outs.dtype), jnp.clip(idx, 0, m - 1), 0)
            outs = jnp.where(valid, upd, outs)
            state = jax.lax.ppermute(out, stage_axis, rot)
            return (state, outs), None

        state0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (state, outs), _ = jax.lax.scan(tick, (state0, outs0), jnp.arange(ticks))
        # only the last stage's buffer is real; replicate it via psum
        mask = (s == n_stages - 1).astype(outs.dtype)
        return jax.lax.psum(outs * mask, stage_axis)

    in_specs = (
        jax.tree.map(lambda _: P(stage_axis), stage_params,
                     is_leaf=lambda x: hasattr(x, "shape")),
        P(),
    )
    return compat.shard_map(
        staged, mesh=mesh, in_specs=in_specs, out_specs=P(),
        axis_names={stage_axis}, check_vma=False,
    )(stage_params, microbatches)
