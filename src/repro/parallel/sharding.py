"""Logical-axis sharding rules -> PartitionSpecs for the production mesh.

Mesh axes:
  * ``pod``   (multi-pod only): pure data parallelism across pods; MoE
    experts and optimizer behaviour replicate across it.
  * ``data``  : data parallelism + ZeRO-1 optimizer-state sharding + MoE
    expert parallelism (EP group = one pod).
  * ``model`` : tensor parallelism (attention heads / FFN hidden / vocab).

Logical param axes (registered by every ParamBuilder site):
  layers, embed, ff, heads, kv_heads, vocab, experts, ssm_inner, state
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)   # ("pod","data") on the multi-pod mesh
    tp_axis: str = "model"
    ep_axis: str = "data"                  # MoE all-to-all axis (in-pod)

    @property
    def dp_degree(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_degree(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.tp_axis])

    def batch_spec(self, extra_dims: int = 1) -> P:
        """Leading-batch sharding over all DP axes."""
        return P(tuple(self.dp_axes), *([None] * extra_dims))


def make_context(mesh: Optional[Mesh]) -> ParallelContext:
    if mesh is None:
        return ParallelContext(None)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return ParallelContext(mesh=mesh, dp_axes=dp)


def logical_to_spec(
    logical: Tuple[Optional[str], ...],
    pctx: ParallelContext,
    *,
    shape: Optional[Tuple[int, ...]] = None,
    kv_heads: int = 0,
    fsdp: bool = False,
) -> P:
    """Map one param's logical axes to a PartitionSpec.

    ``kv_heads``: fused KV projection dims shard over ``model`` only when
    the head count divides the TP degree (else they stay replicated — a few
    MB — and GSPMD broadcasts, which is what production TP does for GQA
    with fewer KV heads than TP shards).

    ``fsdp``: additionally shard the ``embed`` dims over ``data`` — the
    weight-gathered layout (§Perf): with the batch sharded over every mesh
    axis, GSPMD gathers weights per layer instead of all-reducing
    activations.
    """
    tp = pctx.tp_axis
    out = []
    has_experts = "experts" in logical  # expert dim already owns "data"
    for ax in logical:
        if ax in ("ff", "heads", "vocab", "ssm_inner"):
            out.append(tp)
        elif ax == "embed":
            out.append("data" if (fsdp and not has_experts) else None)
        elif ax == "kv_heads":
            out.append(tp if kv_heads and kv_heads % max(pctx.tp_degree, 1) == 0 else None)
        elif ax == "experts":
            out.append("data")
        else:  # layers, state, None
            out.append(None)
    return P(*out)


def param_specs(
    logical_tree: Dict[str, Tuple[Optional[str], ...]],
    pctx: ParallelContext,
    kv_heads: int = 0,
    fsdp: bool = False,
) -> Dict[str, P]:
    out = {}
    for name, log in logical_tree.items():
        if fsdp and name == "embed":
            # weight-gathered layout: a vocab-sharded gather with the batch
            # sharded over every axis trips GSPMD's involuntary-remat path
            # (observed: full replication of (b,s,d)); a replicated table
            # keeps the gather local.  ~1-2 GiB/chip for the largest vocab.
            out[name] = P()
            continue
        out[name] = logical_to_spec(log, pctx, kv_heads=kv_heads, fsdp=fsdp)
    return out


def zero1_spec(spec: P, shape: Tuple[int, ...], pctx: ParallelContext) -> P:
    """ZeRO-1: additionally shard optimizer state over ``data`` on the first
    dimension that is unsharded and divisible.  Pods replicate optimizer
    state (cheap cross-pod restore after failover)."""
    if pctx.mesh is None or "data" not in pctx.mesh.axis_names:
        return spec
    dsize = int(pctx.mesh.shape["data"])
    entries = list(spec) + [None] * (len(shape) - len(spec))
    if any(e == "data" or (isinstance(e, tuple) and "data" in e) for e in entries):
        return spec  # already data-sharded (e.g. experts)
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dsize == 0 and dim >= dsize:
            entries[i] = "data"
            return P(*entries)
    return spec


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
