"""JAX API compatibility layer for the manual-collectives code paths.

The parallel/MoE modules were written against the modern spellings
(``jax.shard_map`` with ``axis_names=...``/``check_vma=...``,
``jax.lax.axis_size``, ``jax.sharding.set_mesh``); older 0.4.x releases ship
the same functionality under ``jax.experimental.shard_map.shard_map`` with
the complementary ``auto=...``/``check_rep=...`` parameters.  Routing every
call site through this module keeps the tree runnable on both generations.

One capability does NOT translate: *partial-manual* regions (manual over a
strict subset of mesh axes, GSPMD auto-sharding the rest).  The legacy
``auto=`` parameter accepts them, but 0.4.x XLA's SPMD partitioner aborts
(``Check failed: IsManualSubgroup``) when partitioning the auto remainder.
For that reason NO in-repo region is partial-manual any more: every
shard_map call site passes ``axis_names=None`` (or the full axis set) and
places its own collectives on every axis — see ``repro.parallel.tp``,
``repro.models.moe``, and ``repro.parallel.compression`` for the pattern,
and ``docs/known_failures.md`` for the history.  :data:`HAS_PARTIAL_MANUAL`
remains as the capability probe (it also marks where the simpler
partial-manual spelling could return once jax ≥ 0.5 lands).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

try:  # pragma: no cover - absent on newest jax, present on 0.4.x
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
except ImportError:  # pragma: no cover
    _legacy_shard_map = None

#: Modern ``jax.shard_map`` exists (implies partial-manual regions compile).
HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

#: Partial-manual shard_map (manual over a subset of mesh axes) compiles.
#: On 0.4.x the legacy ``auto=`` path exists but XLA's SPMD partitioner
#: aborts the process on it — see docs/known_failures.md.
HAS_PARTIAL_MANUAL = HAS_NATIVE_SHARD_MAP


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` facade accepting the modern keyword spelling.

    ``axis_names`` is the set of *manual* mesh axes (None = all axes); on
    legacy jax it is translated to the complementary ``auto`` set and
    ``check_vma`` to ``check_rep``.
    """
    if HAS_NATIVE_SHARD_MAP:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    if _legacy_shard_map is None:  # pragma: no cover
        raise RuntimeError("no shard_map implementation in this jax")
    names = frozenset(mesh.axis_names)
    manual = frozenset(axis_names) if axis_names is not None else names
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=bool(check_vma),
                             auto=names - manual)


def axis_size(name) -> jax.Array:
    """``jax.lax.axis_size`` with the ``psum(1, axis)`` fallback."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def set_mesh(mesh: Optional[jax.sharding.Mesh]):
    """``jax.sharding.set_mesh`` context; legacy ``Mesh`` is itself a
    context manager with the equivalent ambient-mesh effect."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh if mesh is not None else contextlib.nullcontext()
