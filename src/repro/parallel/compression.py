"""Error-feedback-style int8 gradient compression for the slow cross-pod
links.

At 2x16x16 the in-pod gradient reduction runs full precision over fast ICI
(an explicit pmean on the data axis); the *pod*-axis stage is block-scaled
int8 quantised, summed over the pod axis, and dequantised.  Cross-pod
gradient traffic shrinks ~4x (int8 + fp32 block scales vs fp32).

The whole value_and_grad is wrapped in a shard_map that is manual over
EVERY mesh axis (batch sharded over pod+data, params replicated — each
model shard recomputes the same local grads): the image's jax has no
partial-manual shard_map (docs/known_failures.md), so both reduction
stages are explicit collectives instead of leaving the in-pod stage to
GSPMD.  Shard-local loss is a mean over an equal-size batch slice, so
pmean-of-means is exactly the global mean.

The compiled HLO shows the int8 all-reduce on the pod axis — visible to the
roofline collective parser, which is how §Perf measures the win.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compat

BLOCK = 2048


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def _compressed_pod_mean(g: jax.Array, pod_axis: str) -> jax.Array:
    """int8 payload + fp32 block scales, summed over the pod axis."""
    q, scale = quantize_int8(g)
    # Each pod's payload is dequantised with its own scale after the int32
    # sum of per-pod (q * 1) values would lose scale pairing; instead psum
    # the dequantised *block* representation: int8 payload summed in int32
    # with a shared max-scale so dequantisation distributes over the sum.
    smax = jax.lax.pmax(scale, pod_axis)
    qr = jnp.clip(jnp.round(q.astype(jnp.float32) * (scale / smax)), -127, 127)
    qsum = jax.lax.psum(qr.astype(jnp.int32), pod_axis)
    n = compat.axis_size(pod_axis)
    flat = qsum.astype(jnp.float32) * smax / n
    total = 1
    for s in g.shape:
        total *= s
    return flat.reshape(-1)[:total].reshape(g.shape)


def compressed_value_and_grad(
    loss_fn: Callable,       # params, batch -> scalar loss
    params: Any,
    batch: Any,
    pctx,
    *,
    enabled: bool,
) -> Tuple[jax.Array, Any]:
    """value_and_grad with the pod-axis reduction stage int8-compressed.

    Disabled / single-pod: plain value_and_grad (GSPMD reduces everything).
    Enabled on a multi-pod mesh: a fully-manual region — batch sharded over
    pod and data axes, per-shard grads pmean'd full-precision over data,
    then the pod-axis psum carries int8 payloads.
    """
    if not enabled or pctx.mesh is None or "pod" not in pctx.mesh.axis_names:
        return jax.value_and_grad(loss_fn)(params, batch)

    mesh = pctx.mesh
    batch_axes = tuple(pctx.dp_axes)            # ("pod", "data")
    data_axes = tuple(a for a in batch_axes if a != "pod")

    def podwise(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, batch_axes)
        if data_axes:
            # in-pod stage: full precision over the fast links
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, data_axes), grads)
        grads = jax.tree.map(lambda g: _compressed_pod_mean(g, "pod"), grads)
        return loss, grads

    rep = lambda tree: jax.tree.map(lambda _: P(), tree,
                                    is_leaf=lambda x: hasattr(x, "shape"))
    batch_specs = {k: P(batch_axes) for k in batch}
    return compat.shard_map(
        podwise, mesh=mesh,
        in_specs=(rep(params), batch_specs),
        out_specs=(P(), rep(params)),
        check_vma=False,
    )(params, batch)
