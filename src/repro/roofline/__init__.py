from . import hw  # noqa: F401
from .analysis import (CollectiveStats, RooflineTerms, cost_from_compiled,  # noqa: F401
                       extrapolate, model_flops, parse_collectives)
from .hw import (HardwareProfile, all_profiles, get_profile,  # noqa: F401
                 register_profile)
