"""Named hardware profiles for the analytic performance models.

Seed versions of this module hardcoded one TPU-v5e constant table; the
cost model (``repro.cost``) and the roofline analysis now resolve their
peak-FLOPs / bandwidth / memory numbers through a :class:`HardwareProfile`
registry instead, so the same analytic machinery prices kernels and graph
schedules for any target the registry names.

Profiles:

* ``tpu_v5e`` (default) — one v5e chip: 197 TFLOP/s bf16, 819 GB/s HBM,
  ~128 MiB VMEM, 4 usable ICI links at 50 GB/s.
* ``cpu_interpret`` — the Pallas interpret path this repo's CI runs on.
  The absolute numbers are a deliberately small proxy (interpret mode is
  not a hardware target); only *relative* ordering of candidates is
  meaningful, which is all the autotune pruner needs off-TPU.

Selection: :func:`get_profile` resolves an explicit name, else the
``REPRO_HW_PROFILE`` environment variable, else ``tpu_v5e``.  New targets
register with :func:`register_profile`; ``tools/check_docs.py`` requires
every registered profile name to be documented in ``docs/cost_model.md``.

The module-level constants (``PEAK_FLOPS_BF16`` ...) are the ``tpu_v5e``
numbers, kept for existing call sites that predate the registry.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

_ENV_VAR = "REPRO_HW_PROFILE"
DEFAULT_PROFILE = "tpu_v5e"


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """Analytic description of one execution target.

    All rates are per chip/device; the roofline's collective term combines
    ``ici_bw_per_link`` with ``links_per_chip``.
    """

    name: str
    peak_flops: float            # FLOP/s (the dominant matmul dtype)
    hbm_bw: float                # bytes/s main-memory bandwidth
    vmem_bytes: int              # on-chip scratch ceiling (tile residency)
    hbm_bytes: int               # main-memory capacity
    ici_bw_per_link: float = 0.0  # bytes/s per interconnect link
    links_per_chip: int = 0
    description: str = ""

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte where compute and memory time balance."""
        return self.peak_flops / self.hbm_bw


_PROFILES: Dict[str, HardwareProfile] = {}


def register_profile(profile: HardwareProfile) -> HardwareProfile:
    if profile.name in _PROFILES:
        raise ValueError(f"hardware profile {profile.name!r} already "
                         "registered")
    _PROFILES[profile.name] = profile
    return profile


def get_profile(name: Optional[str] = None) -> HardwareProfile:
    """Resolve a profile: explicit ``name`` > ``$REPRO_HW_PROFILE`` >
    ``tpu_v5e``."""
    resolved = name or os.environ.get(_ENV_VAR) or DEFAULT_PROFILE
    try:
        return _PROFILES[resolved]
    except KeyError:
        raise KeyError(f"no hardware profile {resolved!r}; "
                       f"known: {sorted(_PROFILES)}") from None


def all_profiles() -> Dict[str, HardwareProfile]:
    return dict(_PROFILES)


register_profile(HardwareProfile(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    vmem_bytes=128 * 1024 * 1024,
    hbm_bytes=16 * 1024**3,
    ici_bw_per_link=50e9,
    links_per_chip=4,
    description="one TPU v5e chip (bf16 MXU peak, 2D-torus ICI)",
))

register_profile(HardwareProfile(
    name="cpu_interpret",
    peak_flops=5e9,
    hbm_bw=20e9,
    vmem_bytes=16 * 1024 * 1024,
    hbm_bytes=8 * 1024**3,
    ici_bw_per_link=0.0,
    links_per_chip=0,
    description="Pallas interpret mode on CPU — ordering-only proxy; "
                "absolute estimates are not hardware predictions",
))


# -- legacy constant aliases (the tpu_v5e numbers) --------------------------
_V5E = _PROFILES["tpu_v5e"]

PEAK_FLOPS_BF16 = _V5E.peak_flops     # FLOP/s per chip
HBM_BW = _V5E.hbm_bw                  # bytes/s per chip
ICI_BW_PER_LINK = _V5E.ici_bw_per_link  # bytes/s per link

SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512
VMEM_BYTES = _V5E.vmem_bytes
HBM_BYTES = _V5E.hbm_bytes
