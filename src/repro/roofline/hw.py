"""TPU v5e hardware constants (per the assignment sheet)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link

SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512
VMEM_BYTES = 128 * 1024 * 1024  # ~128 MiB scratch ceiling (v5e class)
HBM_BYTES = 16 * 1024**3
