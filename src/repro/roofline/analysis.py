"""Three-term roofline from compiled dry-run artifacts.

    compute    = HLO_FLOPs   / (chips x 197 TFLOP/s)
    memory     = HLO_bytes   / (chips x 819 GB/s)
    collective = wire_bytes  / (chips x n_links x 50 GB/s)

``cost_analysis()`` supplies FLOPs / bytes; collective bytes are parsed out
of the optimized HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute operand sizes, ring-algorithm wire cost).

Scan-body correction: XLA counts a ``lax.scan`` body once, so per-cell
costs are obtained from two *unrolled* shallow compiles and extrapolated:
    cost(full) = cost(k=1) + (D - 1) * (cost(k=2) - cost(k=1))
with D the number of scan units (configs.base.depth_units) and, for
training, times the number of grad-accum microbatches for the per-step
total.  Memory fit always comes from the real full-depth scan compile.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from . import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int = 2) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0                   # ring-cost bytes per device
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, b: float):
        self.wire_bytes += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.count += 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device wire bytes of every collective in the HLO.

    Ring-algorithm cost per participating device, with S the payload bytes
    on one device and n the group size:
      all-gather:          output S_out  -> (n-1)/n * S_out
      reduce-scatter:      input  S_in   -> (n-1)/n * S_in  (= out*(n-1))
      all-reduce:          2 * (n-1)/n * S
      all-to-all:          (n-1)/n * S
      collective-permute:  S
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # HLO line form: "%name = TYPE kind(operands), attrs" — the result
        # TYPE sits between '=' and the op token (op *names* often contain
        # the op string too, so anchor on "<space>kind(").
        kind = None
        m = None
        for k in _COLLECTIVES:
            m = re.search(rf"=\s*(.+?)\s{k}(-start)?\(", stripped)
            if m:
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(m.group(1))
        if not shapes:
            continue
        size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        n = _group_size(stripped)
        frac = (n - 1) / max(n, 1)
        if kind == "all-reduce":
            b = 2.0 * frac * size
        elif kind == "all-gather":
            b = frac * size                     # size is the gathered output
        elif kind == "reduce-scatter":
            b = (n - 1) * size                  # size is the scattered output
        elif kind == "all-to-all":
            b = frac * size
        else:  # collective-permute
            b = float(size)
        stats.add(kind, b)
    return stats


@dataclasses.dataclass
class RooflineTerms:
    """All byte/FLOP quantities are PER-DEVICE: XLA's cost_analysis and the
    HLO text both describe the per-device SPMD program, so

        compute = FLOPs_dev/peak == HLO_FLOPs_total/(chips*peak).

    Hardware constants resolve through a named
    :class:`~repro.roofline.hw.HardwareProfile` (``profile=None`` picks
    ``$REPRO_HW_PROFILE``, default ``tpu_v5e``) instead of the seed's
    single hardcoded v5e table."""

    flops: float          # per-device
    hbm_bytes: float      # per-device
    wire_bytes: float     # per-device
    chips: int
    links_per_chip: int = 4  # v5e 2D torus: 4 ICI links usable
    profile: Optional[hw.HardwareProfile] = None

    def _hw(self) -> hw.HardwareProfile:
        return self.profile if self.profile is not None else hw.get_profile()

    @property
    def t_compute(self) -> float:
        return self.flops / self._hw().peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self._hw().hbm_bw

    @property
    def t_collective(self) -> float:
        link_bw = self._hw().ici_bw_per_link
        if link_bw <= 0 or self.links_per_chip <= 0:
            return 0.0
        return self.wire_bytes / (self.links_per_chip * link_bw)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
        }


def extrapolate(c1: float, c2: float, depth: int, multiplier: float = 1.0) -> float:
    """cost(full) = c1 + (depth-1)*(c2-c1), optionally x microbatches."""
    per_layer = max(c2 - c1, 0.0)
    return (c1 + (depth - 1) * per_layer) * multiplier


def cost_from_compiled(compiled) -> Tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bts = float(ca.get("bytes accessed", 0.0))
    return flops, bts


def analytic_hbm_bytes(cfg, shape, chips: int, *, param_count: int,
                       cache_bytes: int = 0, microbatches: int = 1) -> float:
    """Per-device HBM traffic under TPU-grade fusion (lower bound).

    The CPU backend's ``bytes accessed`` counts every unfused op's operands
    (~5-20x real TPU HBM traffic), so the memory roofline term uses this
    analytic model instead — the same accounting MaxText-style perf sheets
    use — while the raw HLO number is kept as an upper bound:

    train:  3x param reads (fwd + remat + bwd) + grad write/read (fp32)
            + optimizer state read/write + layer-boundary activations
            (x3: fwd write, bwd read, remat re-write) + logits
    prefill: param read + boundary activations + KV-cache write
    decode:  param read + full cache read + token write
    """
    p_dev = param_count * 2 / chips  # bf16 resident
    d, v = cfg.d_model, cfg.vocab_size
    tokens_dev = shape.global_batch * shape.seq_len / chips * \
        (1 if shape.kind != "decode" else 0)
    layers = cfg.num_layers + (cfg.encoder_layers or 0)

    if shape.kind == "train":
        param_traffic = 3 * p_dev + 4 * p_dev  # reads + fp32 grad w/r
        opt_traffic = (2 * p_dev) if cfg.optimizer == "adafactor" else 12 * p_dev
        act = tokens_dev * d * 2 * layers * 3  # boundary x (fwd,bwd,remat)
        logits = tokens_dev * v * 2 * 3
        return param_traffic + opt_traffic + act + logits
    if shape.kind == "prefill":
        act = tokens_dev * d * 2 * layers * 2
        return p_dev + act + cache_bytes / max(chips, 1) + tokens_dev * v * 2
    # decode: weights once + cache streamed once
    return p_dev + cache_bytes / max(chips, 1) + shape.global_batch * v * 2 / chips


def model_flops(cfg, shape, training: bool) -> float:
    """Analytic 6*N_active*D (train) / 2*N_active*D (inference) per step."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return cfg.model_flops_per_token(training=True) * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return cfg.model_flops_per_token(training=False) * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return cfg.model_flops_per_token(training=False) * tokens
