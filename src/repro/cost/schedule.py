"""Whole-graph schedule caching: ConfigCache entries keyed by graph shape.

Choosing a fusion clustering costs a pass sweep plus a traffic estimate
per candidate rewrite; an engine that compiles the same prefill/decode
graphs at every start-up should pay that once.  This module persists a
:class:`~repro.cost.graph.ScheduleDecision`'s kept-pass subset in the same
:class:`~repro.bench.config.ConfigCache` that holds tuned kernel tiles, so
engines warm graph schedules exactly like block configs (scoped per
engine, JSON on disk, ``kernel|shape|dtype|backend`` keys).

Key scheme::

    __graph_schedule__|<graph signature>|-|any

The **graph signature** is a sha256 over the traced (pre-fusion) graph's
canonical structure: per value its (id, shape, dtype, kind), per node its
(op, attr names+reprs, input ids, output ids), plus the graph's I/O lists.
Const *values* are excluded — two engines with different weights but the
same architecture share a schedule — while const shapes/dtypes are
included, so e.g. an int8-quantized variant (whose weight consts are int8
and feed ``fold_quant_dequant``) signs differently from the fp32 one.
Value/node ids are deterministic tracing artifacts, which makes the
signature stable across processes for the same model geometry
(``tests/test_cost.py::TestSignature``).

The cached entry is a :class:`~repro.bench.config.BlockConfig` mapping
every candidate pass name to 0/1.  A lookup whose stored pass vocabulary
differs from the current registry (a pass added or renamed since the cache
was written) is treated as a miss and re-derived — never half-applied.
"""
from __future__ import annotations

import hashlib
from typing import List, Optional

from ..bench.config import BlockConfig, ConfigCache, active_cache
from ..graph.ir import Graph
from .graph import ScheduleDecision, candidate_passes

SCHEDULE_KERNEL = "__graph_schedule__"
_DTYPE = "-"
_BACKEND = "any"


def graph_signature(g: Graph) -> str:
    """Stable content hash of a traced graph's structure (not its consts)."""
    h = hashlib.sha256()
    for vid in sorted(g.values):
        v = g.values[vid]
        h.update(f"v{v.id}:{tuple(v.shape)}:{v.dtype}:{v.kind};".encode())
    for n in g.nodes:
        attrs = ",".join(f"{k}={n.attrs[k]!r}" for k in sorted(n.attrs))
        h.update(f"n{n.op}({attrs})<{n.inputs}>{n.outputs};".encode())
    h.update(f"in{tuple(g.inputs)}out{tuple(g.outputs)}".encode())
    return h.hexdigest()


def store_schedule(decision: ScheduleDecision,
                   cache: Optional[ConfigCache] = None) -> None:
    """Persist ``decision`` under its signature in ``cache`` (default: the
    active scoped cache, i.e. the engine's own tune cache)."""
    cache = cache if cache is not None else active_cache()
    vocab = candidate_passes()
    cfg = BlockConfig.make(
        **{name: int(name in decision.passes) for name in vocab})
    cache.store(SCHEDULE_KERNEL, decision.signature, _DTYPE, _BACKEND, cfg,
                metrics={
                    "traffic_unfused": float(
                        decision.unfused.intermediate_traffic),
                    "traffic_fused": float(
                        decision.fused.intermediate_traffic),
                    "predicted_us": decision.fused.predicted_us,
                })


def lookup_schedule(signature: str,
                    cache: Optional[ConfigCache] = None
                    ) -> Optional[List[str]]:
    """The cached kept-pass list for ``signature`` in application order, or
    None on miss / stale pass vocabulary."""
    cache = cache if cache is not None else active_cache()
    cfg = cache.lookup(SCHEDULE_KERNEL, signature, _DTYPE, _BACKEND)
    if cfg is None:
        return None
    vocab = candidate_passes()
    if set(cfg.to_dict()) != set(vocab):
        return None    # schedule written against a different pass registry
    return [name for name in vocab if cfg[name]]
