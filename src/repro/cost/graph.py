"""Graph-level cost model: price a fusion clustering by predicted traffic.

A fusion clustering's value is exactly the paper's metric — how much
memory-access frequency it removes.  :func:`estimate_graph` prices any
:class:`~repro.graph.ir.Graph` from the memory planner's accounting
(:func:`repro.graph.plan.memory_report`: one write per materializing
intermediate plus one read per consumer, consts and outputs streamed once)
plus the analytic FLOPs of its contraction nodes, combined on a
:class:`~repro.roofline.hw.HardwareProfile` with the same
roofline-with-leak rule as the kernel model.

:func:`select_passes` replaces the graph compiler's fixed pass-order
heuristic: it walks the registered ``@fusion_pass`` rewrites in canonical
order (``default_passes()`` first — order constraints like quant-folding-
before-epilogue are preserved — then any extra registrations), applies
each to the working graph, and **keeps a rewrite only if the model
predicts an HBM-traffic win** (strictly less intermediate traffic, or the
same traffic from strictly fewer clusters).  Every candidate subset of the
property-tested passes is output-preserving, so the greedy walk is legal
by construction; what it adds over the fixed pipeline is an auditable
per-pass traffic delta (`PassDecision`) and a stable
:class:`ScheduleDecision` artifact the schedule cache can persist.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from ..graph.ir import Graph
from ..graph.passes import all_passes, default_passes, get_pass
from ..graph.plan import memory_report
from ..roofline.hw import HardwareProfile, get_profile
from .model import combine_times

#: ops whose analytic FLOPs dominate a graph (2 * prod(contraction dims));
#: everything else is costed as memory traffic only.
_CONTRACTION_OPS = ("matmul", "quant_matmul", "conv2d")


def _node_flops(g: Graph, node) -> float:
    """Analytic FLOPs of one primitive node (fused clusters sum their
    bodies).  Contractions: 2 * output elements * reduction depth."""
    total = 0.0
    for n in node.body_nodes():
        if n.op not in _CONTRACTION_OPS:
            continue
        out = g.values.get(n.outputs[0])
        lhs = g.values.get(n.inputs[0]) if n.inputs else None
        if out is None or lhs is None:
            continue
        out_elems = 1
        for d in out.shape:
            out_elems *= int(d)
        if n.op == "conv2d":
            rhs = g.values.get(n.inputs[1])
            red = 1
            for d in (rhs.shape[:-1] if rhs is not None else ()):
                red *= int(d)          # hf * wf * c_in
        else:
            red = int(lhs.shape[-1]) if lhs.shape else 1
        total += 2.0 * out_elems * red
    return total


@dataclasses.dataclass(frozen=True)
class GraphCostEstimate:
    """Analytic price of one whole-graph execution."""

    name: str
    flops: float
    intermediate_traffic: int    # write + read-per-consumer, planner terms
    const_bytes: int             # weights streamed once
    output_bytes: int
    n_nodes: int
    n_intermediates: int
    t_compute_s: float
    t_memory_s: float
    predicted_s: float
    profile: str

    @property
    def hbm_bytes(self) -> int:
        return self.intermediate_traffic + self.const_bytes + self.output_bytes

    @property
    def predicted_us(self) -> float:
        return self.predicted_s * 1e6

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hbm_bytes"] = self.hbm_bytes
        d["predicted_us"] = self.predicted_us
        return d


def estimate_graph(g: Graph, *,
                   profile: Optional[HardwareProfile] = None
                   ) -> GraphCostEstimate:
    """Price ``g`` as compiled: cluster-internal values cost nothing
    (the graph-level APR), everything that materializes is charged the
    planner's write + read-per-consumer traffic."""
    prof = profile if profile is not None else get_profile()
    rep = memory_report(g)
    flops = sum(_node_flops(g, n) for n in g.nodes)
    hbm = rep.intermediate_traffic + rep.const_bytes + rep.output_bytes
    t_c = flops / prof.peak_flops
    t_m = hbm / prof.hbm_bw
    return GraphCostEstimate(
        name=g.name, flops=flops,
        intermediate_traffic=rep.intermediate_traffic,
        const_bytes=rep.const_bytes, output_bytes=rep.output_bytes,
        n_nodes=rep.n_nodes, n_intermediates=rep.n_intermediates,
        t_compute_s=t_c, t_memory_s=t_m,
        predicted_s=combine_times(t_c, t_m), profile=prof.name,
    )


@dataclasses.dataclass(frozen=True)
class PassDecision:
    """One pass's audit row in a schedule decision."""

    name: str
    kept: bool
    traffic_before: int
    traffic_after: int
    nodes_before: int
    nodes_after: int

    @property
    def traffic_saved(self) -> int:
        return self.traffic_before - self.traffic_after


@dataclasses.dataclass
class ScheduleDecision:
    """The chosen whole-graph schedule plus its cost audit trail.

    ``passes`` is the kept subset in application order — replaying it with
    :func:`repro.graph.passes.run_passes` on the same traced graph rebuilds
    the same clustering (determinism is what makes the schedule cachable).
    """

    graph_name: str
    signature: str                   # repro.cost.schedule.graph_signature
    passes: List[str]
    decisions: List[PassDecision]
    unfused: GraphCostEstimate
    fused: GraphCostEstimate
    cached: bool = False             # True when replayed from a cache hit

    @property
    def traffic_reduction(self) -> float:
        return (self.unfused.intermediate_traffic
                / max(self.fused.intermediate_traffic, 1))

    def report(self) -> str:
        """Human-readable ``--explain`` block."""
        lines = [
            f"schedule {self.graph_name} "
            f"[sig {self.signature[:12]}, profile {self.fused.profile}"
            f"{', cached' if self.cached else ''}]",
            f"  unfused: {self.unfused.n_nodes} nodes, "
            f"{self.unfused.intermediate_traffic:,} B intermediate traffic, "
            f"predicted {self.unfused.predicted_us:.1f}us",
        ]
        for d in self.decisions:
            verdict = "keep" if d.kept else "drop"
            lines.append(
                f"  pass {d.name:24s} {verdict}  "
                f"traffic {d.traffic_before:,} -> {d.traffic_after:,} B  "
                f"nodes {d.nodes_before} -> {d.nodes_after}")
        lines.append(
            f"  fused:   {self.fused.n_nodes} nodes, "
            f"{self.fused.intermediate_traffic:,} B intermediate traffic "
            f"({self.traffic_reduction:.2f}x less), "
            f"predicted {self.fused.predicted_us:.1f}us")
        return "\n".join(lines)


def candidate_passes(names: Optional[Sequence[str]] = None) -> List[str]:
    """Canonical evaluation order: ``default_passes()`` first (their
    relative order encodes real constraints), then any other registered
    passes sorted by name."""
    if names is not None:
        return list(names)
    ordered = default_passes()
    extras = sorted(set(all_passes()) - set(ordered))
    return ordered + extras


def select_passes(g: Graph, *, names: Optional[Sequence[str]] = None,
                  profile: Optional[HardwareProfile] = None,
                  signature: str = "") -> ScheduleDecision:
    """Cost-driven clustering: greedily keep each candidate rewrite iff it
    wins predicted HBM traffic.  Mutates and returns a decision over ``g``
    (passes rewrite in place, like :func:`run_passes`)."""
    prof = profile if profile is not None else get_profile()
    unfused = estimate_graph(g, profile=prof)
    kept: List[str] = []
    decisions: List[PassDecision] = []
    traffic = unfused.intermediate_traffic
    n_nodes = unfused.n_nodes
    for name in candidate_passes(names):
        g = get_pass(name)(g)
        rep = memory_report(g)
        win = (rep.intermediate_traffic < traffic
               or (rep.intermediate_traffic == traffic
                   and rep.n_nodes < n_nodes))
        decisions.append(PassDecision(
            name=name, kept=win,
            traffic_before=traffic, traffic_after=rep.intermediate_traffic,
            nodes_before=n_nodes, nodes_after=rep.n_nodes))
        # a rewrite with no predicted win leaves the graph unchanged (fusion
        # only ever *removes* intermediates, each worth > 0 traffic), so
        # "drop" and "keep" coincide on the graph — only the schedule
        # artifact records the drop
        if win:
            kept.append(name)
        traffic, n_nodes = rep.intermediate_traffic, rep.n_nodes
    return ScheduleDecision(
        graph_name=g.name, signature=signature, passes=kept,
        decisions=decisions, unfused=unfused,
        fused=estimate_graph(g, profile=prof))


def per_pass_table(decision: ScheduleDecision) -> List[Dict]:
    """JSON-ready audit rows (benchmarks and ``--explain`` consumers)."""
    return [dataclasses.asdict(d) for d in decision.decisions]
