"""``repro.cost`` — the unified analytic cost model.

The paper's claim is won or lost on *memory access frequency*, yet until
this package the repo decided its schedules with three oracles that never
talked: the empirical autotuner (``repro.bench``), the analytic roofline
(``repro.roofline``), and the graph memory planner (``repro.graph.plan``).
``repro.cost`` is the layer that unifies them:

* :mod:`repro.cost.model` — per-kernel pricing: FLOPs + HBM traffic +
  VMEM occupancy per :class:`~repro.bench.config.BlockConfig`, over named
  :class:`~repro.roofline.hw.HardwareProfile`\\ s (``tpu_v5e`` default,
  ``cpu_interpret`` for the CI path; ``$REPRO_HW_PROFILE`` selects).
  The autotuner ranks each tune space with this and times only the
  cheapest-predicted top-K (exhaustive stays the fallback and the
  correctness oracle gate is unchanged); ``BENCH_kernels.json`` records
  predicted-vs-measured error per family, continuously validating the
  model against the sweep it prunes.
* :mod:`repro.cost.graph` — graph-level pricing: any candidate fusion
  clustering is priced by predicted intermediate-HBM traffic via
  :func:`repro.graph.plan.memory_report`; :func:`select_passes` keeps a
  rewrite only if the model predicts a traffic win, replacing the fixed
  pass-order heuristic with an audited :class:`ScheduleDecision`.
* :mod:`repro.cost.schedule` — whole-graph schedule caching: the chosen
  pass subset persists in the same :class:`~repro.bench.config.ConfigCache`
  as tuned kernel tiles, keyed by a stable graph signature, so serve
  engines warm schedules exactly like block configs.

:func:`plan_graph` is the one-call entry the graph compiler uses:
signature -> cache lookup -> (on miss) cost-driven selection -> store.

Docs: ``docs/cost_model.md`` (model terms, profile table, pruning
contract, schedule-cache key).
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..graph.ir import Graph
from ..graph.passes import run_passes
from ..roofline.hw import (HardwareProfile, all_profiles,  # noqa: F401
                           get_profile, register_profile)
from .graph import (GraphCostEstimate, PassDecision,  # noqa: F401
                    ScheduleDecision, candidate_passes, estimate_graph,
                    per_pass_table, select_passes)
from .model import (OVERLAP_LEAK, CostEstimate, combine_times,  # noqa: F401
                    estimate_kernel, rank_candidates)
from .schedule import (SCHEDULE_KERNEL, graph_signature,  # noqa: F401
                       lookup_schedule, store_schedule)


def plan_graph(g: Graph, *, profile: Optional[HardwareProfile] = None,
               names: Optional[Sequence[str]] = None,
               cache=None, use_cache: bool = True) -> ScheduleDecision:
    """Fuse ``g`` under the cost model, consulting the schedule cache.

    On a cache hit the stored kept-pass subset is replayed (no per-pass
    re-estimation); on a miss :func:`select_passes` derives the schedule
    and persists it.  Mutates ``g`` like :func:`run_passes` and returns
    the :class:`ScheduleDecision` (``.cached`` marks hits).
    """
    prof = profile if profile is not None else get_profile()
    sig = graph_signature(g)
    if use_cache and names is None:
        cached = lookup_schedule(sig, cache)
        if cached is not None:
            unfused = estimate_graph(g, profile=prof)
            g = run_passes(g, cached)
            return ScheduleDecision(
                graph_name=g.name, signature=sig, passes=list(cached),
                decisions=[], unfused=unfused,
                fused=estimate_graph(g, profile=prof), cached=True)
    decision = select_passes(g, names=names, profile=prof, signature=sig)
    if use_cache and names is None:
        store_schedule(decision, cache)
    return decision
