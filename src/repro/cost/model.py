"""Per-kernel analytic cost model: price a ``BlockConfig`` before timing it.

The model combines the three quantities every :class:`~repro.bench.registry
.KernelSpec` already declares — analytic FLOPs, analytic HBM traffic at a
given block configuration, and (optionally) the VMEM tile footprint — with
a named :class:`~repro.roofline.hw.HardwareProfile`:

    t_compute = flops / profile.peak_flops
    t_memory  = hbm_bytes / profile.hbm_bw
    predicted = max(t_compute, t_memory) + OVERLAP_LEAK * min(t_c, t_m)

The ``OVERLAP_LEAK`` term models imperfect compute/memory overlap.  It also
makes the prediction *strictly* monotone in traffic at fixed FLOPs (and
vice versa) — exact roofline ``max()`` would tie every candidate on the
flat side of the ridge, and a pruner that cannot order candidates prunes
arbitrarily.  Monotonicity is a tested contract
(``tests/test_cost.py::test_monotone_in_traffic``).

Candidates whose tile footprint exceeds the profile's VMEM ceiling get a
multiplicative spill penalty proportional to the overflow: they are not
declared illegal (the kernel wrapper may still legalise them) but they sink
to the bottom of the ranking, which is where a spilling tile belongs.

Consumers: :func:`repro.bench.autotune.autotune` ranks a tune space with
:func:`rank_candidates` and times only the cheapest-predicted top-K;
``BENCH_kernels.json`` records predicted-vs-measured error per family so
the model is continuously validated against the empirical sweep.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from ..roofline.hw import HardwareProfile, get_profile

#: Fraction of the non-dominant roofline term charged on top of the
#: dominant one (imperfect overlap).  Strictly positive by contract — see
#: the module docstring.
OVERLAP_LEAK = 0.15

#: Spill penalty per byte of VMEM overflow, relative to the ceiling.
SPILL_PENALTY = 4.0


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Analytic price of running one kernel candidate once."""

    kernel: str
    flops: float
    hbm_bytes: float
    t_compute_s: float
    t_memory_s: float
    predicted_s: float
    vmem_bytes: Optional[int]      # tile footprint, None if unmodelled
    vmem_ok: bool                  # footprint fits the profile's ceiling
    profile: str                   # HardwareProfile name used

    @property
    def predicted_us(self) -> float:
        return self.predicted_s * 1e6

    @property
    def dominant(self) -> str:
        return "compute" if self.t_compute_s >= self.t_memory_s else "memory"

    @property
    def intensity(self) -> float:
        """Arithmetic intensity, FLOP per HBM byte."""
        return self.flops / max(self.hbm_bytes, 1.0)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["predicted_us"] = self.predicted_us
        d["dominant"] = self.dominant
        return d


def combine_times(t_compute: float, t_memory: float) -> float:
    """The roofline-with-leak combination (shared with the graph model)."""
    hi, lo = max(t_compute, t_memory), min(t_compute, t_memory)
    return hi + OVERLAP_LEAK * lo


def estimate_kernel(spec, shape, config, *,
                    profile: Optional[HardwareProfile] = None) -> CostEstimate:
    """Price one ``(spec, shape, config)`` candidate on ``profile``.

    ``spec`` is a :class:`~repro.bench.registry.KernelSpec` (duck-typed:
    anything with ``flops``/``hbm_bytes``/optional ``vmem_bytes``).
    """
    prof = profile if profile is not None else get_profile()
    flops = float(spec.flops(shape))
    hbm = float(spec.hbm_bytes(shape, config))
    t_c = flops / prof.peak_flops
    t_m = hbm / prof.hbm_bw
    predicted = combine_times(t_c, t_m)

    vmem = None
    vmem_ok = True
    vmem_model = getattr(spec, "vmem_bytes", None)
    if vmem_model is not None:
        vmem = int(vmem_model(shape, config))
        if vmem > prof.vmem_bytes:
            vmem_ok = False
            overflow = (vmem - prof.vmem_bytes) / prof.vmem_bytes
            predicted *= 1.0 + SPILL_PENALTY * overflow
    return CostEstimate(
        kernel=getattr(spec, "name", "?"),
        flops=flops, hbm_bytes=hbm,
        t_compute_s=t_c, t_memory_s=t_m, predicted_s=predicted,
        vmem_bytes=vmem, vmem_ok=vmem_ok, profile=prof.name,
    )


def rank_candidates(spec, shape, candidates: Sequence,
                    *, profile: Optional[HardwareProfile] = None,
                    ) -> List[Tuple[object, CostEstimate]]:
    """Order ``candidates`` cheapest-predicted first.

    The sort is stable on the original candidate order (predicted-cost
    ties keep the tune space's enumeration order), so pruning is
    deterministic run to run.
    """
    prof = profile if profile is not None else get_profile()
    priced = [(cfg, estimate_kernel(spec, shape, cfg, profile=prof))
              for cfg in candidates]
    return sorted(priced, key=lambda ce: ce[1].predicted_s)
