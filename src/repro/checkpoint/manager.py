"""Atomic, versioned, elastic checkpointing.

Layout:
    <dir>/step_<N>/manifest.json        tree-def, shapes, dtypes, metadata
    <dir>/step_<N>/<flat-key>.npy       one array per leaf
    <dir>/LATEST                        committed pointer (atomic rename)

Guarantees:
* **atomicity** — a checkpoint directory is staged under ``.tmp-...`` and
  renamed into place; LATEST is updated last, also by rename.  A crash at
  any point leaves the previous checkpoint intact.
* **elasticity** — the manifest stores *logical* (global) shapes; restore
  re-slices onto whatever mesh/sharding the restoring job passes (512 -> 256
  chips restores fine; tested 8 -> 4).
* **async** — ``save_async`` snapshots to host memory synchronously (one
  device->host copy) and writes in a background thread, overlapping the
  next training steps; ``wait()`` joins before the next save.
* **retention** — keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _rebuild_like(target, flat, prefix=""):
    """Rebuild ``target``'s structure from the flat key->array dict (walks
    exactly like _flatten, so ordering concerns never arise)."""
    if isinstance(target, dict):
        return {k: _rebuild_like(v, flat, f"{prefix}{k}/") for k, v in target.items()}
    if isinstance(target, tuple) and hasattr(target, "_fields"):  # NamedTuple
        vals = [_rebuild_like(v, flat, f"{prefix}{i}/") for i, v in enumerate(target)]
        return type(target)(*vals)
    if isinstance(target, (list, tuple)) and not hasattr(target, "shape"):
        vals = [_rebuild_like(v, flat, f"{prefix}{i}/") for i, v in enumerate(target)]
        return type(target)(vals) if isinstance(target, list) else tuple(vals)
    return flat[prefix.rstrip("/")]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        pointer = os.path.join(self.dir, "LATEST")
        if not os.path.exists(pointer):
            return None
        with open(pointer) as f:
            return int(f.read().strip())

    def all_steps(self):
        return sorted(
            int(d.split("_")[1]) for d in os.listdir(self.dir)
            if d.startswith("step_")
        )

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> str:
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host, metadata or {})

    def save_async(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot now
        meta = dict(metadata or {})
        self._thread = threading.Thread(
            target=self._write, args=(step, host, meta), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, metadata: Dict) -> str:
        flat = _flatten(host_tree)
        final = self._step_dir(step)
        tmp = os.path.join(self.dir, f".tmp-{step}-{os.getpid()}-{time.time_ns()}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "metadata": metadata, "arrays": {}}
        for key, arr in flat.items():
            arr = np.asarray(arr)
            logical_dtype = str(arr.dtype)
            if logical_dtype == "bfloat16":
                # np.load cannot reconstruct ml_dtypes dtypes; store the raw
                # bits and re-view on restore (manifest keeps the truth).
                arr = arr.view(np.uint16)
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["arrays"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": logical_dtype,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        ptr_tmp = os.path.join(self.dir, f".LATEST-{time.time_ns()}")
        with open(ptr_tmp, "w") as f:
            f.write(str(step))
        os.rename(ptr_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, *, shardings: Any = None,
                target: Any = None):
        """Load arrays; optionally re-place onto ``shardings`` (a pytree of
        NamedSharding matching ``target``'s structure) — this is the elastic
        path: the stored global arrays are resharded for the new mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        def load_one(info):
            raw = np.load(os.path.join(d, info["file"]))
            if info["dtype"] == "bfloat16":
                import ml_dtypes
                raw = raw.view(ml_dtypes.bfloat16)
            return raw

        flat = {key: load_one(info)
                for key, info in manifest["arrays"].items()}
        if target is None:
            return flat, manifest["metadata"]
        flat_target = _flatten(target)
        assert set(flat_target) == set(flat), (
            sorted(set(flat_target) ^ set(flat))[:5])
        if shardings is not None:
            flat_sh = _flatten(shardings)
            restored = {
                k: jax.device_put(flat[k], flat_sh[k]) for k in flat_target
            }
        else:
            restored = {k: jax.numpy.asarray(flat[k]) for k in flat_target}
        return _rebuild_like(target, restored), manifest["metadata"]
