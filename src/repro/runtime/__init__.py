from .fault_tolerance import (FaultInjector, Heartbeat, RunReport,  # noqa: F401
                              StragglerDetector, TrainController)
from .elastic import build_mesh, remesh_restore  # noqa: F401
