"""Elastic scaling: rebuild the mesh at a different size and reshard state.

A 512-chip job that loses a pod restores its last checkpoint onto the
remaining 256 chips: the checkpoint stores logical (global) arrays, the new
mesh supplies new NamedShardings, and ``CheckpointManager.restore`` placing
does the re-slicing.  Tested at toy scale (8 -> 4 host devices) in
tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..checkpoint import CheckpointManager
from ..parallel.sharding import ParallelContext, make_context


def build_mesh(n_devices: Optional[int] = None, *, model_parallel: int = 1,
               pods: int = 1) -> Mesh:
    """Largest mesh that fits the currently-healthy device set."""
    devs = jax.devices()
    n = n_devices or len(devs)
    devs = devs[:n]
    data = n // (model_parallel * pods)
    assert data >= 1 and data * model_parallel * pods == n, (n, model_parallel, pods)
    arr = np.array(devs).reshape(
        (pods, data, model_parallel) if pods > 1 else (data, model_parallel))
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return Mesh(arr, names)


def remesh_restore(
    ckpt: CheckpointManager,
    target: Any,
    spec_tree: Any,
    new_mesh: Mesh,
    step: Optional[int] = None,
) -> Tuple[Any, dict, ParallelContext]:
    """Restore ``target``-shaped state onto ``new_mesh`` (elastic restart)."""
    pctx = make_context(new_mesh)
    shardings = jax.tree.map(
        lambda spec: NamedSharding(new_mesh, spec), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    state, meta = ckpt.restore(step, shardings=shardings, target=target)
    return state, meta, pctx
