"""Fault-tolerant step-loop controller for 1000+-node operation.

Responsibilities (all host-side policy — the pieces a real cluster agent
drives):

* **heartbeat**: a monotonically advancing (step, wall-time) record written
  after every step; an external watchdog (or the elastic controller) treats
  a stale heartbeat as a hung/failed worker.
* **checkpoint/restart**: periodic async checkpoints; on a step failure the
  controller retries, and after ``max_retries`` restores the latest
  checkpoint and continues (simulated fault injection in tests).
* **straggler mitigation**: per-step wall-time EWMA + MAD outlier detection;
  a sustained straggle raises a re-plan signal (drop to checkpoint and
  re-mesh without the slow host — the mesh rebuild is the elastic path).
* **elastic re-mesh**: ``ElasticController.remesh`` rebuilds the context for
  a different device count and reshards the restored state onto it.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

from ..checkpoint import CheckpointManager
from ..data.pipeline import DataCursor


class FaultInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_steps=(), exc=RuntimeError):
        self.fail_steps = set(fail_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_steps and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected fault at step {step}")


@dataclasses.dataclass
class StragglerDetector:
    """EWMA + MAD step-time outlier detection."""
    window: int = 32
    threshold: float = 3.0       # MADs above median = straggle
    sustained: int = 3           # consecutive outliers before re-plan

    def __post_init__(self):
        self.times = deque(maxlen=self.window)
        self.consecutive = 0

    def observe(self, dt: float) -> bool:
        """Returns True when a sustained straggle is detected."""
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            mad = sorted(abs(t - med) for t in self.times)[len(self.times) // 2]
            if dt > med + self.threshold * max(mad, 1e-6, 0.05 * med):
                self.consecutive += 1
            else:
                self.consecutive = 0
        self.times.append(dt)
        return self.consecutive >= self.sustained


class Heartbeat:
    def __init__(self, path: str):
        self.path = path

    def beat(self, step: int, **info):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time(), **info}, f)
        os.rename(tmp, self.path)

    def read(self) -> Optional[Dict]:
        if not os.path.exists(self.path):
            return None
        with open(self.path) as f:
            return json.load(f)

    def is_stale(self, timeout_s: float) -> bool:
        hb = self.read()
        return hb is None or (time.time() - hb["time"]) > timeout_s


@dataclasses.dataclass
class RunReport:
    steps_completed: int
    restarts: int
    retries: int
    straggle_events: int
    losses: list


class TrainController:
    """Wraps a step function with heartbeat / retry / restore / straggler
    policy.  ``state`` is any pytree holding (params, opt_state, ...)."""

    def __init__(
        self,
        step_fn: Callable,                # (state, batch, step) -> (state, metrics)
        ckpt: CheckpointManager,
        *,
        ckpt_every: int = 50,
        max_retries: int = 1,
        heartbeat_path: Optional[str] = None,
        injector: Optional[FaultInjector] = None,
        straggler: Optional[StragglerDetector] = None,
        on_straggle: Optional[Callable] = None,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.heartbeat = Heartbeat(heartbeat_path) if heartbeat_path else None
        self.injector = injector
        self.straggler = straggler or StragglerDetector()
        self.on_straggle = on_straggle

    def run(self, state: Any, source, cursor: DataCursor,
            num_steps: int) -> (Any, RunReport):
        restarts = retries = straggles = 0
        losses = []
        step = cursor.step
        end = step + num_steps
        while step < end:
            batch = source.batch_at(step)
            t0 = time.time()
            try:
                if self.injector:
                    self.injector.maybe_fail(step)
                state, metrics = self.step_fn(state, batch, step)
            except Exception:
                retries += 1
                if retries <= self.max_retries:
                    continue  # transient: retry same step
                # fatal: restore from latest checkpoint
                self.ckpt.wait()  # never race an in-flight async write
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise
                state, meta = self.ckpt.restore(latest, target=state)
                step = meta["cursor"]["step"]
                cursor.step = step
                restarts += 1
                retries = 0
                continue
            retries = 0
            dt = time.time() - t0
            losses.append(float(metrics.get("loss", 0.0)))
            if self.straggler.observe(dt):
                straggles += 1
                if self.on_straggle:
                    self.on_straggle(step, dt)
            if self.heartbeat:
                self.heartbeat.beat(step, loss=losses[-1])
            step += 1
            cursor.step = step
            if step % self.ckpt_every == 0:
                self.ckpt.save_async(step, state,
                                     metadata={"cursor": cursor.to_dict()})
        self.ckpt.wait()
        return state, RunReport(
            steps_completed=num_steps, restarts=restarts, retries=retries,
            straggle_events=straggles, losses=losses,
        )
