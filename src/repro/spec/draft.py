"""Draft proposers for speculative decoding.

A proposer's job each speculative tick: given the committed token history of
every DECODING request, propose up to ``k`` continuation tokens per slot for
the target model to verify in one batched forward.  Two implementations:

* :class:`NgramDraft` — self-drafting fallback (no second model): propose
  the continuation of the longest recent n-gram match in the request's own
  history.  Free (pure host), surprisingly strong on the repetitive tails
  greedy decoding produces, and the default when no draft config is
  registered for the target arch.
* :class:`ModelDraft` — a small paired model (``repro.configs.DRAFT_FOR``,
  validated by ``repro.models.registry.check_draft_pair``) running its own
  paged KV cache in lockstep with the target: catch-up tokens are prefilled
  in chunks, proposals are generated with batched T=1 ``decode_paged``
  steps, and rejected proposals are rolled back with the same
  ``PagedKVCache.truncate`` primitive the target cache uses.

The engine talks to proposers through four hooks (``admit`` / ``propose`` /
``observe`` / ``release``); acceptance bookkeeping lives on the engine and
the :class:`~repro.serve.scheduler.Request`, not here.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..models.registry import ModelBundle
from ..parallel.sharding import ParallelContext
from ..serve.paged_cache import OutOfPages, PagedKVCache
from ..serve.scheduler import Request

#: one verify plan entry: (slot, request, k proposals wanted)
PlanEntry = Tuple[int, Request, int]


class DraftProposer:
    """Base proposer: lifecycle hooks are no-ops, ``propose`` is abstract.

    ``propose`` returns ``{slot: [tokens...]}``; a slot may receive *fewer*
    than ``k`` proposals (down to zero — the engine then verifies just the
    pending token, which is exactly a plain decode step), so a proposer can
    always degrade instead of failing.
    """

    def admit(self, slot: int, req: Request) -> None:
        """A request was placed in ``slot`` (fresh or after preemption)."""

    def release(self, slot: int) -> None:
        """``slot``'s request finished or was preempted; drop its state."""

    def observe(self, slot: int, req: Request, new_len: int) -> None:
        """Post-verify: the target cache was truncated to ``new_len`` KV
        entries; bring any draft-side state back in sync."""

    def propose(self, plan: Sequence[PlanEntry]) -> Dict[int, List[int]]:
        raise NotImplementedError


class NgramDraft(DraftProposer):
    """Self-drafting n-gram proposer.

    For each request, find the longest ``n <= max_n`` suffix of its history
    (prompt + generated tokens, last token = the pending one) that occurred
    earlier in the same history, and propose the tokens that followed that
    earlier occurrence.  Greedy decoding of a converged model frequently
    revisits patterns (and eventually cycles), so copied continuations are
    accepted at high rates exactly when plain decode is at its most
    wasteful; with no match the last token is repeated, which still wins on
    period-1 tails and costs nothing when rejected.
    """

    def __init__(self, max_n: int = 4):
        if max_n < 1:
            raise ValueError("max_n must be >= 1")
        self.max_n = max_n

    def propose(self, plan: Sequence[PlanEntry]) -> Dict[int, List[int]]:
        return {slot: self._continue(req.prompt + req.output, k)
                for slot, req, k in plan}

    def _continue(self, hist: List[int], k: int) -> List[int]:
        if k <= 0 or not hist:
            return []
        for n in range(min(self.max_n, len(hist) - 1), 0, -1):
            pattern = hist[-n:]
            # newest earlier occurrence of the suffix (rightmost match whose
            # continuation is still inside the history)
            for j in range(len(hist) - n - 1, -1, -1):
                if hist[j:j + n] == pattern:
                    # copy forward from the match; once the copy runs past
                    # the end of the history it continues over the proposals
                    # themselves, so a period-p cycle extends as the cycle
                    # (not as a smeared final token)
                    virtual = list(hist)
                    out: List[int] = []
                    for i in range(k):
                        out.append(virtual[j + n + i])
                        virtual.append(out[-1])
                    return out
        return [hist[-1]] * k


class ModelDraft(DraftProposer):
    """Small paired draft model with its own paged KV cache.

    The draft cache mirrors the target's committed state (``C - 1`` entries
    when the request has ``C`` committed tokens, the pending token not yet
    written — the same off-by-one the target keeps).  Each tick:

    1. *Catch-up*: chunked prefill of committed tokens the draft has not
       seen (one token after a fully-accepted step, the whole prompt after
       admit/preemption recompute).
    2. *Generate*: ``k`` batched T=1 ``decode_paged`` steps — feed the
       pending token, then each of its own proposals, collecting argmaxes.
    3. *Rollback* (``observe``): truncate the draft cache to the verified
       length, exactly as the engine truncates the target cache.

    On ``OutOfPages`` the slot's draft state is dropped and no proposals are
    returned for it this tick (the engine degrades to plain decode there).
    """

    def __init__(self, bundle: ModelBundle, params, pctx: ParallelContext,
                 *, slots: int, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_pages_per_slot: Optional[int] = None,
                 chunk: int = 16, kv_dtype: str = "bfloat16"):
        import jax

        if not bundle.supports_paged_kv:
            raise ValueError(
                f"{bundle.cfg.family!r} draft family has no paged KV cache")
        self.bundle = bundle
        self.params = params
        self.slots = slots
        self.page_size = page_size
        self.chunk = chunk
        if num_pages is None:
            num_pages = slots * max(256 // page_size, 1)
        if max_pages_per_slot is None:
            max_pages_per_slot = min(num_pages, max(256 // page_size, 1))
        self.kv = PagedKVCache(slots=slots, num_pages=num_pages,
                               page_size=page_size,
                               max_pages_per_slot=max_pages_per_slot)
        self.cache = bundle.init_paged_cache(self.kv.pool_pages, page_size,
                                             kv_dtype=kv_dtype)
        # one jit covers T=1 generation and T=chunk catch-up (shapes differ)
        self._step = jax.jit(
            lambda p, c, t, l, n, bt: bundle.decode_paged(p, c, t, l, n, bt,
                                                          pctx))

    # -- lifecycle --------------------------------------------------------
    def admit(self, slot: int, req: Request) -> None:
        self.kv.free_slot(slot)   # fresh slate; full catch-up on first tick

    def release(self, slot: int) -> None:
        self.kv.free_slot(slot)

    def observe(self, slot: int, req: Request, new_len: int) -> None:
        # After full acceptance the draft is one token *behind* the target
        # (it never fed the last proposal); never truncate upward.
        self.kv.truncate(slot, min(self.kv.length(slot), new_len))

    # -- proposing --------------------------------------------------------
    def _sync_all(self, entries: List[Tuple[int, List[int]]]) -> set:
        """Chunk-prefill every listed slot's draft cache up to
        ``len(committed) - 1`` entries (everything but the pending token),
        batched across slots — one ``(slots, chunk)`` forward per round,
        idle slots masked via ``new_counts = 0``.  In steady state the gap
        is at most one token (the unfed last proposal after a fully
        accepted step), so this is a single call per tick shared by all
        slots.  Returns the slots dropped on ``OutOfPages``."""
        import jax.numpy as jnp

        failed: set = set()
        while True:
            toks = np.zeros((self.slots, self.chunk), np.int32)
            counts = np.zeros((self.slots,), np.int32)
            for slot, committed in entries:
                if slot in failed:
                    continue
                pos = self.kv.length(slot)
                n = min(self.chunk, len(committed) - 1 - pos)
                if n <= 0:
                    continue
                try:
                    self.kv.allocate(slot, pos + n)
                except OutOfPages:
                    self.kv.free_slot(slot)   # full resync next time it fits
                    failed.add(slot)
                    continue
                toks[slot, :n] = committed[pos:pos + n]
                counts[slot] = n
            if not counts.any():
                return failed
            lengths = np.array([self.kv.length(s) for s in range(self.slots)],
                               np.int32)
            _, self.cache = self._step(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(lengths), jnp.asarray(counts),
                jnp.asarray(self.kv.block_tables))
            for slot in np.flatnonzero(counts):
                self.kv.commit(slot, int(lengths[slot] + counts[slot]))

    def propose(self, plan: Sequence[PlanEntry]) -> Dict[int, List[int]]:
        import jax.numpy as jnp

        committed = {slot: req.prompt + req.output for slot, req, _ in plan}
        failed = self._sync_all([(s, c) for s, c in committed.items()])
        out: Dict[int, List[int]] = {}
        live: List[Tuple[int, int]] = []          # (slot, k)
        feed = np.zeros((self.slots, 1), np.int32)
        for slot, req, k in plan:
            if slot in failed:
                continue
            try:
                # room for the pending token + k-1 written proposals
                self.kv.allocate(slot, len(committed[slot]) - 1 + max(k, 1))
            except OutOfPages:
                self.kv.free_slot(slot)   # full resync next time it fits
                continue
            out[slot] = []
            if k > 0:
                live.append((slot, k))
                feed[slot, 0] = committed[slot][-1]
        for j in range(max((k for _, k in live), default=0)):
            counts = np.zeros((self.slots,), np.int32)
            for slot, k in live:
                if j < k:
                    counts[slot] = 1
            lengths = np.array([self.kv.length(s) for s in range(self.slots)],
                               np.int32)
            logits, self.cache = self._step(
                self.params, self.cache, jnp.asarray(feed),
                jnp.asarray(lengths), jnp.asarray(counts),
                jnp.asarray(self.kv.block_tables))
            greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for slot, k in live:
                if j < k:
                    self.kv.commit(slot, self.kv.length(slot) + 1)
                    tok = int(greedy[slot])
                    out[slot].append(tok)
                    feed[slot, 0] = tok
        return out
