"""Speculative decoding on the paged serving engine.

:class:`SpeculativeServeEngine` replaces the one-token decode tick of
:class:`~repro.serve.engine.PagedServeEngine` with a draft-and-verify step
that attacks the same bound the APR attacks at kernel level — work per
memory pass.  Plain decode streams the target model's weights once per
generated token; a speculative step streams them once per *verify batch*:
a draft proposes ``k`` tokens, the target scores all ``k + 1`` positions
(pending token + proposals) in ONE batched ``decode_paged`` forward, and
greedy verification accepts the longest proposal prefix that matches the
target's own argmaxes, plus one bonus token from the target itself.  Every
accepted token amortises the weight stream; every rejected token costs a
host-side rollback — ``PagedKVCache.truncate`` drops the KV page suffix,
and on recurrent-state families (rwkv6/mamba2/zamba2) the paired
``StateCache`` checkpoint written by the verify forward is restored in the
same ``_truncate_slot`` call, so KV pages and state roll back atomically.

Token-identity guarantee: row ``i`` of the verify logits is computed from
exactly the state the plain engine would have after emitting the first
``i`` tokens (the paged cache holds the same KV at the same positions, the
causal-within-chunk mask exposes the same prefix), and emission stops at
the first position where the proposal disagrees with the target's argmax —
substituting the argmax itself.  Greedy outputs therefore match the plain
engine token for token, at any acceptance rate, for any proposer (an empty
proposal degrades a slot to a plain decode step).  The guarantee is gated
in CI by ``benchmarks/bench_spec.py --quick``.

Everything below the tick is inherited unchanged: pages, chunked prefill,
FIFO admission, preemption-with-recompute (the draft is notified through
its ``admit``/``release`` hooks and recovers by re-syncing), int8 KV
(``kv_dtype="int8"`` — rollback leaves stale payload+scale slots that are
masked by length and rewritten in lockstep, see ``docs/quantization.md``).
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..bench.specs import spec_verify_shapes
from ..models.registry import ModelBundle, check_draft_pair
from ..parallel.sharding import ParallelContext
from ..serve.engine import PagedServeEngine
from ..serve.scheduler import DECODING, DONE, Request
from ..serve.state_cache import TRASH_STATE
from .draft import DraftProposer, ModelDraft, NgramDraft


class SpeculativeServeEngine(PagedServeEngine):
    """Draft-and-verify continuous batching over the paged KV cache.

    ``draft`` is any :class:`~repro.spec.draft.DraftProposer`; with
    ``draft=None`` an :class:`NgramDraft` self-drafting fallback is used.
    ``spec_k`` is the per-slot proposal budget (``spec_k=0`` degenerates to
    the plain engine, one verify row per slot); ``verify_budget`` caps the
    verify rows one tick may spend across slots (see
    :meth:`repro.serve.scheduler.FifoScheduler.verify_plan`).
    """

    def __init__(self, bundle: ModelBundle, params, pctx: ParallelContext,
                 *, spec_k: int = 4, draft: Optional[DraftProposer] = None,
                 draft_bundle: Optional[ModelBundle] = None,
                 draft_params=None, verify_budget: Optional[int] = None,
                 **kwargs):
        if spec_k < 0:
            raise ValueError("spec_k must be >= 0")
        self.spec_k = spec_k  # set before super().__init__ warms kernels
        super().__init__(bundle, params, pctx, **kwargs)
        self.sched.verify_budget = verify_budget
        if draft is not None and draft_bundle is not None:
            raise ValueError("pass either draft= or draft_bundle=, not both")
        if draft_bundle is not None:
            check_draft_pair(bundle.cfg, draft_bundle.cfg)
            draft = ModelDraft(draft_bundle, draft_params, pctx,
                               slots=self.slots, page_size=self.page_size,
                               num_pages=self.kv.num_pages,
                               max_pages_per_slot=self.kv.max_pages_per_slot,
                               chunk=self.prefill_chunk,
                               kv_dtype=self.kv_dtype)
        self.draft: DraftProposer = draft if draft is not None else NgramDraft()
        self._verify = self._decode  # same jit fn; shapes (slots, spec_k+1)

    def _decode_kernel_shapes(self):
        """Plain decode shapes plus the widened verify-batch GEMM (the
        verify attention reuses the already-warm paged family)."""
        return (super()._decode_kernel_shapes()
                + spec_verify_shapes(self.bundle.cfg, self.slots, self.spec_k))

    # -- draft lifecycle hooks -------------------------------------------
    def _on_admit(self, slot: int, req: Request) -> None:
        self.draft.admit(slot, req)

    def _preempt(self, req: Request) -> None:
        slot = req.slot
        super()._preempt(req)
        self.draft.release(slot)

    def _finish(self, req: Request) -> None:
        slot = req.slot
        super()._finish(req)
        self.draft.release(slot)

    # -- the speculative tick --------------------------------------------
    def _decode_tick(self) -> None:
        decoding = [r for r in self._active_requests() if r.state == DECODING]
        if not decoding:
            return
        plan = self.sched.verify_plan(decoding, self.spec_k)
        # Reserve pages for the worst case (k proposals + the pending token
        # all written) before drafting; reservation may preempt a younger
        # sibling that is itself in the plan, so re-check liveness after.
        alive: List[Tuple[Request, int]] = []
        for req, k in plan:
            if self.active[req.slot] is not req or req.state != DECODING:
                continue
            if self._ensure_pages(req, self.kv.length(req.slot) + k + 1):
                alive.append((req, k))
        alive = [(r, k) for r, k in alive
                 if self.active[r.slot] is r and r.state == DECODING]
        self._sync_page_copies()   # reservation may have COW-split a shared
                                   # boundary page (prefix sharing)
        if not alive:
            return

        t0 = time.perf_counter()
        proposals = self.draft.propose(
            [(r.slot, r, k) for r, k in alive])
        self.metrics.draft_time_s += time.perf_counter() - t0

        t_verify = self.spec_k + 1
        tokens = np.zeros((self.slots, t_verify), np.int32)
        counts = np.zeros((self.slots,), np.int32)
        props = {}
        lengths = np.array([self.kv.length(s) for s in range(self.slots)],
                           np.int32)
        if self.state is not None:
            # Recurrent state cannot drop a suffix: every verify position
            # writes its post-token state into a fresh ring checkpoint
            # (snapshot ids handed out empty, scattered into by the
            # forward), so the rollback below *restores* the checkpoint at
            # the accepted count instead of truncating.
            write_ids = np.full((self.slots, t_verify), TRASH_STATE,
                                np.int32)
        else:
            write_ids = None
        for req, k in alive:
            p = [int(t) for t in proposals.get(req.slot, [])[:k]]
            props[req.slot] = p
            tokens[req.slot, 0] = self.last_tokens[req.slot]
            tokens[req.slot, 1:1 + len(p)] = p
            counts[req.slot] = 1 + len(p)
            if self.state is not None:
                for t in range(1 + len(p)):
                    write_ids[req.slot, t] = self.state.snapshot(
                        req.slot, int(lengths[req.slot]) + t + 1, copy=False)
        t0 = time.perf_counter()
        logits, self.cache = self._verify(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(lengths), jnp.asarray(counts),
            jnp.asarray(self._tables(range(self.slots), write_ids)))
        jax.block_until_ready(logits)
        self.metrics.decode_time_s += time.perf_counter() - t0
        greedy = np.asarray(jnp.argmax(logits, axis=-1))     # (slots, T)

        for req, _k in alive:
            slot = req.slot
            p = props[slot]
            # greedy[slot, i] is what plain decode would emit at position i;
            # accept proposals while they agree, then emit the target's own
            # token (correction on mismatch, bonus after full acceptance).
            emitted: List[int] = []
            accepted = 0
            for i, d in enumerate(p):
                g = int(greedy[slot, i])
                emitted.append(g)
                if g != d:
                    break
                accepted += 1
            else:
                emitted.append(int(greedy[slot, len(p)]))
            n_emitted = 0
            for tok in emitted:
                req.output.append(tok)
                self.last_tokens[slot] = tok
                self.metrics.decode_tokens += 1
                n_emitted += 1
                self._maybe_finish(req, tok)
                if req.state == DONE:
                    break     # later candidates are past eos/max_new
            # Acceptance is only credited for tokens that were actually
            # emitted: a proposal matching the target's argmax *past* an
            # eos/max_new stop produced nothing, and counting it would let
            # acceptance_rate disagree with tokens_per_step.
            accepted = min(accepted, n_emitted)
            self.metrics.spec_steps += 1
            req.spec_steps += 1
            self.metrics.draft_proposed += len(p)
            req.draft_proposed += len(p)
            self.metrics.draft_accepted += accepted
            req.draft_accepted += accepted
            if req.state == DONE:
                continue      # _finish freed the pages and the draft slot
            # Cache holds KV for the pending token + every *written*
            # proposal; only pending + accepted proposals are real.  The
            # last emitted token (correction/bonus) was never fed, so it is
            # the new pending token, exactly like a plain decode's output.
            # On state engines the same call atomically restores the
            # recurrent-state checkpoint at the accepted count.
            self._truncate_slot(slot, int(lengths[slot]) + 1 + accepted)
            self.draft.observe(slot, req, int(lengths[slot]) + 1 + accepted)
