"""Speculative decoding on the paged serving engine (draft-and-verify).

See ``docs/architecture.md`` for where this sits in the system and
``docs/serving.md`` for the engine it extends; the benchmark is
``benchmarks/bench_spec.py`` -> ``BENCH_spec.json``.
"""
from .draft import DraftProposer, ModelDraft, NgramDraft  # noqa: F401
from .engine import SpeculativeServeEngine  # noqa: F401
