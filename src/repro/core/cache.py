"""Analytic L1 cache model (paper Table II: 512 KB, 2-way, 64 B lines).

Two components:

* **D-side**: access count equals the number of memory-type instructions
  (that is the paper's definition of the mem column); miss counts come from
  a per-layer working-set sweep model.  The -O0 stack traffic hits a few
  hot lines and never misses; array traffic misses on first touch (cold)
  and, when a layer's streamed operand exceeds the cache, once per sweep
  (capacity).
* **I-side**: the in-order front end fetches ``fetch_bytes`` per L1I access
  along the fall-through path and issues one extra access per taken
  control-flow redirect, which reproduces gem5's "overall cache access"
  accounting on top of the D-side accesses.
"""
from __future__ import annotations

from dataclasses import dataclass

from .program import ConvLayer, FCLayer, Layer

LINE_BYTES = 64
L1_BYTES = 512 * 1024


@dataclass(frozen=True)
class CacheStats:
    d_accesses: int
    d_misses: int
    i_accesses: int

    @property
    def overall_accesses(self) -> int:
        return self.d_accesses + self.i_accesses


def data_misses(layer: Layer) -> int:
    """Cold + capacity misses for one layer's array traffic."""
    cold = (layer.input_bytes + layer.filter_bytes + layer.output_bytes) // LINE_BYTES
    capacity = 0
    if isinstance(layer, ConvLayer):
        # Per output filter i, the full input plane is re-swept; if the
        # input plane plus the filter block exceeds L1, each re-sweep
        # misses on the excess.
        ws = layer.input_bytes + layer.filter_bytes // max(layer.M, 1)
        if ws > L1_BYTES:
            capacity += (layer.M - 1) * ((ws - L1_BYTES) // LINE_BYTES)
        # Per output position, the filter bank row is re-read; only an issue
        # for enormous filter banks (pointwise convs with many channels).
        if layer.filter_bytes > L1_BYTES:
            sweeps = layer.Ho * layer.Wo
            capacity += (sweeps - 1) * ((layer.filter_bytes - L1_BYTES) // LINE_BYTES)
    else:
        if layer.filter_bytes > L1_BYTES:
            # Weight matrix streamed once (row per output) - no reuse sweeps.
            pass
    return cold + capacity


def instruction_accesses(
    instruction_bytes: int,
    redirects: int,
    fetch_bytes: int,
) -> int:
    """L1I accesses: sequential line-buffer fetches plus redirect fetches."""
    return instruction_bytes // fetch_bytes + redirects
