"""Frozen calibration constants for the Level-A simulator.

Fitted ONCE against the (LeNet, RV64F) row of paper Table III
(IC = 44,310,154; mem-type = 19,288,578; IPC = 0.666; L1 = 23,071,838)
by ``benchmarks/calibrate.py``, then held fixed for every other
(model, ISA) cell so that all cross-ISA and cross-model enhancements are
structural predictions, not fits.
"""
from .pipeline import PipelineParams
from .program import CodegenParams

CODEGEN = CodegenParams(
    spills_per_ref=1,
    mv_per_ref=0,
    extra_alu_per_mac=20,
    schedule_loads=True,
)

PIPELINE = PipelineParams(
    load_use_penalty=1,
    branch_penalty=2,
    jump_penalty=1,
    int_mul_latency=2,
    int_div_latency=12,
    fp_latency=8,
    l1_hit_cycles=2,
    l1_miss_penalty=80,
    fetch_bytes=40,
    instr_bytes=4,
)
