"""Loop-nest code generation for the three ISAs of the paper (Fig. 1).

The paper compiles the canonical 6-deep convolution loop nest with a
customised riscv-gnu-toolchain at -O0-like optimisation (the Fig. 1 assembly
re-computes every array address from stack-resident index variables each
iteration, which is why Table III shows ~100 dynamic instructions per MAC
for RV64F).  This module is that "compiler": it emits the same shape of
instruction stream for each ISA variant:

* ``RV64F``    (Fig. 1a): address(In)+flw, address(Fil)+flw, address(Out)+flw,
  ``fmul.s``, spill/reload of the product, ``fadd.s``, address(Out) again,
  ``fsw`` — the partial sum round-trips through memory every iteration.
* ``Baseline`` (Fig. 1b): same loads, single ``fmac.s``, and the output
  address is computed once (the inline-asm "+f" operand keeps it live).
* ``RV64R``    (Fig. 1c): address(In)+flw, address(Fil)+flw, ``rfmac.s`` —
  no output reference in the inner loop at all.  Once per output element,
  after the reduction loops close: address(Out), ``rfsmac.s``, ``fsw``.

Calibration knobs (``CodegenParams``) model -O0 stack-spill traffic and are
fitted ONCE against the (LeNet, RV64F) row of Table III, then held fixed for
every other (model, ISA) cell, so all *relative* enhancements are structural.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

from .isa import Instr, Isa, Kind


# ---------------------------------------------------------------------------
# Workload layer descriptions.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLayer:
    """One convolution layer: M filters of C x Hf x Wf over an input plane,
    producing Ho x Wo output positions (stride folded into Ho/Wo)."""

    name: str
    M: int      # output channels / number of filters
    C: int      # input channels seen by each filter (1 for depthwise)
    Ho: int
    Wo: int
    Hf: int
    Wf: int
    Hin: int = 0
    Win: int = 0
    stride: int = 1

    @property
    def macs(self) -> int:
        return self.M * self.Ho * self.Wo * self.C * self.Hf * self.Wf

    @property
    def outputs(self) -> int:
        return self.M * self.Ho * self.Wo

    @property
    def input_bytes(self) -> int:
        hin = self.Hin or (self.Ho * self.stride + self.Hf - 1)
        win = self.Win or (self.Wo * self.stride + self.Wf - 1)
        return self.C * hin * win * 4

    @property
    def filter_bytes(self) -> int:
        return self.M * self.C * self.Hf * self.Wf * 4

    @property
    def output_bytes(self) -> int:
        return self.outputs * 4


@dataclass(frozen=True)
class FCLayer:
    """Fully-connected layer: O outputs, each a reduction over I inputs."""

    name: str
    O: int
    I: int

    @property
    def macs(self) -> int:
        return self.O * self.I

    @property
    def outputs(self) -> int:
        return self.O

    @property
    def input_bytes(self) -> int:
        return self.I * 4

    @property
    def filter_bytes(self) -> int:
        return self.O * self.I * 4

    @property
    def output_bytes(self) -> int:
        return self.O * 4


Layer = ConvLayer | FCLayer


# ---------------------------------------------------------------------------
# Codegen parameters (calibrated once on LeNet/RV64F — see calibration.py).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CodegenParams:
    spills_per_ref: int = 2   # sw+lw stack round-trips per array reference
    mv_per_ref: int = 2       # register-shuffle ALU ops per array reference
    extra_alu_per_mac: int = 0  # residual -O0 noise (sext.w etc.)
    schedule_loads: bool = True  # cluster index loads ahead of the address
                                 # arithmetic (matches Fig. 1 assembly layout)


# ---------------------------------------------------------------------------
# Instruction-sequence builders.
# ---------------------------------------------------------------------------


def _lw(dst: str, comment: str = "") -> Instr:
    return Instr(Kind.LOAD, dst=dst, srcs=("sp",), comment=comment)


def _sw(src: str, comment: str = "") -> Instr:
    return Instr(Kind.STORE, srcs=(src, "sp"), comment=comment)


def _alu(dst: str, *srcs: str, comment: str = "") -> Instr:
    return Instr(Kind.ALU, dst=dst, srcs=srcs, comment=comment)


def _mul(dst: str, *srcs: str) -> Instr:
    return Instr(Kind.MUL, dst=dst, srcs=srcs)


def gen_addr(
    tag: str,
    idx_dims: Sequence[str],
    compound: Sequence[bool],
    divided: Sequence[bool],
    params: CodegenParams,
) -> Tuple[List[Instr], str]:
    """-O0-style flattened address computation for a multi-dim array ref.

    ``idx_dims[q]`` names the q-th index variable; ``compound[q]`` marks an
    index of the form (a+b) (e.g. ``j+m``); ``divided[q]`` marks ``j/S``.
    Returns (instructions, address register name).
    """
    loads: List[Instr] = []
    arith: List[Instr] = []
    acc = f"{tag}_acc"
    for q, var in enumerate(idx_dims):
        v = f"{tag}_i{q}"
        loads.append(_lw(v, f"lw {var}"))
        if compound[q]:
            v2 = f"{tag}_i{q}b"
            loads.append(_lw(v2, f"lw {var}(+)"))
            arith.append(_alu(v, v, v2, comment="compound add"))
        if divided[q]:
            # -O0 keeps the stride S in a stack slot and emits a real div
            # (no strength reduction), serialising the address chain.
            s = f"{tag}_s{q}"
            loads.append(_lw(s, "lw S"))
            arith.append(Instr(Kind.DIV, dst=v, srcs=(v, s), comment="div /S"))
        if q == 0:
            arith.append(_alu(acc, v, comment="mv acc"))
        else:
            d = f"{tag}_d{q}"
            loads.append(_lw(d, "lw dim"))
            arith.append(_mul(acc, acc, d))
            arith.append(_alu(acc, acc, v))
    arith.append(_alu(f"{tag}_off", acc, comment="slli 2"))
    loads.append(_lw(f"{tag}_base", "lw base ptr"))
    addr = f"{tag}_addr"
    arith.append(_alu(addr, f"{tag}_base", f"{tag}_off"))
    if params.schedule_loads:
        out = loads + arith
    else:
        # naive interleave: each load placed immediately before its first use
        out = []
        pending = list(loads)
        for a in arith:
            for l in [p for p in pending if p.dst in a.srcs]:
                out.append(l)
                pending.remove(l)
            out.append(a)
        out = pending + out
    # -O0 spill/reload of the computed address through the stack; the reload
    # forwards from the store buffer, i.e. depends on the spilled value.
    for s in range(params.spills_per_ref):
        out.append(_sw(addr, "spill addr"))
        out.append(Instr(Kind.LOAD, dst=addr, srcs=(addr,), comment="reload addr"))
    for _ in range(params.mv_per_ref):
        out.append(_alu(addr, addr, comment="mv/sext"))
    return out, addr


def _ref_input_conv(params: CodegenParams) -> Tuple[List[Instr], str]:
    # Input[l][j+m][k+n]
    return gen_addr("in", ("l", "jm", "kn"), (False, True, True), (False,) * 3, params)


def _ref_filter_conv(params: CodegenParams) -> Tuple[List[Instr], str]:
    # Filter[i][l][m][n]
    return gen_addr("fil", ("i", "l", "m", "n"), (False,) * 4, (False,) * 4, params)


def _ref_output_conv(params: CodegenParams, tag: str = "out") -> Tuple[List[Instr], str]:
    # Output[i][j/S][k/S]
    return gen_addr(tag, ("i", "j", "k"), (False,) * 3, (False, True, True), params)


def _ref_input_fc(params: CodegenParams) -> Tuple[List[Instr], str]:
    return gen_addr("in", ("i",), (False,), (False,), params)


def _ref_filter_fc(params: CodegenParams) -> Tuple[List[Instr], str]:
    return gen_addr("fil", ("o", "i"), (False,) * 2, (False,) * 2, params)


def _ref_output_fc(params: CodegenParams, tag: str = "out") -> Tuple[List[Instr], str]:
    return gen_addr(tag, ("o",), (False,), (False,), params)


def mac_body(
    isa: Isa,
    params: CodegenParams,
    *,
    fc: bool = False,
) -> List[Instr]:
    """The innermost-loop body for one MAC under each ISA (paper Fig. 1)."""
    ref_in = _ref_input_fc if fc else _ref_input_conv
    ref_fil = _ref_filter_fc if fc else _ref_filter_conv
    ref_out = _ref_output_fc if fc else _ref_output_conv

    out: List[Instr] = []
    a_in_seq, a_in = ref_in(params)
    a_fil_seq, a_fil = ref_fil(params)
    out += a_in_seq
    out.append(Instr(Kind.FLW, dst="fa4", srcs=(a_in,), comment="flw input"))
    out += a_fil_seq
    out.append(Instr(Kind.FLW, dst="fa3", srcs=(a_fil,), comment="flw filter"))

    if isa == Isa.RV64F:
        a_out_seq, a_out = ref_out(params)
        out += a_out_seq
        out.append(Instr(Kind.FLW, dst="fa5", srcs=(a_out,), comment="flw partial"))
        out.append(Instr(Kind.FMUL, dst="ft0", srcs=("fa4", "fa3")))
        # -O0 spills the product through the stack before the add; the
        # reload store-to-load forwards, exposing the fmul latency.
        out.append(Instr(Kind.FSW, srcs=("ft0", "sp"), comment="spill product"))
        out.append(Instr(Kind.FLW, dst="ft0", srcs=("ft0",), comment="reload product"))
        out.append(Instr(Kind.FADD, dst="fa5", srcs=("fa5", "ft0")))
        a_out2_seq, a_out2 = ref_out(params, tag="out2")  # recomputed for the store
        out += a_out2_seq
        out.append(Instr(Kind.FSW, srcs=("fa5", a_out2), comment="fsw partial"))
    elif isa == Isa.BASELINE:
        a_out_seq, a_out = ref_out(params)
        out += a_out_seq
        out.append(Instr(Kind.FLW, dst="fa5", srcs=(a_out,), comment="flw partial"))
        out.append(Instr(Kind.FMAC, dst="fa5", srcs=("fa5", "fa4", "fa3")))
        out.append(Instr(Kind.FSW, srcs=("fa5", a_out), comment="fsw partial"))
    elif isa == Isa.RV64R:
        out.append(Instr(Kind.RFMAC, srcs=("fa4", "fa3"), comment="rfmac.s"))
    else:  # pragma: no cover
        raise ValueError(isa)

    for _ in range(params.extra_alu_per_mac):
        out.append(_alu("pad", "pad"))
    return out


def rfsmac_block(params: CodegenParams, *, fc: bool = False) -> List[Instr]:
    """Per-output-element epilogue for RV64R: rd <- APR, APR <- 0, store."""
    ref_out = _ref_output_fc if fc else _ref_output_conv
    seq, addr = ref_out(params, tag="outR")
    seq.append(Instr(Kind.RFSMAC, dst="fa5", comment="rfsmac.s"))
    seq.append(Instr(Kind.FSW, srcs=("fa5", addr), comment="fsw result"))
    return seq


def loop_overhead(level: str) -> Tuple[List[Instr], List[Instr]]:
    """-O0 per-iteration loop header (bound check) and footer (incr + jump)."""
    i = f"lv_{level}"
    header = [
        _lw(i, f"lw {level}"),
        _lw(f"{i}_b", f"lw bound({level})"),
        Instr(Kind.BRANCH, srcs=(i, f"{i}_b"), taken=False, comment=f"bge exit {level}"),
    ]
    footer = [
        _lw(i, f"lw {level}"),
        _alu(i, i, comment=f"addi {level}"),
        _sw(i, f"sw {level}"),
        Instr(Kind.JUMP, comment=f"j head {level}"),
    ]
    return header, footer


# ---------------------------------------------------------------------------
# Loop-nest IR + evaluation helpers.
# ---------------------------------------------------------------------------


@dataclass
class LoopNode:
    """One loop level.  Per iteration it runs: header, body, children (in
    order), post, footer."""

    level: str
    trips: int
    header: List[Instr] = field(default_factory=list)
    body: List[Instr] = field(default_factory=list)
    children: List["LoopNode"] = field(default_factory=list)
    post: List[Instr] = field(default_factory=list)
    footer: List[Instr] = field(default_factory=list)

    def own_stream(self) -> List[Instr]:
        return self.header + self.body + self.post + self.footer


def build_conv_nest(layer: ConvLayer, isa: Isa, params: CodegenParams) -> LoopNode:
    """Paper Fig. 1 loop order: i(M) j(Ho) k(Wo) l(C) m(Hf) n(Wf)."""
    levels = [
        ("i", layer.M),
        ("j", layer.Ho),
        ("k", layer.Wo),
        ("l", layer.C),
        ("m", layer.Hf),
        ("n", layer.Wf),
    ]
    inner_body = mac_body(isa, params, fc=False)
    node: Optional[LoopNode] = None
    for level, trips in reversed(levels):
        header, footer = loop_overhead(level)
        this = LoopNode(level=level, trips=trips, header=header, footer=footer)
        if node is None:
            this.body = inner_body
        else:
            this.children = [node]
        # RV64R: one rfsmac per output element, i.e. after the l-loop closes
        # inside the k-level iteration.
        if isa == Isa.RV64R and level == "k":
            this.post = rfsmac_block(params, fc=False)
        node = this
    assert node is not None
    return node


def build_fc_nest(layer: FCLayer, isa: Isa, params: CodegenParams) -> LoopNode:
    inner_body = mac_body(isa, params, fc=True)
    h_i, f_i = loop_overhead("i")
    inner = LoopNode(level="i", trips=layer.I, header=h_i, body=inner_body, footer=f_i)
    h_o, f_o = loop_overhead("o")
    outer = LoopNode(level="o", trips=layer.O, header=h_o, children=[inner], footer=f_o)
    if isa == Isa.RV64R:
        outer.post = rfsmac_block(params, fc=True)
    return outer


def build_nest(layer: Layer, isa: Isa, params: CodegenParams) -> LoopNode:
    if isinstance(layer, ConvLayer):
        return build_conv_nest(layer, isa, params)
    return build_fc_nest(layer, isa, params)
