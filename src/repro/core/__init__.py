"""Level-A (paper-faithful RISC-V R-extension model) + the accumulator-
residency abstraction shared with the TPU kernels (Level B)."""

from .isa import Isa, Kind, Instr  # noqa: F401
from .simulate import simulate_model, table3, enhancement, Metrics  # noqa: F401
