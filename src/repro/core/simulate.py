"""Table-III evaluation: (model x ISA) -> runtime / IC / IPC / mem / L1.

The evaluator walks the loop-nest IR from ``program.py``.  Per loop level it
measures the converged cycles-per-iteration of that level's own instruction
stream with the exact pipeline model (``pipeline.steady_state_cycles``) and
multiplies by trip counts; dynamic instruction counts are exact.  Cache
effects are added from the analytic model in ``cache.py``.

This basic-block-granularity evaluation is *exact* for the pipeline term
(the streams are cyclic and the simulator converges to the true steady
state) and lets the 4x10^9-instruction ResNet/MobileNet rows of Table III be
reproduced in milliseconds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Tuple

from . import calibration
from .cache import data_misses, instruction_accesses
from .isa import Instr, Isa, Kind
from .pipeline import PipelineParams, steady_state_cycles, validate_stream
from .program import CodegenParams, Layer, LoopNode, build_nest
from .workloads import MODELS

CLOCK_HZ = 1_000_000_000  # Table II: 1 GHz


@dataclass
class Counts:
    instructions: int = 0
    mem_instrs: int = 0
    cycles: float = 0.0
    redirects: int = 0          # taken control-flow transfers (L1I refetches)
    instr_bytes: int = 0

    def add(self, other: "Counts") -> None:
        self.instructions += other.instructions
        self.mem_instrs += other.mem_instrs
        self.cycles += other.cycles
        self.redirects += other.redirects
        self.instr_bytes += other.instr_bytes


def _block_stats(block: Tuple[Instr, ...], params: PipelineParams) -> Tuple[float, int, int, int]:
    """(cycles/iter, mem instrs, redirects, bytes) for one cyclic block."""
    cyc = steady_state_cycles(list(block), params)
    mem = sum(1 for i in block if i.is_mem)
    red = sum(1 for i in block if i.kind == Kind.JUMP or (i.kind == Kind.BRANCH and i.taken))
    nbytes = len(block) * params.instr_bytes
    return cyc, mem, red, nbytes


class _BlockCache:
    """Steady-state results keyed by the block's structural identity."""

    def __init__(self, params: PipelineParams):
        self.params = params
        self._memo: Dict[Tuple, Tuple[float, int, int, int]] = {}

    def stats(self, block: List[Instr]) -> Tuple[float, int, int, int]:
        key = tuple((i.kind, i.dst, i.srcs, i.taken) for i in block)
        if key not in self._memo:
            self._memo[key] = _block_stats(tuple(block), self.params)
        return self._memo[key]


def _eval_node(node: LoopNode, cache: _BlockCache) -> Counts:
    out = Counts()
    own = node.own_stream()
    cyc, mem, red, nbytes = cache.stats(own)
    out.instructions = len(own) * node.trips
    out.mem_instrs = mem * node.trips
    out.cycles = cyc * node.trips
    out.redirects = red * node.trips
    out.instr_bytes = nbytes * node.trips
    for child in node.children:
        c = _eval_node(child, cache)
        # the child body runs once per iteration of this level
        out.instructions += c.instructions * node.trips
        out.mem_instrs += c.mem_instrs * node.trips
        out.cycles += c.cycles * node.trips
        out.redirects += c.redirects * node.trips
        out.instr_bytes += c.instr_bytes * node.trips
    return out


@dataclass
class Metrics:
    """One Table III row."""

    model: str
    isa: Isa
    runtime_s: float
    instructions: int
    ipc: float
    mem_instrs: int
    l1_accesses: int
    d_misses: int = 0

    def as_row(self) -> Dict[str, float]:
        return {
            "model": self.model,
            "isa": self.isa.pretty,
            "runtime_s": round(self.runtime_s, 4),
            "IC": self.instructions,
            "IPC": round(self.ipc, 3),
            "mem_instrs": self.mem_instrs,
            "l1_accesses": self.l1_accesses,
        }


def simulate_model(
    model: str,
    isa: Isa,
    *,
    codegen: CodegenParams | None = None,
    pipeline: PipelineParams | None = None,
) -> Metrics:
    codegen = codegen or calibration.CODEGEN
    pipeline = pipeline or calibration.PIPELINE
    layers: List[Layer] = MODELS[model]()
    cache = _BlockCache(pipeline)

    total = Counts()
    d_misses = 0
    for layer in layers:
        nest = build_nest(layer, isa, codegen)
        validate_stream(nest.own_stream(), isa)
        total.add(_eval_node(nest, cache))
        d_misses += data_misses(layer)

    cycles = total.cycles + d_misses * pipeline.l1_miss_penalty
    i_acc = instruction_accesses(total.instr_bytes, total.redirects, pipeline.fetch_bytes)
    return Metrics(
        model=model,
        isa=isa,
        runtime_s=cycles / CLOCK_HZ,
        instructions=total.instructions,
        ipc=total.instructions / max(cycles, 1.0),
        mem_instrs=total.mem_instrs,
        l1_accesses=total.mem_instrs + i_acc,
        d_misses=d_misses,
    )


def table3(models: Tuple[str, ...] = ("lenet", "resnet20", "mobilenet_v1")) -> List[Metrics]:
    rows: List[Metrics] = []
    for model in models:
        for isa in (Isa.RV64F, Isa.BASELINE, Isa.RV64R):
            rows.append(simulate_model(model, isa))
    return rows


def enhancement(base: Metrics, new: Metrics) -> Dict[str, float]:
    """Paper-style enhancement percentages of ``new`` over ``base``."""
    return {
        "runtime": 100.0 * (base.runtime_s - new.runtime_s) / base.runtime_s,
        "IC": 100.0 * (base.instructions - new.instructions) / base.instructions,
        "IPC": 100.0 * (new.ipc - base.ipc) / base.ipc,
        "mem_instrs": 100.0 * (base.mem_instrs - new.mem_instrs) / base.mem_instrs,
        "l1_accesses": 100.0 * (base.l1_accesses - new.l1_accesses) / base.l1_accesses,
    }
