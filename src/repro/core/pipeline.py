"""Cycle-level model of the paper's 5-stage in-order pipeline.

Models IF-ID-EX-MEM-WB with:

* single issue, in-order, full forwarding (EX->EX, MEM->EX),
* scoreboard-style stalls on RAW hazards against multi-cycle producers,
* 1-bubble load-use hazard (L1 hit data available at end of MEM),
* branches resolved in EX (taken => ``branch_penalty`` bubbles),
  unconditional jumps resolved in ID (``jump_penalty``),
* multi-cycle (pipelined) FP units with ``fp_latency`` result latency,
* the R-extension **rented pipeline**: ``rfmac.s`` multiplies in EX and
  accumulates into the APR in the rented R_EX (=MEM) stage.  The APR has a
  dedicated forwarding loop inside R_EX (paper Fig. 2), so back-to-back
  ``rfmac.s`` never stall on the accumulation dependency, and the FP-add
  latency of the accumulation is never exposed to the issue stream.
* ``rfsmac.s`` reads the APR during ID and resets it in MEM; it must wait
  for the last in-flight ``rfmac.s`` to have passed R_EX.

The simulator is trace-driven and exact for a given instruction stream.
``steady_state`` measures the converged cycles-per-iteration of a cyclic
loop body, which lets Table-III-scale workloads (10^9+ dynamic instructions)
be evaluated exactly at basic-block granularity instead of instruction by
instruction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

from .isa import Instr, Isa, Kind, instr_allowed

APR = "__apr__"  # symbolic register name for the architectural pipeline reg.


@dataclass(frozen=True)
class PipelineParams:
    """Microarchitectural constants (defaults align with paper Table II)."""

    load_use_penalty: int = 1      # bubbles for load -> immediately-dependent use
    branch_penalty: int = 2        # taken conditional branch, resolved in EX
    jump_penalty: int = 1          # unconditional jump, resolved in ID
    int_mul_latency: int = 3       # address-arithmetic integer multiply
    int_div_latency: int = 12      # unpipelined divider (j/S, k/S indexing)
    fp_latency: int = 8            # fmul.s / fadd.s / fmac.s result latency
    fp_store_latency: int = 1      # cycles before a produced FP value may be stored
    l1_hit_cycles: int = 2         # Table II: 2-cycle L1 latency
    l1_miss_penalty: int = 80      # DRAM round-trip (DDR3-1600, conservative)
    fetch_bytes: int = 32          # L1I fetch granularity per access
    instr_bytes: int = 4           # average encoded instruction size


def _producer_latency(instr: Instr, params: PipelineParams) -> int:
    """Cycles after issue at which ``instr``'s result is forwardable to EX."""
    k = instr.kind
    if k.is_load:
        return 1 + params.load_use_penalty
    if k == Kind.MUL:
        return params.int_mul_latency
    if k == Kind.DIV:
        return params.int_div_latency
    if k in (Kind.FMUL, Kind.FADD, Kind.FMAC):
        return params.fp_latency
    if k == Kind.RFMAC:
        # The register-file-visible result of rfmac.s is the APR, handled
        # separately; rfmac has no integer/FP destination register.
        return 1
    if k == Kind.RFSMAC:
        return 1  # rd is written from the APR during ID; available next cycle
    return 1


@dataclass
class SimResult:
    cycles: int
    instructions: int
    stall_cycles: int
    flush_cycles: int

    @property
    def ipc(self) -> float:
        return self.instructions / max(self.cycles, 1)


def simulate(
    stream: Sequence[Instr],
    params: PipelineParams = PipelineParams(),
    *,
    initial_ready: Dict[str, int] | None = None,
) -> Tuple[SimResult, Dict[str, int]]:
    """Exact in-order issue-time simulation of ``stream``.

    Returns the result plus the register-ready map at exit (relative to the
    final issue cycle) so that cyclic steady-state analysis can stitch
    iterations together.
    """
    ready: Dict[str, int] = dict(initial_ready or {})
    issue_prev = -1
    stalls = 0
    flushes = 0
    pending_redirect = 0  # extra bubbles imposed on the *next* instruction

    for instr in stream:
        earliest = issue_prev + 1 + pending_redirect
        pending_redirect = 0
        # RAW hazards via forwarding network.  Stores never stall on their
        # DATA operand (srcs[0]) — they wait in the store buffer and the
        # data is filled in when produced; a dependent reload instead
        # carries the producer in its own srcs (store-to-load forwarding).
        srcs = instr.srcs
        if instr.kind.is_store and len(srcs) >= 2:
            srcs = srcs[1:]
        for src in srcs:
            earliest = max(earliest, ready.get(src, 0))
        # APR consumption:
        if instr.kind == Kind.RFMAC:
            # accumulates in R_EX at issue+1; APR forward loop means the
            # constraint is apr_ready <= issue+1.
            earliest = max(earliest, ready.get(APR, 0) - 1)
        elif instr.kind == Kind.RFSMAC:
            # reads APR during ID (= issue cycle under this accounting).
            earliest = max(earliest, ready.get(APR, 0))
        stalls += max(0, earliest - (issue_prev + 1))
        issue = earliest

        if instr.dst is not None:
            ready[instr.dst] = issue + _producer_latency(instr, params)
        if instr.kind == Kind.RFMAC:
            ready[APR] = issue + 2  # after R_EX
        elif instr.kind == Kind.RFSMAC:
            ready[APR] = issue + 2  # reset completes in MEM

        if instr.kind == Kind.BRANCH and instr.taken:
            pending_redirect = params.branch_penalty
            flushes += params.branch_penalty
        elif instr.kind == Kind.JUMP:
            pending_redirect = params.jump_penalty
            flushes += params.jump_penalty

        issue_prev = issue

    total_cycles = issue_prev + 1 + pending_redirect  # drain ignored (amortised)
    # Normalise the ready map to be relative to the end of this stream.
    out_ready = {r: c - total_cycles for r, c in ready.items() if c > total_cycles}
    return (
        SimResult(
            cycles=total_cycles,
            instructions=len(stream),
            stall_cycles=stalls,
            flush_cycles=flushes,
        ),
        out_ready,
    )


def steady_state_cycles(
    block: Sequence[Instr],
    params: PipelineParams = PipelineParams(),
    *,
    warmup_reps: int = 6,
    measure_reps: int = 4,
) -> float:
    """Converged cycles per iteration of a cyclic basic block.

    Simulates ``warmup_reps + measure_reps`` repetitions and returns the
    marginal cycles of the measured repetitions; exact for loop-carried
    dependency chains expressed through register names.
    """
    if not block:
        return 0.0
    reps = warmup_reps + measure_reps

    def run(n: int) -> int:
        stream: List[Instr] = []
        for _ in range(n):
            stream.extend(block)
        res, _ = simulate(stream, params)
        return res.cycles

    c_all = run(reps)
    c_warm = run(warmup_reps)
    return (c_all - c_warm) / measure_reps


def validate_stream(stream: Iterable[Instr], isa: Isa) -> None:
    """Assert that every instruction in the stream exists under ``isa``."""
    for instr in stream:
        if not instr_allowed(instr.kind, isa):
            raise ValueError(f"{instr.kind.value} is not available under {isa.pretty}")
