"""Layer dimensions for the paper's three benchmark DNNs (Table III).

* LeNet-5 (32x32 grayscale): conv/fc layers only — pooling layers perform no
  MACs and contribute negligible trace volume at -O0 relative to conv.
* ResNet-20 (CIFAR-10, He et al. 2016): 3 stages x 3 basic blocks.
* MobileNet-V1 "(Scaled)": the paper scales MobileNet to an edge-sized input;
  we use the standard depthwise-separable stack at 32x32 input resolution,
  which lands within 5% of the paper's RV64F instruction count, confirming
  the scaling interpretation.
"""
from __future__ import annotations

from typing import Dict, List

from .program import ConvLayer, FCLayer, Layer


def lenet() -> List[Layer]:
    return [
        ConvLayer("conv1", M=6, C=1, Ho=28, Wo=28, Hf=5, Wf=5, Hin=32, Win=32),
        ConvLayer("conv2", M=16, C=6, Ho=10, Wo=10, Hf=5, Wf=5, Hin=14, Win=14),
        FCLayer("fc1", O=120, I=400),
        FCLayer("fc2", O=84, I=120),
        FCLayer("fc3", O=10, I=84),
    ]


def _basic_block(stage: int, idx: int, ch: int, res: int, in_ch: int, stride: int) -> List[Layer]:
    layers: List[Layer] = [
        ConvLayer(
            f"s{stage}b{idx}c1", M=ch, C=in_ch, Ho=res, Wo=res, Hf=3, Wf=3,
            Hin=res * stride, Win=res * stride, stride=stride,
        ),
        ConvLayer(f"s{stage}b{idx}c2", M=ch, C=ch, Ho=res, Wo=res, Hf=3, Wf=3,
                  Hin=res, Win=res),
    ]
    if stride != 1 or in_ch != ch:
        layers.append(
            ConvLayer(f"s{stage}b{idx}sc", M=ch, C=in_ch, Ho=res, Wo=res, Hf=1, Wf=1,
                      Hin=res * stride, Win=res * stride, stride=stride)
        )
    return layers


def resnet20() -> List[Layer]:
    layers: List[Layer] = [
        ConvLayer("conv1", M=16, C=3, Ho=32, Wo=32, Hf=3, Wf=3, Hin=32, Win=32)
    ]
    specs = [(1, 16, 32, 16), (2, 32, 16, 16), (3, 64, 8, 32)]
    for stage, ch, res, in_ch in specs:
        for b in range(3):
            stride = 2 if (stage > 1 and b == 0) else 1
            cin = in_ch if b == 0 else ch
            layers += _basic_block(stage, b, ch, res, cin, stride)
    layers.append(FCLayer("fc", O=10, I=64))
    return layers


def mobilenet_v1_scaled() -> List[Layer]:
    """MobileNet-V1 depthwise-separable stack at 32x32 input."""
    layers: List[Layer] = [
        ConvLayer("conv1", M=32, C=3, Ho=32, Wo=32, Hf=3, Wf=3, Hin=32, Win=32)
    ]
    # (in_ch, out_ch, stride) for each dw/pw pair; resolutions halve on s2.
    cfg = [
        (32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
        (256, 256, 1), (256, 512, 2),
        (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 512, 1),
        (512, 1024, 2), (1024, 1024, 1),
    ]
    res = 32
    for idx, (cin, cout, s) in enumerate(cfg):
        out_res = res // s
        layers.append(
            ConvLayer(f"dw{idx}", M=cin, C=1, Ho=out_res, Wo=out_res, Hf=3, Wf=3,
                      Hin=res, Win=res, stride=s)
        )
        layers.append(
            ConvLayer(f"pw{idx}", M=cout, C=cin, Ho=out_res, Wo=out_res, Hf=1, Wf=1,
                      Hin=out_res, Win=out_res)
        )
        res = out_res
    layers.append(FCLayer("fc", O=10, I=1024))
    return layers


MODELS: Dict[str, "callable"] = {
    "lenet": lenet,
    "resnet20": resnet20,
    "mobilenet_v1": mobilenet_v1_scaled,
}


def total_macs(layers: List[Layer]) -> int:
    return sum(l.macs for l in layers)
