"""Instruction-set definitions for the RISC-V R-extension reproduction.

Level-A (paper-faithful) model of the ISAs compared in the paper:

* ``RV64F``    — stock F-extension: ``fmul.s`` + ``fadd.s`` (+ ``flw``/``fsw``).
* ``BASELINE`` — RV64F plus a naive ``fmac.s`` MAC module in the EX stage
  (the paper's re-scalarised ``vmac``).
* ``RV64R``    — the paper's R-extension: ``rfmac.s`` (multiply in EX,
  accumulate into the APR in the rented R_EX stage) and ``rfsmac.s``
  (write APR to ``rd`` in ID, reset APR in MEM).

Encodings follow Fig. 3 / Fig. 4 of the paper exactly: OP-FP major opcode
(0b1010011), fmt=S (0b00), funct5 = FMUL 0x02 / FMAC 0x0C / RFMAC 0x0D /
RFSMAC 0x0E, with MASK/MATCH pairs that zero out the unused rd (rfmac.s)
and rs1/rs2 (rfsmac.s) fields.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# ISA variants under comparison (paper Table III rows).
# ---------------------------------------------------------------------------


class Isa(enum.Enum):
    RV64F = "rv64f"
    BASELINE = "baseline"  # RV64F + naive fmac.s in EX
    RV64R = "rv64r"        # rented-pipeline + APR

    @property
    def pretty(self) -> str:
        return {"rv64f": "RV64F", "baseline": "Baseline", "rv64r": "RV64R"}[self.value]


# ---------------------------------------------------------------------------
# Bit-level encodings (paper Fig. 3 / Fig. 4).
# ---------------------------------------------------------------------------

OPCODE_OP_FP = 0b1010011  # "OP-FP (0x14)" in the paper's 5-bit major-opcode
                          # notation; full 7-bit opcode incl. the 0b11 quadrant.

FMT_S = 0b00  # Table I: 32-bit single precision
FMT_D = 0b01
FMT_H = 0b10
FMT_Q = 0b11

FUNCT5_FMUL = 0x02
FUNCT5_FMAC = 0x0C
FUNCT5_RFMAC = 0x0D
FUNCT5_RFSMAC = 0x0E

RM_DYN = 0b111  # dynamic rounding mode (from CSR, per §II-B)


def _fp_encode(funct5: int, fmt: int, rs2: int, rs1: int, rm: int, rd: int) -> int:
    """Assemble a 32-bit OP-FP instruction word."""
    assert 0 <= funct5 < 32 and 0 <= fmt < 4
    assert 0 <= rs2 < 32 and 0 <= rs1 < 32 and 0 <= rd < 32 and 0 <= rm < 8
    return (
        (funct5 << 27)
        | (fmt << 25)
        | (rs2 << 20)
        | (rs1 << 15)
        | (rm << 12)
        | (rd << 7)
        | OPCODE_OP_FP
    )


def encode_fmul_s(rd: int, rs1: int, rs2: int, rm: int = RM_DYN) -> int:
    return _fp_encode(FUNCT5_FMUL, FMT_S, rs2, rs1, rm, rd)


def encode_fmac_s(rd: int, rs1: int, rs2: int, rm: int = RM_DYN) -> int:
    return _fp_encode(FUNCT5_FMAC, FMT_S, rs2, rs1, rm, rd)


def encode_rfmac_s(rs1: int, rs2: int, rm: int = RM_DYN) -> int:
    # rd field unused -> must be zero (enforced by MASK_RFMAC_S).
    return _fp_encode(FUNCT5_RFMAC, FMT_S, rs2, rs1, rm, rd=0)


def encode_rfsmac_s(rd: int, rm: int = RM_DYN) -> int:
    # rs1/rs2 unused -> must be zero (enforced by MASK_RFSMAC_S).
    return _fp_encode(FUNCT5_RFSMAC, FMT_S, 0, 0, rm, rd)


# MASK filters out the opcode + function fields; MATCH carries their values
# (paper Fig. 4).  Essential variable fields (rm, rs1, rs2, rd) are left open
# unless the instruction does not use them.
MASK_FMUL_S = 0xFE00007F
MATCH_FMUL_S = 0x10000053
MASK_FMAC_S = 0xFE00007F
MATCH_FMAC_S = 0x60000053
# rfmac.s writes no destination register: rd bits join the mask.
MASK_RFMAC_S = 0xFE000FFF
MATCH_RFMAC_S = 0x68000053
# rfsmac.s reads no source registers: rs1/rs2 bits join the mask.
MASK_RFSMAC_S = 0xFFF0007F | (0x1F << 15)  # funct5|fmt|rs2|rs1 masked
MATCH_RFSMAC_S = 0x70000053


def matches(word: int, mask: int, match: int) -> bool:
    return (word & mask) == match


def decode(word: int) -> str:
    """Decode a 32-bit word into one of the modelled OP-FP mnemonics."""
    for name, mask, match in (
        ("fmul.s", MASK_FMUL_S, MATCH_FMUL_S),
        ("fmac.s", MASK_FMAC_S, MATCH_FMAC_S),
        ("rfmac.s", MASK_RFMAC_S, MATCH_RFMAC_S),
        ("rfsmac.s", MASK_RFSMAC_S, MATCH_RFSMAC_S),
    ):
        if matches(word, mask, match):
            return name
    raise ValueError(f"unrecognised instruction word 0x{word:08x}")


# ---------------------------------------------------------------------------
# Micro-op level instruction model used by the trace generator + pipeline.
# ---------------------------------------------------------------------------


class Kind(enum.Enum):
    # integer
    ALU = "alu"          # add/sub/slli/srli/sext.w/li ...
    MUL = "mul"          # integer multiply (address arithmetic)
    DIV = "div"          # integer divide (j/S, k/S output indexing at -O0)
    LOAD = "load"        # lw/ld (integer load, incl. stack reloads)
    STORE = "store"      # sw/sd
    BRANCH = "branch"    # bge/bne/blt (conditional)
    JUMP = "jump"        # j / jal (always taken)
    # floating point
    FLW = "flw"
    FSW = "fsw"
    FMUL = "fmul.s"
    FADD = "fadd.s"
    FMAC = "fmac.s"      # baseline: naive MAC in EX
    RFMAC = "rfmac.s"    # R-ext: mul in EX, accumulate in rented R_EX via APR
    RFSMAC = "rfsmac.s"  # R-ext: rd <- APR (ID), APR <- 0 (MEM)
    NOP = "nop"

    @property
    def is_mem(self) -> bool:
        """Memory-type instruction (paper Table III column 5)."""
        return self in (Kind.LOAD, Kind.STORE, Kind.FLW, Kind.FSW)

    @property
    def is_load(self) -> bool:
        return self in (Kind.LOAD, Kind.FLW)

    @property
    def is_store(self) -> bool:
        return self in (Kind.STORE, Kind.FSW)

    @property
    def is_arith_fp(self) -> bool:
        return self in (Kind.FMUL, Kind.FADD, Kind.FMAC, Kind.RFMAC)


@dataclass(frozen=True)
class Instr:
    """One instruction in a trace.

    Register identities are symbolic strings so the generator can express
    dataflow without real register allocation; the pipeline model only cares
    about dependency structure.
    """

    kind: Kind
    dst: Optional[str] = None
    srcs: Tuple[str, ...] = ()
    taken: bool = False        # for BRANCH: statically taken this iteration?
    comment: str = ""

    @property
    def is_mem(self) -> bool:
        return self.kind.is_mem

    @property
    def reads_apr(self) -> bool:
        return self.kind in (Kind.RFMAC, Kind.RFSMAC)

    @property
    def writes_apr(self) -> bool:
        return self.kind in (Kind.RFMAC, Kind.RFSMAC)


def instr_allowed(kind: Kind, isa: Isa) -> bool:
    """Which instruction kinds exist under each ISA variant."""
    if kind == Kind.FMAC:
        return isa == Isa.BASELINE
    if kind in (Kind.RFMAC, Kind.RFSMAC):
        return isa == Isa.RV64R
    return True
