"""APR (Architectural Pipeline Register) — the TPU-native abstraction.

The paper's APR is a register at the MEM/WB boundary that holds a running
reduction so partial sums never round-trip through the memory system.  On
TPU the equivalent storage class is a VMEM scratch buffer that persists
across the reduction steps of a Pallas grid.  ``AccumulatorSpec`` names that
mapping explicitly so every kernel in ``repro.kernels`` speaks the same
vocabulary, and the traffic model quantifies what residency buys — the
Level-B analogue of paper Table III's memory columns.

Residency classes:

* ``"apr"`` — the accumulator lives in VMEM scratch for the whole reduction;
  HBM sees exactly one write per output element (the ``rfsmac.s`` flush).
* ``"hbm"`` — the accumulator round-trips through HBM on every reduction
  step (the ``fmac.s``/F-extension baseline: one load + one store of the
  partial per step).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal, Tuple

Residency = Literal["apr", "hbm"]


@dataclasses.dataclass(frozen=True)
class AccumulatorSpec:
    """Shape/dtype/residency of one kernel's running accumulator."""

    shape: Tuple[int, ...]
    dtype: str = "float32"
    residency: Residency = "apr"

    @property
    def bytes(self) -> int:
        itemsize = {"float32": 4, "bfloat16": 2, "float16": 2}[self.dtype]
        return math.prod(self.shape) * itemsize


def reduction_hbm_traffic(
    out_elems: int,
    n_steps: int,
    out_bytes_per_elem: int,
    residency: Residency,
    acc_bytes_per_elem: int = 4,
) -> int:
    """HBM bytes attributable to the *accumulator* of a blocked reduction.

    ``apr``: one final write per output element.
    ``hbm``: one read + one write of the fp32 partial per reduction step,
    plus the final write — exactly the flw/fsw-per-MAC pattern of Fig. 1(a/b)
    lifted to block granularity.
    """
    final = out_elems * out_bytes_per_elem
    if residency == "apr":
        return final
    per_step = 2 * acc_bytes_per_elem * out_elems  # read + write each step
    return n_steps * per_step + final


def traffic_reduction(out_elems: int, n_steps: int, out_bytes: int = 2) -> float:
    """Fractional HBM-traffic saving of apr vs hbm residency (Table-III
    'memory access' analogue at kernel level)."""
    apr = reduction_hbm_traffic(out_elems, n_steps, out_bytes, "apr")
    hbm = reduction_hbm_traffic(out_elems, n_steps, out_bytes, "hbm")
    return 1.0 - apr / hbm
