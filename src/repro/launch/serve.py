"""Serving launcher: asyncio streaming server over paged continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 6

Requests are submitted through :class:`repro.serve.AsyncServeFrontend` and
stream their tokens back concurrently — the engine admits late arrivals
mid-flight instead of draining a fixed batch.  ``--slo-mix`` marks every
other request TTFT-class (priority admission with aged anti-starvation,
``docs/serving.md``); per-request rows in the report show class, TTFT,
latency, and queue-jump counts.

``--engine slot`` falls back to the contiguous slot engine (the numerics
baseline, and the only path for the audio family).  Recurrent-state
families (rwkv6 / mamba2 / zamba2) serve on the paged engine through the
state-slot pool (``repro.serve.state_cache``; ``--state-dtype int8``
stores the big state leaves int8): token-identical to the slot engine,
with ``--spec-k``/``--draft-model ngram`` speculation working through
snapshot-ring rollback.  ``--prefix-sharing`` is rejected for them with a
reason — a recurrent state is a lossy running summary, so cached prefix
KV cannot be attached mid-sequence.

Prefix cache (``--prefix-sharing``): requests whose prompts share a prefix
attach the cached KV pages read-only instead of re-prefilling them;
copy-on-write splits on divergence.  The launcher's default prompts share
a common head so the effect shows up in ``prefix hits`` / the effective-KV
multiplier line.

Multi-precision (`repro.quant`, docs/quantization.md): ``--int8-weights``
serves the int8-weight variant of the model, ``--kv-dtype int8`` stores the
paged KV cache as int8 + per-(page slot, head) scales.

Speculative decoding (`repro.spec`, docs/architecture.md): ``--draft-model``
picks the draft proposer — ``ngram`` (self-drafting), ``auto`` (the draft
arch registered for the target in ``repro.configs.DRAFT_FOR``, falling back
to ngram), or an explicit draft arch name; ``--spec-k`` sets the per-slot
proposal budget.  Greedy outputs are token-identical to the plain engine.

Tensor parallelism (`repro.parallel.tp`, docs/parallel.md): ``--mesh N``
shards attention heads, MLP blocks, and the KV page pools of the paged
engine over the first N devices; greedy outputs stay token-identical to
``--mesh 1``.  On a CPU-only machine the launcher simulates the devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) before jax loads.
"""
import argparse
import asyncio
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--engine", choices=("paged", "slot"), default="paged")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page pool size (default: slots * 256/page_size)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="refcounted prefix cache with copy-on-write pages "
                         "(paged engine only; docs/serving.md)")
    ap.add_argument("--slo-mix", action="store_true",
                    help="submit every other request as TTFT-class (priority "
                         "admission; the rest are throughput-class FIFO)")
    ap.add_argument("--int8-weights", action="store_true",
                    help="serve the int8-weight variant "
                         "(repro.quant.quantize_params)")
    ap.add_argument("--kv-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16",
                    help="paged KV page-pool storage dtype")
    ap.add_argument("--state-dtype", choices=("float32", "int8"),
                    default="float32",
                    help="recurrent-state pool storage dtype (ssm/mamba/"
                         "hybrid on the paged engine; int8 is lossy)")
    ap.add_argument("--graph-prefill", action="store_true",
                    help="route chunked prefill through the repro.graph "
                         "fused executor (paged engine only; docs/graph.md)")
    ap.add_argument("--cost-model", choices=("on", "off"), default="on",
                    help="with --graph-prefill: choose the fusion schedule "
                         "with the repro.cost model and cache it by graph "
                         "signature ('off' reverts to the fixed pass "
                         "pipeline; docs/cost_model.md)")
    ap.add_argument("--explain", action="store_true",
                    help="print the cost model's per-pass schedule audit "
                         "for the graph-compiled steps before serving")
    ap.add_argument("--draft-model", default=None,
                    help="speculative decoding draft: 'ngram', 'auto', or a "
                         "draft arch name (repro.spec; paged engine only)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens verified per step per slot")
    ap.add_argument("--mesh", type=int, default=1,
                    help="tensor-parallel degree: shard the paged engine "
                         "over the first N devices (repro.parallel.tp; "
                         "simulated on CPU via host-platform devices)")
    args = ap.parse_args()

    if args.mesh > 1 and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # must be decided before jax initialises its backends; real
        # accelerators already expose their device count, but a plain CPU
        # process defaults to one device
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.mesh}").strip()

    import jax

    from ..configs import get_config, get_draft_config
    from ..models import build_model
    from ..parallel.sharding import ParallelContext
    from ..serve import (SLO_THROUGHPUT, SLO_TTFT, AsyncServeFrontend,
                         PagedServeEngine, ServeEngine)

    cfg = get_config(args.arch, smoke=True)
    if args.mesh > 1 and (cfg.num_heads % args.mesh
                          or cfg.num_kv_heads % args.mesh
                          or cfg.d_ff % args.mesh):
        # Lift the reduced smoke geometry to a TP-divisible head layout
        # (full-size configs divide naturally; the smoke ones are tiny).
        import dataclasses
        up = lambda v, n: -(-v // n) * n
        hkv = up(cfg.num_kv_heads, args.mesh)
        h = up(max(cfg.num_heads, hkv), hkv)   # whole GQA groups per shard
        cfg = dataclasses.replace(cfg, num_heads=h, num_kv_heads=hkv,
                                  head_dim=cfg.resolved_head_dim,
                                  d_ff=up(cfg.d_ff, args.mesh))
        print(f"note: smoke geometry lifted for --mesh {args.mesh}: "
              f"heads={h} kv_heads={hkv} d_ff={cfg.d_ff}")
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    if args.int8_weights:
        params = bundle.quantize_params(params)
    if args.mesh > 1:
        if not (args.engine == "paged" and bundle.supports_paged_kv):
            raise SystemExit(
                f"--mesh requires the paged engine and a paged-KV family "
                f"(got --engine {args.engine}, family {cfg.family!r})")
        if args.graph_prefill:
            raise SystemExit("--graph-prefill is incompatible with --mesh "
                             "(the graph executor is a host-side op loop)")
        from ..parallel.tp import make_serving_mesh, make_tp_context
        pctx = make_tp_context(make_serving_mesh(args.mesh))
        print(f"mesh: {args.mesh}-way tensor parallel over "
              f"{[str(d) for d in pctx.mesh.devices.flat]}")
    else:
        pctx = ParallelContext(None)
    if args.draft_model and not (args.engine == "paged"
                                 and bundle.supports_paged_serving):
        raise SystemExit(f"--draft-model requires the paged engine and a "
                         f"paged-serving family (got --engine {args.engine},"
                         f" family {cfg.family!r})")
    if args.graph_prefill and cfg.family == "hybrid":
        raise SystemExit(
            "--graph-prefill is unsupported for the hybrid family: the "
            "graph executor's cluster boundaries make the f32 SSD update "
            "FMA-contraction sensitive, so token identity to the jit path "
            "cannot be guaranteed (run without --graph-prefill)")
    if args.prefix_sharing and bundle.supports_paged_state:
        raise SystemExit(
            f"--prefix-sharing is unsupported for the {cfg.family!r} "
            "family: a recurrent state is a lossy running summary of its "
            "whole history, so cached prefix KV cannot be attached "
            "mid-sequence (run without --prefix-sharing)")
    if args.engine == "paged" and bundle.supports_paged_serving:
        engine_kw = dict(slots=args.slots, page_size=args.page_size,
                         num_pages=args.num_pages,
                         prefill_chunk=args.prefill_chunk,
                         kv_dtype=args.kv_dtype,
                         state_dtype=args.state_dtype,
                         prefix_sharing=args.prefix_sharing,
                         use_graph=args.graph_prefill,
                         graph_cost_model=(args.cost_model == "on"))
        if args.draft_model:
            from ..models import build_draft_model
            from ..spec import SpeculativeServeEngine

            if args.draft_model == "ngram":
                draft_cfg = None
            elif args.draft_model == "auto":
                draft_cfg = get_draft_config(args.arch, smoke=True)
            else:  # an explicit *draft* arch name (not a target arch)
                draft_cfg = get_draft_config(args.draft_model, smoke=True,
                                             pairing=False)
                if draft_cfg is None:
                    raise SystemExit(
                        f"no draft config registered as {args.draft_model!r}")
            if draft_cfg is None:
                print(f"speculative: ngram self-draft, k={args.spec_k}")
                engine = SpeculativeServeEngine(
                    bundle, params, pctx, spec_k=args.spec_k, **engine_kw)
            else:
                print(f"speculative: draft={draft_cfg.name}, k={args.spec_k}")
                draft_bundle = build_draft_model(cfg, draft_cfg)
                draft_params = draft_bundle.init_params(jax.random.PRNGKey(1))
                engine = SpeculativeServeEngine(
                    bundle, params, pctx, spec_k=args.spec_k,
                    draft_bundle=draft_bundle, draft_params=draft_params,
                    **engine_kw)
        else:
            engine = PagedServeEngine(bundle, params, pctx, **engine_kw)
    else:
        if args.engine == "paged":
            print(f"note: {cfg.family!r} family has no paged KV cache or "
                  "state pool; using the contiguous slot engine")
        if args.kv_dtype != "bfloat16":
            print(f"note: --kv-dtype {args.kv_dtype} only applies to the "
                  "paged engine; the slot engine keeps its bf16 cache")
        if args.prefix_sharing:
            print("note: --prefix-sharing only applies to the paged engine")
        engine = ServeEngine(bundle, params, pctx, slots=args.slots,
                             max_seq=max(128, args.prompt_len + args.max_new + 2))

    if args.explain:
        report = (engine.graph_schedule_report()
                  if isinstance(engine, PagedServeEngine) else "")
        print(report if report else
              "no graph schedules to explain (needs --graph-prefill with "
              "the cost model on)")

    # a shared prompt head (the "system prompt") + a per-request tail, so
    # --prefix-sharing has something to dedupe
    head_len = max(args.prompt_len // 2, 1)
    head = list(range(2, 2 + head_len))

    async def serve() -> list:
        rows = []
        async with AsyncServeFrontend(engine) as front:
            streams = []
            for i in range(args.requests):
                slo = (SLO_TTFT if args.slo_mix and i % 2 else
                       SLO_THROUGHPUT)
                tail = [100 + i] * (args.prompt_len - head_len)
                streams.append(await front.submit(
                    head + tail, max_new_tokens=args.max_new, slo=slo))
            await asyncio.gather(*(s.drain() for s in streams))
            rows = [s.metrics() for s in streams]
        return rows

    rows = asyncio.run(serve())

    done = sum(1 for row in rows if row["tokens"] > 0)
    print(f"served {done}/{args.requests} requests")
    for row in rows:
        ttft = f"{row['ttft_s'] * 1e3:.1f}ms" if row["ttft_s"] else "-"
        lat = f"{row['latency_s'] * 1e3:.1f}ms" if row["latency_s"] else "-"
        print(f"  r{row['rid']:<3} slo={row['slo']:<10} "
              f"tokens={row['tokens']:<4} ttft={ttft:<9} latency={lat:<9} "
              f"preempt={row['preemptions']} jumped={row['queue_jumped']}")
    if isinstance(engine, PagedServeEngine):
        m = engine.metrics
        print(f"  ticks={m.ticks}  prefill={m.prefill_tokens} tok "
              f"({m.prefill_tps:.1f} tok/s)  decode={m.decode_tokens} tok "
              f"({m.decode_tps:.1f} tok/s)")
        if m.ttfts:
            print(f"  ttft mean={m.mean_ttft * 1e3:.1f}ms "
                  f"p50={m.p50_ttft * 1e3:.1f}ms")
        print(f"  page utilization peak={m.peak_page_utilization:.0%} "
              f"mean={m.mean_page_utilization:.0%}  "
              f"preemptions={m.preemptions}")
        if m.prefix_hit_requests or m.cow_copies:
            print(f"  prefix cache: hits={m.prefix_hit_requests} req / "
                  f"{m.prefix_hit_tokens} tok  cow={m.cow_copies}  "
                  f"effective-KV x{m.effective_kv_multiplier:.2f} "
                  f"({m.prompt_pages_logical} logical / "
                  f"{m.prompt_pages_unique} unique pages)")
        if m.spec_steps:
            print(f"  speculative: acceptance={m.acceptance_rate:.0%}  "
                  f"tokens/step={m.tokens_per_step:.2f}  "
                  f"decode tok/s incl draft={m.spec_decode_tps:.1f}")
        if engine.tp_plan is not None:
            print(f"  tensor parallel: {engine.tp_plan.degree} shards  "
                  f"kv pool/device={engine.kv_pool_bytes_per_device()}B "
                  f"(logical {engine.kv_pool_bytes()}B)  "
                  f"weights/device={engine.weight_bytes_per_device()}B")


if __name__ == "__main__":
    main()
