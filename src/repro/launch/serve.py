"""Serving launcher: batched decode with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 6
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    import jax

    from ..configs import get_config
    from ..models import build_model
    from ..parallel.sharding import ParallelContext
    from ..serve import Request, ServeEngine

    cfg = get_config(args.arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(bundle, params, ParallelContext(None),
                         slots=args.slots, max_seq=128)
    for i in range(args.requests):
        engine.submit(Request(rid=i, prompt=[1 + i, 2, 3],
                              max_new_tokens=args.max_new))
    done = []
    for tick in range(10_000):
        n = engine.step()
        if n == 0 and engine.pending.empty():
            break
    print(f"served {args.requests} requests in {tick + 1} engine ticks")


if __name__ == "__main__":
    main()
