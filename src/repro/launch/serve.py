"""Serving launcher: paged continuous batching with chunked prefill.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 6

``--engine slot`` falls back to the contiguous slot engine (the numerics
baseline, and the only path for ssm/hybrid/audio families).

Multi-precision (`repro.quant`, docs/quantization.md): ``--int8-weights``
serves the int8-weight variant of the model, ``--kv-dtype int8`` stores the
paged KV cache as int8 + per-(page slot, head) scales.

Speculative decoding (`repro.spec`, docs/architecture.md): ``--draft-model``
picks the draft proposer — ``ngram`` (self-drafting), ``auto`` (the draft
arch registered for the target in ``repro.configs.DRAFT_FOR``, falling back
to ngram), or an explicit draft arch name; ``--spec-k`` sets the per-slot
proposal budget.  Greedy outputs are token-identical to the plain engine.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--engine", choices=("paged", "slot"), default="paged")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page pool size (default: slots * 256/page_size)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--int8-weights", action="store_true",
                    help="serve the int8-weight variant "
                         "(repro.quant.quantize_params)")
    ap.add_argument("--kv-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16",
                    help="paged KV page-pool storage dtype")
    ap.add_argument("--graph-prefill", action="store_true",
                    help="route chunked prefill through the repro.graph "
                         "fused executor (paged engine only; docs/graph.md)")
    ap.add_argument("--draft-model", default=None,
                    help="speculative decoding draft: 'ngram', 'auto', or a "
                         "draft arch name (repro.spec; paged engine only)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens verified per step per slot")
    args = ap.parse_args()

    import jax

    from ..configs import get_config, get_draft_config
    from ..models import build_model
    from ..parallel.sharding import ParallelContext
    from ..serve import PagedServeEngine, Request, ServeEngine

    cfg = get_config(args.arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    if args.int8_weights:
        params = bundle.quantize_params(params)
    pctx = ParallelContext(None)
    if args.draft_model and not (args.engine == "paged"
                                 and bundle.supports_paged_kv):
        raise SystemExit(f"--draft-model requires the paged engine and a "
                         f"paged-KV family (got --engine {args.engine}, "
                         f"family {cfg.family!r})")
    if args.engine == "paged" and bundle.supports_paged_kv:
        engine_kw = dict(slots=args.slots, page_size=args.page_size,
                         num_pages=args.num_pages,
                         prefill_chunk=args.prefill_chunk,
                         kv_dtype=args.kv_dtype,
                         use_graph=args.graph_prefill)
        if args.draft_model:
            from ..models import build_draft_model
            from ..spec import SpeculativeServeEngine

            if args.draft_model == "ngram":
                draft_cfg = None
            elif args.draft_model == "auto":
                draft_cfg = get_draft_config(args.arch, smoke=True)
            else:  # an explicit *draft* arch name (not a target arch)
                draft_cfg = get_draft_config(args.draft_model, smoke=True,
                                             pairing=False)
                if draft_cfg is None:
                    raise SystemExit(
                        f"no draft config registered as {args.draft_model!r}")
            if draft_cfg is None:
                print(f"speculative: ngram self-draft, k={args.spec_k}")
                engine = SpeculativeServeEngine(
                    bundle, params, pctx, spec_k=args.spec_k, **engine_kw)
            else:
                print(f"speculative: draft={draft_cfg.name}, k={args.spec_k}")
                draft_bundle = build_draft_model(cfg, draft_cfg)
                draft_params = draft_bundle.init_params(jax.random.PRNGKey(1))
                engine = SpeculativeServeEngine(
                    bundle, params, pctx, spec_k=args.spec_k,
                    draft_bundle=draft_bundle, draft_params=draft_params,
                    **engine_kw)
        else:
            engine = PagedServeEngine(bundle, params, pctx, **engine_kw)
    else:
        if args.engine == "paged":
            print(f"note: {cfg.family!r} family has no paged KV cache; "
                  "using the contiguous slot engine")
        if args.kv_dtype != "bfloat16":
            print(f"note: --kv-dtype {args.kv_dtype} only applies to the "
                  "paged engine; the slot engine keeps its bf16 cache")
        engine = ServeEngine(bundle, params, pctx, slots=args.slots,
                             max_seq=max(128, args.prompt_len + args.max_new + 2))

    reqs = [Request(rid=i, prompt=[1 + i] + list(range(2, 2 + args.prompt_len - 1)),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()

    done = sum(r.done for r in reqs)
    print(f"served {done}/{args.requests} requests")
    if isinstance(engine, PagedServeEngine):
        m = engine.metrics
        print(f"  ticks={m.ticks}  prefill={m.prefill_tokens} tok "
              f"({m.prefill_tps:.1f} tok/s)  decode={m.decode_tokens} tok "
              f"({m.decode_tps:.1f} tok/s)")
        if m.ttfts:
            print(f"  ttft mean={m.mean_ttft * 1e3:.1f}ms "
                  f"p50={m.p50_ttft * 1e3:.1f}ms")
        print(f"  page utilization peak={m.peak_page_utilization:.0%} "
              f"mean={m.mean_page_utilization:.0%}  "
              f"preemptions={m.preemptions}")
        if m.spec_steps:
            print(f"  speculative: acceptance={m.acceptance_rate:.0%}  "
                  f"tokens/step={m.tokens_per_step:.2f}  "
                  f"decode tok/s incl draft={m.spec_decode_tps:.1f}")
            per_req = "  ".join(f"r{r.rid}={r.acceptance_rate:.0%}"
                                for r in reqs)
            print(f"  per-request acceptance: {per_req}")


if __name__ == "__main__":
    main()
