"""Production mesh factory.

Single-pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; ``pod`` is the
slow-link axis (DCN/inter-pod ICI): pure DP + optional int8-compressed
gradient reduction; MoE EP and ZeRO stay inside a pod.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(n_data: int = 4, n_model: int = 2, *, pods: int = 1):
    """Toy mesh for tests (8 host devices)."""
    if pods > 1:
        return jax.make_mesh((pods, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
