"""Training launcher: end-to-end driver around the fault-tolerant runtime.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

On a real cluster the same entry point runs under ``jax.distributed`` with
the production mesh; on this box it runs the smoke config on the local
device (or a host-device mesh via --host-devices N, set before jax init).
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host devices (mesh n/2 x 2); must be set "
                         "before the first jax import")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            "--xla_disable_hlo_passes=all-reduce-promotion "
            + os.environ.get("XLA_FLAGS", ""))

    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..checkpoint import CheckpointManager
    from ..configs import get_config
    from ..configs.shapes import ShapeSpec
    from ..data import DataCursor, SyntheticLMSource
    from ..models import build_model
    from ..parallel.sharding import make_context
    from ..runtime import TrainController
    from ..train.step import (TrainHyper, assemble_shardings, init_optimizer,
                              make_train_step)
    from .mesh import make_small_mesh

    cfg = get_config(args.arch, smoke=args.smoke)
    bundle = build_model(cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    mesh = None
    if args.host_devices:
        mesh = make_small_mesh(args.host_devices // 2, 2)
    pctx = make_context(mesh)

    params = bundle.init_params(jax.random.PRNGKey(0))
    opt = init_optimizer(cfg, params)
    if mesh is not None:
        pspecs, opt_fn, _ = assemble_shardings(bundle, pctx)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(jax.device_put, params, psh)
        osh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_fn(opt),
                           is_leaf=lambda x: isinstance(x, P))
        opt = jax.tree.map(jax.device_put, opt, osh)

    hyper = TrainHyper(peak_lr=args.lr, warmup=10, total_steps=args.steps)
    train_step = jax.jit(make_train_step(bundle, pctx, hyper))

    def step_fn(state, batch, step):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = train_step(params, opt, batch,
                                          jnp.asarray(step, jnp.int32))
        return (params, opt), metrics

    ckpt = CheckpointManager(args.ckpt_dir)
    cursor = DataCursor()
    state = (params, opt)
    if args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(target=state)
        cursor = DataCursor.from_dict(meta["cursor"])
        print(f"resumed from step {cursor.step}")

    source = SyntheticLMSource(cfg, shape)
    controller = TrainController(
        step_fn, ckpt, ckpt_every=args.ckpt_every,
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat.json"))
    state, report = controller.run(state, source, cursor, args.steps)
    print(f"done: {report.steps_completed} steps; "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}; "
          f"restarts={report.restarts} straggles={report.straggle_events}")


if __name__ == "__main__":
    main()
