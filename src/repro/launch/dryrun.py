import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # XLA *CPU* bug workaround: AllReducePromotion crashes on the barrier
    # all-reduce(copy) that shard_map emits for partial-manual regions
    # (MoE EP path).  CPU-only pass; irrelevant on real TPU toolchains.
    "--xla_disable_hlo_passes=all-reduce-promotion "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * the REAL step function (train_step incl. optimizer / prefill /
    decode_step) lowered and compiled against the production mesh with
    full in/out shardings — ``memory_analysis()`` proves per-chip fit,
  * cost terms: FLOPs / HBM bytes / collective wire bytes, scan-corrected
    via two shallow *unrolled* compiles (see roofline/analysis.py),
  * the three-term roofline + dominant bottleneck.

Results append to a JSON file (resumable; EXPERIMENTS.md tables are
generated from it by benchmarks/roofline_table.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--fast]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, applicable, input_specs, skip_reason
from ..configs.base import ModelConfig, depth_units, with_depth
from ..models import build_model
from ..parallel.sharding import make_context
from ..roofline import (RooflineTerms, cost_from_compiled, extrapolate,
                        model_flops, parse_collectives)
from ..roofline.analysis import analytic_hbm_bytes
from ..train.step import (TrainHyper, assemble_shardings, cache_spec,
                          init_optimizer, make_train_step, microbatches_for)
from .mesh import make_production_mesh

RESULTS_PATH = "dryrun_results.json"

# §Perf hillclimbing variants (see EXPERIMENTS.md): config overrides applied
# on top of the paper-faithful baseline.
VARIANTS = {
    "baseline": {},
    "bf16reduce": dict(bf16_reduce=True),
    "dots": dict(remat_policy="dots"),
    "bf16+dots": dict(bf16_reduce=True, remat_policy="dots"),
    "savecoll": dict(remat_policy="save_coll"),
    "bf16+savecoll": dict(bf16_reduce=True, remat_policy="save_coll"),
    "padheads": dict(rwkv_pad_heads_to=16),
    "bf16+padheads": dict(bf16_reduce=True, rwkv_pad_heads_to=16),
    "bf16+dots+padheads": dict(bf16_reduce=True, remat_policy="dots",
                               rwkv_pad_heads_to=16),
    "fsdp": dict(fsdp=True),
    "fsdp+dots": dict(fsdp=True, remat_policy="dots"),
    "dots+padheads": dict(remat_policy="dots", rwkv_pad_heads_to=16),
}


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(mesh, pctx, batch_specs: Dict[str, Any]):
    out = {}
    for k, v in batch_specs.items():
        b = v.shape[0]
        if b % max(pctx.dp_degree, 1) == 0 and b >= pctx.dp_degree:
            spec = P(tuple(pctx.dp_axes), *([None] * (v.ndim - 1)))
        else:  # tiny batch (long_500k b=1): replicate over DP
            spec = P()
        out[k] = NamedSharding(mesh, spec)
    return out


def lower_cell(
    cfg: ModelConfig,
    shape_name: str,
    mesh,
    *,
    microbatches: Optional[int] = None,
) -> Dict[str, Any]:
    """Lower+compile one cell on ``mesh``; returns artifact metrics."""
    shape = SHAPES[shape_name]
    pctx = make_context(mesh)
    if cfg.fsdp:
        # weight-gathered layout: the batch shards over EVERY mesh axis
        pctx = dataclasses.replace(
            pctx, dp_axes=tuple(pctx.dp_axes) + (pctx.tp_axis,))
    bundle = build_model(cfg)
    specs = input_specs(cfg, shape)
    abstract_params = bundle.abstract_params()
    pspecs, opt_specs_fn, _ = assemble_shardings(bundle, pctx)
    param_sh = _named(mesh, pspecs)

    if shape.kind == "train":
        nmb = microbatches if microbatches is not None else \
            microbatches_for(cfg, shape, pctx)
        hyper = TrainHyper(num_microbatches=nmb)
        step_fn = make_train_step(bundle, pctx, hyper)
        opt_abstract = jax.eval_shape(
            lambda p: init_optimizer(cfg, p), abstract_params)
        opt_sh = _named(mesh, opt_specs_fn(opt_abstract))
        batch = {k: v for k, v in specs.items()}
        batch_sh = _batch_shardings(mesh, pctx, batch)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            step_fn,
            in_shardings=(param_sh, opt_sh, batch_sh, None),
            out_shardings=(param_sh, opt_sh, None),
        )
        lowered = fn.lower(abstract_params, opt_abstract, batch, step_sds)
    elif shape.kind == "prefill":
        batch = dict(specs)
        batch_sh = _batch_shardings(mesh, pctx, batch)

        def prefill_fn(params, batch):
            return bundle.prefill(params, batch, pctx, max_seq=shape.seq_len)

        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
        lowered = fn.lower(abstract_params, batch)
    else:  # decode
        cache_abs = specs["cache"]
        cache_sh = _named(mesh, cache_spec(cfg, pctx, cache_abs))
        tok_sh = _batch_shardings(
            mesh, pctx, {"tokens": specs["tokens"], "lengths": specs["lengths"]})

        def decode_fn(params, cache, tokens, lengths):
            return bundle.decode_step(params, cache, tokens, lengths, pctx)

        fn = jax.jit(
            decode_fn,
            in_shardings=(param_sh, cache_sh, tok_sh["tokens"], tok_sh["lengths"]),
            out_shardings=(None, cache_sh),
        )
        lowered = fn.lower(abstract_params, cache_abs,
                           specs["tokens"], specs["lengths"])

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    flops, hbm = cost_from_compiled(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "wire_bytes": coll.wire_bytes,
        "coll_by_kind": coll.by_kind,
        "coll_count": coll.count,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "microbatches": microbatches,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             fast: bool = False, variant: str = "baseline") -> Dict[str, Any]:
    """Full protocol for one cell: real compile (memory proof) + depth-1/2
    unrolled compiles (cost extrapolation) + roofline terms."""
    cfg = ARCHS[arch]
    if VARIANTS.get(variant):
        cfg = dataclasses.replace(cfg, **VARIANTS[variant])
    shape = SHAPES[shape_name]
    t0 = time.time()
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": skip_reason(cfg, shape)}

    mesh = make_production_mesh(multi_pod=multi_pod)
    pctx = make_context(mesh)
    chips = mesh.size

    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16", "status": "ok",
    }
    # 1) the real full-depth scan compile: memory fit + schedule sanity
    real = lower_cell(cfg, shape_name, mesh)
    out["memory"] = real["memory"]
    out["coll_count_scan"] = real["coll_count"]

    # 2) shallow unrolled compiles for scan-corrected cost
    if fast:
        flops, hbm, wire = real["flops"], real["hbm_bytes"], real["wire_bytes"]
        coll_kind = real["coll_by_kind"]
    else:
        nmb = microbatches_for(cfg, shape, pctx) if shape.kind == "train" else 1
        c1 = lower_cell(with_depth(cfg, 1), shape_name, mesh, microbatches=1)
        c2 = lower_cell(with_depth(cfg, 2), shape_name, mesh, microbatches=1)
        depth = depth_units(cfg)
        flops = extrapolate(c1["flops"], c2["flops"], depth)
        hbm = extrapolate(c1["hbm_bytes"], c2["hbm_bytes"], depth)
        wire = extrapolate(c1["wire_bytes"], c2["wire_bytes"], depth)
        coll_kind = {
            k: extrapolate(c1["coll_by_kind"].get(k, 0.0),
                           c2["coll_by_kind"].get(k, 0.0), depth)
            for k in set(c1["coll_by_kind"]) | set(c2["coll_by_kind"])
        }
        out["depth_extrapolation"] = {
            "d1_flops": c1["flops"], "d2_flops": c2["flops"], "depth": depth,
        }

    cache_bytes = 0
    if shape.kind == "decode":
        import numpy as _np
        specs_tmp = input_specs(cfg, shape)
        cache_bytes = sum(
            int(_np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(specs_tmp["cache"]))
    hbm_analytic = analytic_hbm_bytes(
        cfg, shape, chips, param_count=cfg.param_count(),
        cache_bytes=cache_bytes,
    )
    terms = RooflineTerms(flops=flops, hbm_bytes=hbm_analytic,
                          wire_bytes=wire, chips=chips)
    mf = model_flops(cfg, shape, training=(shape.kind == "train"))
    out.update(terms.as_dict())
    out["hbm_bytes_hlo_unfused"] = hbm
    out["t_memory_hlo_upper_s"] = hbm / 819e9
    out["coll_by_kind"] = coll_kind
    out["model_flops"] = mf
    # cost_analysis is per-device; scale by chips for the global comparison
    out["useful_flops_ratio"] = mf / (flops * chips) if flops else None
    out["chips"] = chips
    out["compile_seconds"] = round(time.time() - t0, 1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="skip depth extrapolation (scan-count costs)")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    try:
        with open(args.out) as f:
            results = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        results = {}

    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                key = f"{arch}|{shape_name}|{'multi' if multi else 'single'}"
                if args.variant != "baseline":
                    key += f"|{args.variant}"
                if key in results and results[key].get("status") in ("ok", "skipped"):
                    print(f"[skip-cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    res = run_cell(arch, shape_name, multi_pod=multi,
                                   fast=args.fast, variant=args.variant)
                except Exception as e:  # record failures; they are bugs
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = res
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = res.get("status")
                dom = res.get("dominant", "-")
                print(f"    -> {status} dominant={dom} "
                      f"t=({res.get('t_compute_s', 0):.2e},"
                      f"{res.get('t_memory_s', 0):.2e},"
                      f"{res.get('t_collective_s', 0):.2e})s "
                      f"[{res.get('compile_seconds', 0)}s]", flush=True)


if __name__ == "__main__":
    main()
