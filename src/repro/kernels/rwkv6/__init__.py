from .ops import rwkv6_wkv  # noqa: F401
from .ref import rwkv6_ref  # noqa: F401
