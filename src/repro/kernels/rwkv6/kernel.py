"""RWKV-6 (Finch) WKV kernel with the recurrent state as APR.

Per head of size D the recurrence is

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with data-dependent per-channel decay ``w_t``.  ``S`` is a (D, D)
accumulator with *decay* — the paper's §I explicitly lists the P-extension
difference-accumulator as a target for the same APR mechanism; a decaying
accumulator is its continuous generalisation.  The kernel keeps S in VMEM
scratch across time-chunk grid steps; HBM sees only r/k/v/w chunk streams in
and y chunks out, never the O(D^2) state.

Grid: (B, H, T/chunk); the chunk loop inside the kernel is a fori_loop over
time steps (the sequential dependency is fundamental, the state residency
is what the APR buys).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(
    r_ref,   # (chunk, D)
    k_ref,   # (chunk, D)
    v_ref,   # (chunk, D)
    w_ref,   # (chunk, D)  decay in (0,1)
    u_ref,   # (1, D)      bonus
    o_ref,   # (chunk, D)
    s_ref,   # VMEM (D, D) APR: recurrent state
    *,
    chunk: int,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0, :].astype(jnp.float32)

    def step(t, state):
        r = r_ref[t, :].astype(jnp.float32)
        k = k_ref[t, :].astype(jnp.float32)
        v = v_ref[t, :].astype(jnp.float32)
        w = w_ref[t, :].astype(jnp.float32)
        kv = k[:, None] * v[None, :]              # (D, D) rank-1 update
        y = ((state + u[:, None] * kv).T @ r)     # (D,)
        o_ref[t, :] = y.astype(o_ref.dtype)
        return w[:, None] * state + kv            # decay + accumulate

    s_ref[...] = jax.lax.fori_loop(0, chunk, step, s_ref[...])


def rwkv6_call(
    r: jax.Array,  # (B, T, H, D)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0,1), same shape
    u: jax.Array,  # (H, D)
    *,
    chunk: int,  # required: chunk choice lives in repro.bench, not here
    interpret: bool = False,
) -> jax.Array:
    b, t, h, d = r.shape
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    def bhtd(x):
        return x.transpose(0, 2, 1, 3)  # (B, H, T, D)

    out = pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=chunk),
        grid=(b, h, n_chunks),
        in_specs=[
            pl.BlockSpec((None, None, chunk, d), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda i, j, c: (i, j, c, 0)),
            pl.BlockSpec((None, 1, d), lambda i, j, c: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, d), lambda i, j, c: (i, j, c, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(bhtd(r), bhtd(k), bhtd(v), bhtd(w), u.reshape(h, 1, d))
    return out.transpose(0, 2, 1, 3)  # back to (B, T, H, D)
