"""jit'd public wrapper for the RWKV-6 WKV kernel.

The time ``chunk`` (grid granularity over which the (D, D) recurrent-state
APR stays VMEM-resident) resolves through the shared tuned-config cache
(``repro.bench.config``): explicit ``chunk`` kwarg > ``config`` object >
tuned cache entry for this (shape, dtype, backend) > :func:`default_config`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...bench.config import BlockConfig, resolve_config, shape_key_from_dims
from .kernel import rwkv6_call

KERNEL_NAME = "rwkv6"


def shape_key(b, t, h, d) -> str:
    return shape_key_from_dims(b=b, t=t, h=h, d=d)


def default_config(b, t, h, d) -> BlockConfig:
    """Untuned heuristic: 64-step chunks balance stream size against the
    sequential fori_loop over the decaying (D, D) state."""
    return BlockConfig.make(chunk=64)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _rwkv6_jit(r, k, v, w, u, *, chunk: int, interpret: bool):
    t = r.shape[1]
    c = min(chunk, t)
    while t % c:  # legalise: chunk must divide T exactly
        c -= 1
    return rwkv6_call(r, k, v, w, u, chunk=c, interpret=interpret)


def rwkv6_wkv(r, k, v, w, u, *, chunk: Optional[int] = None,
              interpret: Optional[bool] = None,
              config: Optional[BlockConfig] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, h, d = r.shape
    cfg = resolve_config(
        KERNEL_NAME, shape_key(b, t, h, d), jnp.dtype(r.dtype).name,
        jax.default_backend(),
        default=default_config(b, t, h, d), override=config,
        explicit={"chunk": chunk},
    )
    return _rwkv6_jit(r, k, v, w, u, chunk=cfg["chunk"], interpret=interpret)
