"""jit'd public wrapper for the RWKV-6 WKV kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import rwkv6_call


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, w, u, *, chunk: int = 64, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t = r.shape[1]
    c = min(chunk, t)
    while t % c:
        c -= 1
    return rwkv6_call(r, k, v, w, u, chunk=c, interpret=interpret)
