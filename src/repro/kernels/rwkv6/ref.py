"""Pure-jnp oracle for the RWKV-6 recurrence (scan over time)."""
import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, w, u):
    """r/k/v/w: (B,T,H,D); u: (H,D) -> (B,T,H,D)."""
    b, t, h, d = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(state, inputs):
        rt, kt, vt, wt = inputs  # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,D,D)
        y = jnp.einsum(
            "bhij,bhi->bhj", state + uf[None, :, :, None] * kv, rt
        )
        new_state = wt[..., :, None] * state + kv
        return new_state, y

    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype)  # (B,T,H,D)
