"""Pure-jnp oracle for quant_matmul.

Mirrors the kernel's exact arithmetic — same per-row dynamic activation
quantization, integer contraction in int32, scales applied once to the
final int32 total — so the comparison is near-bit-exact (the integer part
is exact; only the two fp32 scale multiplies can differ in rounding)."""
import jax.numpy as jnp


def quant_matmul_ref(x, y_q, y_scale, out_dtype=jnp.float32):
    from .ops import quantize_activations  # same rounding as the kernel path

    if hasattr(y_q, "q"):  # QuantizedTensor
        y_q, y_scale = y_q.q, y_q.scale
    x_q, x_scale = quantize_activations(x)
    acc = jnp.dot(x_q, y_q, preferred_element_type=jnp.int32)
    n = y_q.shape[1]
    return (acc.astype(jnp.float32) * x_scale
            * y_scale.reshape(1, n)).astype(out_dtype)


def quant_matmul_fused_ref(x, y_q, y_scale, bias=None, activation="relu",
                           out_dtype=jnp.float32):
    from ..apr_matmul.ref import activation_ref

    acc = quant_matmul_ref(x, y_q, y_scale, out_dtype=jnp.float32)
    if bias is not None:
        acc = acc + bias.reshape(1, -1).astype(jnp.float32)
    return activation_ref(acc, activation).astype(out_dtype)
