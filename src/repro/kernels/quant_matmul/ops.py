"""jit'd public wrapper for the int8 APR matmul.

W8A8-dynamic contract: weights are quantized **offline** (symmetric
per-output-channel, :func:`repro.quant.quantize_channelwise`), activations
are quantized **per call** (symmetric per-row over the full K axis, so one
scale covers every K-block of a row and the int32 accumulation stays
exact).  Handles non-aligned shapes by zero padding (zero int8 operands
contribute nothing to the integer accumulation), resolves block sizes
through the shared tuned-config cache, and auto-selects interpret mode
off-TPU.

Config resolution order (see :func:`repro.bench.config.resolve_config`):
explicit ``block_*`` kwargs > explicit ``config`` object > tuned cache entry
for this (shape, dtype, backend) > :func:`default_config`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...bench.config import BlockConfig, resolve_config, shape_key_from_dims
from ...quant.quantize import INT8_MAX, QuantizedTensor, quantize_channelwise
from .kernel import quant_matmul_call, quant_matmul_fused_call

KERNEL_NAME = "quant_matmul"
FUSED_KERNEL_NAME = "quant_matmul_fused"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def shape_key(m: int, k: int, n: int) -> str:
    return shape_key_from_dims(m=m, k=k, n=n)


def default_config(m: int, k: int, n: int) -> BlockConfig:
    """Untuned heuristic: the fp32 family's 128-cube still holds — int8
    operands are 4x smaller in VMEM, but the int32 APR tile is the same
    ``block_m x block_n x 4B`` as the fp32 APR, and 128x128x128 keeps the
    MXU-aligned base tile."""
    return BlockConfig.make(block_m=128, block_n=128, block_k=128)


def quantize_weights(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Offline weight prep: (K, N) float -> int8 payload + (1, N) scales."""
    qt = quantize_channelwise(w, axis=-2)
    return qt.q, qt.scale


@jax.jit
def quantize_activations(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dynamic per-row symmetric int8: (M, K) float -> int8 + (M, 1) fp32.

    jit'd at module level so the kernel wrapper and the ``ref.py`` oracle
    share ONE compiled program: XLA is free to rewrite ``round(x / s)``
    (e.g. via a reciprocal multiply), and two different compilations can
    round borderline values to adjacent int8 codes — which would make the
    oracle comparison flaky at exactly the autotuner's correctness gate."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax, 1.0) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def _quant_matmul_jit(
    x_q: jax.Array,
    x_scale: jax.Array,
    y_q: jax.Array,
    y_scale: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype,
    interpret: bool,
) -> jax.Array:
    m, k = x_q.shape
    _, n = y_q.shape
    # Legalise the resolved blocks against the (padded) problem: never launch
    # a tile larger than the rounded-up operand.
    bm, bn, bk = (min(block_m, _round_up(m, 8)),
                  min(block_n, _round_up(n, 128)),
                  min(block_k, _round_up(k, 128)))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y_q, ((0, kp - k), (0, np_ - n)))
    xs = jnp.pad(x_scale, ((0, mp - m), (0, 0)))
    ys = jnp.pad(y_scale, ((0, 0), (0, np_ - n)))
    out = quant_matmul_call(
        xp, yp, xs, ys,
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n]


def quant_matmul(
    x: jax.Array,
    y_q: jax.Array,
    y_scale: Optional[jax.Array] = None,
    *,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """``x @ dequant(y)`` with int8 operands and an int32 VMEM APR.

    ``x`` is float (fp32/bf16) and is dynamically quantized per row;
    ``y_q``/``y_scale`` are the offline-quantized weight (pass a
    :class:`~repro.quant.QuantizedTensor` as ``y_q`` to omit ``y_scale``).
    """
    if isinstance(y_q, QuantizedTensor):
        y_q, y_scale = y_q.q, y_q.scale
    assert y_scale is not None, "y_scale required with a raw int8 payload"
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = y_q.shape
    cfg = resolve_config(
        KERNEL_NAME, shape_key(m, k, n), jnp.dtype(x.dtype).name,
        jax.default_backend(),
        default=default_config(m, k, n), override=config,
        explicit={"block_m": block_m, "block_n": block_n, "block_k": block_k},
    )
    x_q, x_scale = quantize_activations(x)
    return _quant_matmul_jit(
        x_q, x_scale, y_q, y_scale.reshape(1, n),
        block_m=cfg["block_m"], block_n=cfg["block_n"], block_k=cfg["block_k"],
        out_dtype=out_dtype, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "activation",
                     "out_dtype", "interpret"),
)
def _quant_matmul_fused_jit(
    x_q: jax.Array,
    x_scale: jax.Array,
    y_q: jax.Array,
    y_scale: jax.Array,
    bias: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    activation: str,
    out_dtype,
    interpret: bool,
) -> jax.Array:
    m, k = x_q.shape
    _, n = y_q.shape
    bm, bn, bk = (min(block_m, _round_up(m, 8)),
                  min(block_n, _round_up(n, 128)),
                  min(block_k, _round_up(k, 128)))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y_q, ((0, kp - k), (0, np_ - n)))
    xs = jnp.pad(x_scale, ((0, mp - m), (0, 0)))
    ys = jnp.pad(y_scale, ((0, 0), (0, np_ - n)))
    bp = jnp.pad(bias, ((0, 0), (0, np_ - n)))
    out = quant_matmul_fused_call(
        xp, yp, xs, ys, bp,
        block_m=bm, block_n=bn, block_k=bk,
        activation=activation, out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n]


def quant_matmul_fused(
    x: jax.Array,
    y_q: jax.Array,
    y_scale: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,   # (N,) or (1, N)
    *,
    activation: str = "relu",
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """``activation(x @ dequant(y) + bias)`` in one kernel: the scales,
    bias and activation all ride the int32 APR's single flush.  This is
    the kernel a quant-folded ``matmul_epilogue`` cluster dispatches to
    (``repro.graph``); tuned under its own ``quant_matmul_fused`` family.
    """
    if isinstance(y_q, QuantizedTensor):
        y_q, y_scale = y_q.q, y_q.scale
    assert y_scale is not None, "y_scale required with a raw int8 payload"
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = y_q.shape
    if bias is None:
        bias = jnp.zeros((1, n), jnp.float32)
    cfg = resolve_config(
        FUSED_KERNEL_NAME, shape_key(m, k, n), jnp.dtype(x.dtype).name,
        jax.default_backend(),
        default=default_config(m, k, n), override=config,
        explicit={"block_m": block_m, "block_n": block_n, "block_k": block_k},
    )
    x_q, x_scale = quantize_activations(x)
    return _quant_matmul_fused_jit(
        x_q, x_scale, y_q, y_scale.reshape(1, n),
        jnp.reshape(bias, (1, n)).astype(jnp.float32),
        block_m=cfg["block_m"], block_n=cfg["block_n"], block_k=cfg["block_k"],
        activation=activation, out_dtype=out_dtype, interpret=interpret,
    )
