"""Int8 blocked matmul with an int32 APR — the paper's mechanism at 8-bit.

This is ``apr_matmul`` with the precision story of the multi-precision
RISC-V processors (SPEED; the precision-scalable extreme-edge processor)
grafted on: both operands of the MXU contraction are int8, the running
block reduction lives in an **int32** VMEM scratch — the direct analogue of
the paper's 32-bit APR, which also accumulates narrow multiplies at full
width so precision is only committed once — and the per-(row, column)
scales are applied exactly once, at the ``rfsmac.s``-style flush:

* int8 ``dot`` + int32 ``+=`` into ``acc_ref``  = ``rfmac.s`` (multiply in
  EX, accumulate in the rented stage, no intermediate rounding),
* the ``@pl.when(last_k)`` scale+write-back      = ``rfsmac.s`` (one HBM
  write per output element, precision committed once).

Operands stream at 1 byte/element instead of 4, so the kernel moves ~4x
less weight traffic than the fp32 family for the same FLOPs; the analytic
model lives with the family registration in ``repro.bench.specs``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_matmul_kernel(x_ref, y_ref, xs_ref, ys_ref, o_ref, acc_ref,
                         *, n_k: int):
    """grid = (M/bm, N/bn, K/bk); acc_ref is the int32 APR (VMEM).

    x_ref (bm, bk) int8, y_ref (bk, bn) int8, xs_ref (bm, 1) fp32 per-row
    activation scales, ys_ref (1, bn) fp32 per-output-channel weight scales.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _reset_apr():  # rfsmac.s reset semantics, hoisted to loop entry
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # rfmac.s at int8: the MXU multiplies int8 x int8 and the APR
    # accumulates exactly in int32 — no rounding until the flush.
    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k_step == n_k - 1)
    def _flush_apr():
        # rfsmac.s write-back: scales applied once, one write per element.
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32) * xs_ref[...] * ys_ref[...]
        ).astype(o_ref.dtype)


def _quant_matmul_fused_kernel(x_ref, y_ref, xs_ref, ys_ref, b_ref, o_ref,
                               acc_ref, *, n_k: int, activation: str):
    """Fused-epilogue variant: identical int8 rfmac.s accumulation; the
    flush applies scales, bias and activation on the int32 APR's fp32
    readout — precision committed once, epilogue free of HBM traffic."""
    from ..apr_matmul.kernel import apply_epilogue

    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _reset_apr():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k_step == n_k - 1)
    def _flush_apr():
        acc = acc_ref[...].astype(jnp.float32) * xs_ref[...] * ys_ref[...]
        o_ref[...] = apply_epilogue(acc, b_ref[...],
                                    activation).astype(o_ref.dtype)


def quant_matmul_fused_call(
    x_q: jax.Array,       # (M, K) int8 activations
    y_q: jax.Array,       # (K, N) int8 weights
    x_scale: jax.Array,   # (M, 1) fp32
    y_scale: jax.Array,   # (1, N) fp32
    bias: jax.Array,      # (1, N) fp32; zeros for "no bias"
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    activation: str = "relu",
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call for ``activation(dequant(x_q @ y_q) + bias)``;
    shapes must already be multiples of the blocks."""
    m, k = x_q.shape
    k2, n = y_q.shape
    assert k == k2, (x_q.shape, y_q.shape)
    assert x_scale.shape == (m, 1) and y_scale.shape == (1, n), \
        (x_scale.shape, y_scale.shape)
    assert bias.shape == (1, n), bias.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_quant_matmul_fused_kernel, n_k=n_k,
                          activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x_q, y_q, x_scale, y_scale, bias)


def quant_matmul_call(
    x_q: jax.Array,       # (M, K) int8 activations
    y_q: jax.Array,       # (K, N) int8 weights
    x_scale: jax.Array,   # (M, 1) fp32 per-row activation scales
    y_scale: jax.Array,   # (1, N) fp32 per-output-channel weight scales
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; shapes must already be multiples of the blocks.

    Block sizes are required here — tile choices live in the tuned-config
    layer (``repro.bench``), not at pallas_call sites."""
    m, k = x_q.shape
    k2, n = y_q.shape
    assert k == k2, (x_q.shape, y_q.shape)
    assert x_scale.shape == (m, 1) and y_scale.shape == (1, n), \
        (x_scale.shape, y_scale.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x_q, y_q, x_scale, y_scale)
