from .ops import (quant_matmul, quant_matmul_fused,  # noqa: F401
                  quantize_activations, quantize_weights)
from .ref import quant_matmul_fused_ref, quant_matmul_ref  # noqa: F401
