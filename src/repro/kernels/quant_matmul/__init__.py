from .ops import (quant_matmul, quantize_activations,  # noqa: F401
                  quantize_weights)
from .ref import quant_matmul_ref  # noqa: F401
