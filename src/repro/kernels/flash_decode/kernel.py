"""Flash-decode attention with APR-resident online-softmax state.

The paper's §I "Versatility" argues the APR mechanism extends to "diverse
accumulation operations".  Online-softmax decode attention is exactly such
an operation: per query head it carries three running accumulators across
the KV-chunk reduction —

    m   (running max),  l   (running normaliser),  acc (running value sum)

Holding (m, l, acc) in VMEM scratch across the KV-chunk grid — instead of
materialising per-chunk partial attention to HBM — is the APR pattern; the
final ``acc / l`` normalisation + write-back is the ``rfsmac.s`` flush.

Layout: one grid step per (batch, kv_head, kv_chunk).  The G = Hq/Hkv query
heads of a GQA group form the rows of the (G, D) query block, so the MXU
contraction is (G, D) x (D, chunk) even at batch=1 decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(
    len_ref,       # SMEM (1,)  valid KV length
    q_ref,         # (G, D)
    k_ref,         # (chunk, D)
    v_ref,         # (chunk, D)
    o_ref,         # (G, D)
    m_ref,         # VMEM (G, 1)   APR: running max
    l_ref,         # VMEM (G, 1)   APR: running normaliser
    acc_ref,       # VMEM (G, D)   APR: running weighted value sum
    *,
    n_chunks: int,
    chunk: int,
    scale: float,
):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _reset():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, chunk)

    # mask out positions beyond this sequence's valid cache length
    valid = len_ref[pl.program_id(0)]
    base = c * chunk
    pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=1)
    s = jnp.where(pos < valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)          # rescale of old accumulators
    p = jnp.exp(s - m_new)                   # (G, chunk)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(c == n_chunks - 1)
    def _flush():  # rfsmac.s: normalise + write back once
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(
    len_ref,       # scalar prefetch (B,)   valid KV length per sequence
    bt_ref,        # scalar prefetch (B, P) block table (physical page ids)
    q_ref,         # (G, D)
    k_ref,         # (chunk, D)  one physical chunk, gathered via bt_ref
    v_ref,         # (chunk, D)
    *rest,         # [ks_ref, vs_ref,] o_ref, m_ref, l_ref, acc_ref
    n_chunks: int,
    chunk: int,
    scale: float,
    quantized: bool,
):
    """One kernel for both KV storage widths.

    ``quantized=False``: ``k_ref``/``v_ref`` are float chunks.
    ``quantized=True``: they are int8 and two extra ``(chunk, 1)`` fp32
    scale refs precede the output — pages stream at 1 byte/element and are
    dequantized *after* the gather, inside VMEM, so HBM only ever sees the
    narrow payload.  Everything else (length masking, dead-lane zeroing,
    the APR online softmax) is deliberately ONE copy of the logic.

    Trailing refs after the inputs: ``o_ref`` (G, D) output, then the VMEM
    APR scratch — ``m_ref`` (G, 1) running max, ``l_ref`` (G, 1) running
    normaliser, ``acc_ref`` (G, D) running weighted value sum.
    """
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _reset():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    if quantized:  # dequant in VMEM, per (page slot, head)
        k = k * ks_ref[...]
        v = v * vs_ref[...]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, chunk)

    # Logical positions are contiguous even though the pages are not: chunk
    # ``c`` always covers logical tokens [c*chunk, (c+1)*chunk).
    valid = len_ref[pl.program_id(0)]
    pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=1)
    live = pos < valid
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # Explicit zero for dead lanes: a fully-masked chunk (null-page padding
    # past ``valid``, or an idle slot with length 0) would otherwise give
    # exp(NEG_INF - NEG_INF) = 1 and pull garbage pages into the softmax.
    p = jnp.where(live, jnp.exp(s - m_new), 0.0)

    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(c == n_chunks - 1)
    def _flush():  # rfsmac.s: normalise + write back once
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_flash_decode_call(
    q: jax.Array,             # (B, Hq, D)
    k_pages: jax.Array,       # (P_pool, page_size, Hkv, D); int8 with scales
    v_pages: jax.Array,
    lengths: jax.Array,       # (B,) int32 valid logical KV length
    block_tables: jax.Array,  # (B, P_max) int32 physical page per logical page
    *,
    k_scales: jax.Array = None,  # (P_pool, page_size, Hkv) fp32; presence
    v_scales: jax.Array = None,  # selects the int8 gather-dequant variant
    chunk: int,  # tokens per grid step; must divide page_size
    interpret: bool = False,
) -> jax.Array:
    """Paged-KV variant of :func:`flash_decode_call`.

    Same APR-resident online softmax; the only difference is *where* each KV
    chunk comes from: the block table (a scalar-prefetch operand, so it is
    available to the BlockSpec index maps before the kernel body runs)
    translates logical chunk ``c`` to a physical chunk inside the page pool.
    Entries past a sequence's allocated pages must point at a valid physical
    page (the allocator pads with the null page 0); masking by ``lengths``
    keeps those positions out of the softmax.

    With ``k_scales``/``v_scales`` the page pools are int8: the scale pools
    ride the SAME BlockSpec index map as their payload pools, so a chunk
    and its scales always move together, and the kernel dequantizes in VMEM
    after the gather.
    """
    b, hq, d = q.shape
    p_pool, page_size, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    quantized = k_scales is not None
    assert hq % hkv == 0
    g = hq // hkv
    assert page_size % chunk == 0, (page_size, chunk)
    cpp = page_size // chunk          # chunks per page
    n_chunks = p_max * cpp
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, g, d)
    # (Hkv, P_pool * page_size, D): flat physical token axis so one block
    # index addresses any (page, within-page chunk) pair.
    kt = k_pages.transpose(2, 0, 1, 3).reshape(hkv, p_pool * page_size, d)
    vt = v_pages.transpose(2, 0, 1, 3).reshape(hkv, p_pool * page_size, d)

    def kv_index(i, h, c, lens, bt):
        # logical chunk c -> physical chunk: page bt[i, c // cpp], then the
        # (c % cpp)-th chunk inside it
        return (h, bt[i, c // cpp] * cpp + c % cpp, 0)

    in_specs = [
        pl.BlockSpec((None, None, g, d), lambda i, h, c, lens, bt: (i, h, 0, 0)),
        pl.BlockSpec((None, chunk, d), kv_index),
        pl.BlockSpec((None, chunk, d), kv_index),
    ]
    operands = [qg, kt, vt]
    if quantized:
        in_specs += [pl.BlockSpec((None, chunk, 1), kv_index),
                     pl.BlockSpec((None, chunk, 1), kv_index)]
        operands += [
            k_scales.transpose(2, 0, 1).reshape(hkv, p_pool * page_size, 1),
            v_scales.transpose(2, 0, 1).reshape(hkv, p_pool * page_size, 1),
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_chunks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, g, d),
                               lambda i, h, c, lens, bt: (i, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_decode_kernel, n_chunks=n_chunks, chunk=chunk, scale=scale,
            quantized=quantized,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), *operands)
    return out.reshape(b, hq, d)


def flash_decode_call(
    q: jax.Array,        # (B, Hq, D)
    k: jax.Array,        # (B, S, Hkv, D)
    v: jax.Array,        # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) int32 valid KV length per sequence
    *,
    chunk: int,  # required: chunk choice lives in repro.bench, not here
    interpret: bool = False,
) -> jax.Array:
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, g, d)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, S, D)
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(
            _flash_decode_kernel, n_chunks=n_chunks, chunk=chunk, scale=scale
        ),
        grid=(b, hkv, n_chunks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, g, d), lambda i, h, c: (i, h, 0, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda i, h, c: (i, h, c, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda i, h, c: (i, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, g, d), lambda i, h, c: (i, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, hq, d)
