"""Pure-jnp oracles: masked softmax attention for single-token decode,
contiguous and paged (block-table-gathered) KV layouts."""
import jax.numpy as jnp


def gather_pages(pages, block_tables):
    """Materialise the logical contiguous view of a paged KV pool.

    pages: (P_pool, page_size, H, D); block_tables: (B, P_max) int32 ->
    (B, P_max * page_size, H, D).  Row ``b``'s logical token ``t`` lives at
    ``pages[block_tables[b, t // page_size], t % page_size]``.
    """
    b, p_max = block_tables.shape
    g = jnp.take(pages, block_tables.reshape(-1), axis=0)
    return g.reshape(b, p_max * pages.shape[1], *pages.shape[2:])


def paged_decode_attention_ref(q, k_pages, v_pages, lengths, block_tables):
    """q: (B,Hq,D); k/v_pages: (P_pool,ps,Hkv,D); lengths: (B,);
    block_tables: (B,P_max) -> (B,Hq,D).  Gather-then-attend oracle for the
    paged kernel: positions past ``lengths`` (incl. anything routed through
    the null page) are masked before the softmax."""
    return decode_attention_ref(q, gather_pages(k_pages, block_tables),
                                gather_pages(v_pages, block_tables), lengths)


def paged_decode_attention_q_ref(q, k_pages, v_pages, k_scales, v_scales,
                                 lengths, block_tables):
    """int8-KV oracle: dequantize the gathered pages (per-(page slot, head)
    fp32 scales over the head dim), then attend as the float oracle."""
    k = (gather_pages(k_pages, block_tables).astype(jnp.float32)
         * gather_pages(k_scales[..., None], block_tables))
    v = (gather_pages(v_pages, block_tables).astype(jnp.float32)
         * gather_pages(v_scales[..., None], block_tables))
    return decode_attention_ref(q, k.astype(q.dtype), v.astype(q.dtype),
                                lengths)


def decode_attention_ref(q, k, v, lengths):
    """q: (B,Hq,D); k/v: (B,S,Hkv,D); lengths: (B,) -> (B,Hq,D)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)   # (B,Hkv,S,D)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, kt) / (d ** 0.5)
    mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, vt)
    return out.reshape(b, hq, d).astype(q.dtype)
