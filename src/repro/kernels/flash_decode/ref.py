"""Pure-jnp oracle: masked softmax attention for single-token decode."""
import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths):
    """q: (B,Hq,D); k/v: (B,S,Hkv,D); lengths: (B,) -> (B,Hq,D)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)   # (B,Hkv,S,D)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, kt) / (d ** 0.5)
    mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, vt)
    return out.reshape(b, hq, d).astype(q.dtype)
