from .ops import flash_decode, flash_decode_paged  # noqa: F401
from .ref import (decode_attention_ref, gather_pages,  # noqa: F401
                  paged_decode_attention_q_ref, paged_decode_attention_ref)
