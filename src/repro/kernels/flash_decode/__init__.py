from .ops import flash_decode  # noqa: F401
from .ref import decode_attention_ref  # noqa: F401
