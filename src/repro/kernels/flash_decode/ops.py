"""jit'd public wrapper for flash-decode."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_decode_call


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    chunk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-new-token attention over a (possibly partially filled) KV cache.

    q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,).  S must be a multiple
    of ``chunk`` (caches are allocated in chunk multiples by serve/kvcache).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    s = k.shape[1]
    c = min(chunk, s)
    return flash_decode_call(q, k, v, lengths, chunk=c, interpret=interpret)
