"""jit'd public wrapper for flash-decode.

The KV ``chunk`` (reduction granularity of the online-softmax APR) resolves
through the shared tuned-config cache (``repro.bench.config``): explicit
``chunk`` kwarg > ``config`` object > tuned cache entry for this (shape,
dtype, backend) > :func:`default_config`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...bench.config import BlockConfig, resolve_config, shape_key_from_dims
from .kernel import flash_decode_call

KERNEL_NAME = "flash_decode"


def shape_key(b, hq, hkv, d, s) -> str:
    return shape_key_from_dims(b=b, hq=hq, hkv=hkv, d=d, s=s)


def default_config(b, hq, hkv, d, s) -> BlockConfig:
    """Untuned heuristic: 512-wide KV chunks amortise the (G, chunk) MXU
    contraction while the (m, l, acc) APR stays tiny."""
    return BlockConfig.make(chunk=512)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _flash_decode_jit(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    chunk: int,
    interpret: bool,
) -> jax.Array:
    s = k.shape[1]
    c = min(chunk, s)
    while s % c:  # legalise: chunk must divide S (guards stale cache entries)
        c -= 1
    return flash_decode_call(q, k, v, lengths, chunk=c, interpret=interpret)


def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    chunk: Optional[int] = None,
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """Single-new-token attention over a (possibly partially filled) KV cache.

    q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,).  S must be a multiple
    of ``chunk`` (caches are allocated in chunk multiples by serve/kvcache).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    cfg = resolve_config(
        KERNEL_NAME, shape_key(b, hq, hkv, d, s), jnp.dtype(q.dtype).name,
        jax.default_backend(),
        default=default_config(b, hq, hkv, d, s), override=config,
        explicit={"chunk": chunk},
    )
    return _flash_decode_jit(q, k, v, lengths, chunk=cfg["chunk"],
                             interpret=interpret)
