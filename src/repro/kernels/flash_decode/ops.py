"""jit'd public wrappers for flash-decode (contiguous and paged KV).

The KV ``chunk`` (reduction granularity of the online-softmax APR) resolves
through the shared tuned-config cache (``repro.bench.config``): explicit
``chunk`` kwarg > ``config`` object > tuned cache entry for this (shape,
dtype, backend) > :func:`default_config`.  The paged variant tunes the same
way under its own family name (``flash_decode_paged``) — its chunk must
additionally divide the page size.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...bench.config import BlockConfig, resolve_config, shape_key_from_dims
from .kernel import flash_decode_call, paged_flash_decode_call

KERNEL_NAME = "flash_decode"
PAGED_KERNEL_NAME = "flash_decode_paged"


def shape_key(b, hq, hkv, d, s) -> str:
    return shape_key_from_dims(b=b, hq=hq, hkv=hkv, d=d, s=s)


def default_config(b, hq, hkv, d, s) -> BlockConfig:
    """Untuned heuristic: 512-wide KV chunks amortise the (G, chunk) MXU
    contraction while the (m, l, acc) APR stays tiny."""
    return BlockConfig.make(chunk=512)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _flash_decode_jit(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    chunk: int,
    interpret: bool,
) -> jax.Array:
    s = k.shape[1]
    c = min(chunk, s)
    while s % c:  # legalise: chunk must divide S (guards stale cache entries)
        c -= 1
    return flash_decode_call(q, k, v, lengths, chunk=c, interpret=interpret)


def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    lengths: jax.Array,
    *,
    chunk: Optional[int] = None,
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """Single-new-token attention over a (possibly partially filled) KV cache.

    q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,).  S must be a multiple
    of ``chunk`` (caches are allocated in chunk multiples by serve/kvcache).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    cfg = resolve_config(
        KERNEL_NAME, shape_key(b, hq, hkv, d, s), jnp.dtype(q.dtype).name,
        jax.default_backend(),
        default=default_config(b, hq, hkv, d, s), override=config,
        explicit={"chunk": chunk},
    )
    return _flash_decode_jit(q, k, v, lengths, chunk=cfg["chunk"],
                             interpret=interpret)


# ---------------------------------------------------------------------------
# Paged variant: KV lives in a shared page pool, gathered via block tables.
# ---------------------------------------------------------------------------


def paged_shape_key(b, hq, hkv, d, pages, ps) -> str:
    return shape_key_from_dims(b=b, hq=hq, hkv=hkv, d=d, pages=pages, ps=ps)


def paged_default_config(b, hq, hkv, d, pages, ps) -> BlockConfig:
    """Untuned heuristic: one page per grid step — the DMA granularity the
    allocator already guarantees is contiguous."""
    return BlockConfig.make(chunk=ps)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _paged_flash_decode_jit(q, k_pages, v_pages, lengths, block_tables, *,
                            chunk: int, interpret: bool) -> jax.Array:
    ps = k_pages.shape[1]
    c = min(chunk, ps)
    while ps % c:  # legalise: chunk must divide the page size
        c -= 1
    return paged_flash_decode_call(q, k_pages, v_pages, lengths, block_tables,
                                   chunk=c, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _paged_flash_decode_q_jit(q, k_pages, v_pages, k_scales, v_scales,
                              lengths, block_tables, *,
                              chunk: int, interpret: bool) -> jax.Array:
    ps = k_pages.shape[1]
    c = min(chunk, ps)
    while ps % c:  # legalise: chunk must divide the page size
        c -= 1
    return paged_flash_decode_call(q, k_pages, v_pages, lengths, block_tables,
                                   k_scales=k_scales, v_scales=v_scales,
                                   chunk=c, interpret=interpret)


def flash_decode_paged(
    q: jax.Array,             # (B, Hq, D)
    k_pages: jax.Array,       # (P_pool, page_size, Hkv, D)
    v_pages: jax.Array,       # (P_pool, page_size, Hkv, D)
    lengths: jax.Array,       # (B,)
    block_tables: jax.Array,  # (B, P_max)
    *,
    k_scales: Optional[jax.Array] = None,  # (P_pool, page_size, Hkv) fp32
    v_scales: Optional[jax.Array] = None,  # when pages are int8
    chunk: Optional[int] = None,
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """Single-new-token attention over a paged KV cache.

    Logical token ``t`` of sequence ``b`` lives at
    ``k_pages[block_tables[b, t // page_size], t % page_size]``.  Block-table
    entries past a sequence's allocated pages must hold a valid physical page
    id (the allocator pads with the reserved null page 0); masking by
    ``lengths`` keeps them out of the softmax.  Rows with ``lengths == 0``
    (idle slots) return zeros.

    Passing ``k_scales``/``v_scales`` selects the **int8-KV** kernel: the
    page pools hold int8 payloads (see ``repro.models.lm.init_paged_cache``
    with ``kv_dtype="int8"``) which are dequantized in VMEM after the
    block-table gather.  The tuned ``chunk`` is cached separately (shape
    key suffix ``_kvint8``) — int8 chunks are 4x smaller in VMEM, so the
    winning chunk can legitimately differ from the bf16/fp32 pools'.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, d = q.shape
    _, ps, hkv, _ = k_pages.shape
    p_max = block_tables.shape[1]
    quantized = k_scales is not None
    key = paged_shape_key(b, hq, hkv, d, p_max, ps)
    if quantized:
        key += "_kvint8"
    cfg = resolve_config(
        PAGED_KERNEL_NAME, key,
        jnp.dtype(q.dtype).name, jax.default_backend(),
        default=paged_default_config(b, hq, hkv, d, p_max, ps),
        override=config, explicit={"chunk": chunk},
    )
    if quantized:
        return _paged_flash_decode_q_jit(q, k_pages, v_pages, k_scales,
                                         v_scales, lengths, block_tables,
                                         chunk=cfg["chunk"],
                                         interpret=interpret)
    return _paged_flash_decode_jit(q, k_pages, v_pages, lengths, block_tables,
                                   chunk=cfg["chunk"], interpret=interpret)
