from .ops import apr_matmul, accumulator_traffic_bytes  # noqa: F401
from .ref import matmul_ref  # noqa: F401
