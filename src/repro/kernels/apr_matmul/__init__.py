from .ops import (apr_matmul, apr_matmul_fused,  # noqa: F401
                  accumulator_traffic_bytes)
from .ref import activation_ref, matmul_fused_ref, matmul_ref  # noqa: F401
