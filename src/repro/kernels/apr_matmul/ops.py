"""jit'd public wrapper for the APR-resident matmul.

Handles non-aligned shapes by zero padding (zeros contribute nothing to the
accumulation), resolves its block sizes through the shared tuned-config
cache (``repro.bench.config``), and auto-selects interpret mode off-TPU so
the same call sites work in tests/examples on CPU.

Config resolution order (see :func:`repro.bench.config.resolve_config`):
explicit ``block_*`` kwargs > explicit ``config`` object > tuned cache entry
for this (shape, dtype, backend) > :func:`default_config`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...bench.config import BlockConfig, resolve_config, shape_key_from_dims
from ...core.apr import reduction_hbm_traffic
from .kernel import apr_matmul_call, apr_matmul_fused_call

KERNEL_NAME = "apr_matmul"
FUSED_KERNEL_NAME = "apr_matmul_fused"

ACTIVATIONS = ("none", "relu", "silu", "gelu")


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def shape_key(m: int, k: int, n: int, residency: str = "apr") -> str:
    # residency is part of the key: blocks tuned for the APR-resident kernel
    # must never silently apply to the HBM-baseline comparison runs
    return shape_key_from_dims(m=m, k=k, n=n) + f"_res{residency}"


def default_config(m: int, k: int, n: int) -> BlockConfig:
    """Untuned heuristic: 128x128x128 keeps both MXU operands
    (128, 128)-aligned; the fp32 APR tile is ``block_m x block_n x 4B``
    (64 KiB at defaults), and the three live blocks plus double buffering
    stay well inside the ~16 MiB of VMEM."""
    return BlockConfig.make(block_m=128, block_n=128, block_k=128)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "residency", "interpret"),
)
def _apr_matmul_jit(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype,
    residency: str,
    interpret: bool,
) -> jax.Array:
    m, k = x.shape
    _, n = y.shape
    # Legalise the resolved blocks against the (padded) problem: never launch
    # a tile larger than the rounded-up operand.
    bm, bn, bk = (min(block_m, _round_up(m, 8)),
                  min(block_n, _round_up(n, 128)),
                  min(block_k, _round_up(k, 128)))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = apr_matmul_call(
        xp, yp,
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, residency=residency, interpret=interpret,
    )
    return out[:m, :n]


def apr_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    out_dtype=jnp.float32,
    residency: str = "apr",
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """``x @ y`` with the running block-accumulator held in VMEM (APR)."""
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = y.shape
    cfg = resolve_config(
        KERNEL_NAME, shape_key(m, k, n, residency), jnp.dtype(x.dtype).name,
        jax.default_backend(),
        default=default_config(m, k, n), override=config,
        explicit={"block_m": block_m, "block_n": block_n, "block_k": block_k},
    )
    return _apr_matmul_jit(
        x, y,
        block_m=cfg["block_m"], block_n=cfg["block_n"], block_k=cfg["block_k"],
        out_dtype=out_dtype, residency=residency, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "activation",
                     "out_dtype", "interpret"),
)
def _apr_matmul_fused_jit(
    x: jax.Array,
    y: jax.Array,
    bias: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    activation: str,
    out_dtype,
    interpret: bool,
) -> jax.Array:
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = (min(block_m, _round_up(m, 8)),
                  min(block_n, _round_up(n, 128)),
                  min(block_k, _round_up(k, 128)))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(bias.reshape(1, n).astype(jnp.float32),
                 ((0, 0), (0, np_ - n)))
    out = apr_matmul_fused_call(
        xp, yp, bp,
        block_m=bm, block_n=bn, block_k=bk,
        activation=activation, out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n]


def apr_matmul_fused(
    x: jax.Array,
    y: jax.Array,
    bias: Optional[jax.Array] = None,   # (N,) or (1, N)
    *,
    activation: str = "relu",
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """``activation(x @ y + bias)`` in one kernel: the epilogue runs on the
    APR tile at the flush, so bias/activation add zero HBM round-trips.
    This is the kernel the graph compiler's ``matmul_epilogue`` clusters
    dispatch to (``repro.graph``); tuned under its own family name so an
    epilogue-bearing GEMM can pick different tiles than a bare one."""
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}; "
                         f"expected one of {ACTIVATIONS}")
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = y.shape
    if bias is None:
        bias = jnp.zeros((1, n), jnp.float32)
    cfg = resolve_config(
        FUSED_KERNEL_NAME, shape_key_from_dims(m=m, k=k, n=n),
        jnp.dtype(x.dtype).name, jax.default_backend(),
        default=default_config(m, k, n), override=config,
        explicit={"block_m": block_m, "block_n": block_n, "block_k": block_k},
    )
    return _apr_matmul_fused_jit(
        x, y, bias,
        block_m=cfg["block_m"], block_n=cfg["block_n"], block_k=cfg["block_k"],
        activation=activation, out_dtype=out_dtype, interpret=interpret,
    )


def accumulator_traffic_bytes(m: int, n: int, k: int, block_k: int,
                              residency: str, out_bytes: int = 2) -> int:
    """Analytic HBM traffic attributable to the accumulator (Table-III
    'memory access' analogue; used by benchmarks/kernel_traffic.py)."""
    n_steps = max(1, (k + block_k - 1) // block_k)
    return reduction_hbm_traffic(m * n, n_steps, out_bytes, residency)
