"""jit'd public wrapper for the APR-resident matmul.

Handles non-aligned shapes by zero padding (zeros contribute nothing to the
accumulation), picks TPU-friendly default blocks, and auto-selects interpret
mode off-TPU so the same call sites work in tests/examples on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.apr import reduction_hbm_traffic
from .kernel import apr_matmul_call


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "residency", "interpret"),
)
def apr_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=jnp.float32,
    residency: str = "apr",
    interpret: bool | None = None,
) -> jax.Array:
    """``x @ y`` with the running block-accumulator held in VMEM (APR).

    Hardware-alignment notes: blocks default to 128x128x128 so both MXU
    operands are (128, 128)-aligned; the fp32 APR tile is
    ``block_m x block_n x 4B`` (64 KiB at defaults), and the three live
    blocks plus double buffering stay well inside the ~16 MiB of VMEM.
    """
    if interpret is None:
        interpret = not _on_tpu()
    m, k = x.shape
    _, n = y.shape
    bm, bn, bk = (min(block_m, _round_up(m, 8)),
                  min(block_n, _round_up(n, 128)),
                  min(block_k, _round_up(k, 128)))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = apr_matmul_call(
        xp, yp,
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, residency=residency, interpret=interpret,
    )
    return out[:m, :n]


def accumulator_traffic_bytes(m: int, n: int, k: int, block_k: int,
                              residency: str, out_bytes: int = 2) -> int:
    """Analytic HBM traffic attributable to the accumulator (Table-III
    'memory access' analogue; used by benchmarks/kernel_traffic.py)."""
    n_steps = max(1, (k + block_k - 1) // block_k)
    return reduction_hbm_traffic(m * n, n_steps, out_bytes, residency)
