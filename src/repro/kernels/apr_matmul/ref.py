"""Pure-jnp oracles for apr_matmul and its fused-epilogue variant."""
import jax
import jax.numpy as jnp


def matmul_ref(x, y, out_dtype=jnp.float32):
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(out_dtype)


def activation_ref(x, activation: str):
    """Epilogue activations in the order the fused kernels apply them."""
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "silu":
        return x * jax.nn.sigmoid(x)
    if activation == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {activation!r}")


def matmul_fused_ref(x, y, bias=None, activation="relu",
                     out_dtype=jnp.float32):
    acc = jnp.dot(x, y, preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.reshape(1, -1).astype(jnp.float32)
    return activation_ref(acc, activation).astype(out_dtype)
