"""APR-resident blocked matmul — the paper's mechanism on the MXU.

Mapping (see DESIGN.md §2):

* the fp32 VMEM scratch ``acc_ref``     = the APR,
* one K-grid step's ``dot`` + ``+=``    = ``rfmac.s`` (multiply in EX,
  accumulate in the rented stage),
* the ``@pl.when(last_k)`` flush+reset  = ``rfsmac.s``,
* Pallas's grid software pipeline (DMA of block k+1 overlapped with MXU
  compute on block k) = the rented MEM-stage/EX-stage overlap.

The ``hbm`` residency variant reproduces the F-extension/baseline behaviour
for comparison: K is the outermost grid axis, so the output block leaves
VMEM and the fp32 partial round-trips through HBM on every reduction step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apr_matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """grid = (M/bm, N/bn, K/bk); acc_ref is the APR (VMEM, fp32)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _reset_apr():  # rfsmac.s reset semantics, hoisted to loop entry
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # rfmac.s: multiply (MXU) + accumulate into the APR.  fp32 accumulation
    # regardless of input dtype, like the 32-bit APR of the paper.
    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_step == n_k - 1)
    def _flush_apr():  # rfsmac.s write-back: HBM sees one write per element
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def apply_epilogue(acc, bias, activation: str):
    """Shared epilogue math for the fused kernel variants: runs at the
    ``rfsmac.s`` flush, on the APR tile, before the single HBM write —
    the kernel-level form of the graph compiler's epilogue fusion
    (``repro.graph.passes.fuse_matmul_epilogue``)."""
    if bias is not None:
        acc = acc + bias
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif activation != "none":
        raise ValueError(f"unknown epilogue activation {activation!r}")
    return acc


def _apr_matmul_fused_kernel(x_ref, y_ref, b_ref, o_ref, acc_ref, *,
                             n_k: int, activation: str):
    """Fused-epilogue variant: identical rfmac.s accumulation; the flush
    applies ``activation(acc + bias)`` while the tile is still in the APR,
    so bias/activation cost zero extra HBM round-trips (the unfused path
    writes the matmul result, re-reads it for the bias add, writes again,
    re-reads for the activation...)."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _reset_apr():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k_step == n_k - 1)
    def _flush_apr():  # rfsmac.s write-back with the epilogue folded in
        o_ref[...] = apply_epilogue(
            acc_ref[...], b_ref[...], activation).astype(o_ref.dtype)


def apr_matmul_fused_call(
    x: jax.Array,
    y: jax.Array,
    bias: jax.Array,       # (1, N) fp32; pass zeros for "no bias"
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    activation: str = "relu",
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call for ``activation(x @ y + bias)``; shapes must
    already be multiples of the blocks."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert bias.shape == (1, n), bias.shape
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k

    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_apr_matmul_fused_kernel, n_k=n_k,
                          activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, y, bias)


def _hbm_matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """Baseline residency: partial sums revisit the output block every K
    step.  K is the outermost grid axis so the block cannot stay resident —
    the fmac.s-through-memory pattern of Fig. 1(b)."""
    k_step = pl.program_id(0)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def apr_matmul_call(
    x: jax.Array,
    y: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype=jnp.float32,
    residency: str = "apr",
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; shapes must already be multiples of the blocks.

    Block sizes are required here — tile choices live in the tuned-config
    layer (``repro.bench``), not at pallas_call sites."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k

    if residency == "apr":
        grid = (m // block_m, n // block_n, n_k)
        return pl.pallas_call(
            functools.partial(_apr_matmul_kernel, n_k=n_k),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
                pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
            interpret=interpret,
        )(x, y)

    if residency == "hbm":
        # fp32 output so the revisited partial loses no precision (the
        # paper's baseline also keeps a full-precision partial in memory).
        grid = (n_k, m // block_m, n // block_n)
        out = pl.pallas_call(
            functools.partial(_hbm_matmul_kernel, n_k=n_k),
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda kk, i, j: (i, kk)),
                pl.BlockSpec((block_k, block_n), lambda kk, i, j: (kk, j)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), lambda kk, i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=interpret,
        )(x, y)
        return out.astype(out_dtype)

    raise ValueError(f"unknown residency {residency!r}")
