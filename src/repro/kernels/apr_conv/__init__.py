from .ops import apr_conv2d, apr_conv2d_fused  # noqa: F401
from .ref import conv2d_fused_ref, conv2d_ref  # noqa: F401
