from .ops import apr_conv2d  # noqa: F401
from .ref import conv2d_ref  # noqa: F401
