"""jit'd public wrapper for APR-resident conv2d.

Block sizes resolve through the shared tuned-config cache
(``repro.bench.config``): explicit ``block_*`` kwargs > ``config`` object >
tuned cache entry for this (shape, dtype, backend) > :func:`default_config`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...bench.config import BlockConfig, resolve_config, shape_key_from_dims
from .kernel import conv2d_call, conv2d_fused_call

KERNEL_NAME = "apr_conv"
FUSED_KERNEL_NAME = "apr_conv_fused"


def shape_key(b, h, w, c, hf, wf, m, stride, padding,
              residency: str = "apr") -> str:
    # residency is part of the key: blocks tuned for the APR-resident kernel
    # must never silently apply to the HBM-baseline comparison runs
    return shape_key_from_dims(b=b, h=h, w=w, c=c, hf=hf, wf=wf, m=m,
                               s=stride, p=padding) + f"_res{residency}"


def default_config(b, h, w, c, hf, wf, m, stride, padding) -> BlockConfig:
    """Untuned heuristic: MXU-aligned 128 tiles on the im2col matmul."""
    return BlockConfig.make(block_m=128, block_n=128, block_k=128)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "block_m", "block_n", "block_k",
                     "residency", "interpret"),
)
def _apr_conv2d_jit(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int,
    padding: int,
    block_m: int,
    block_n: int,
    block_k: int,
    residency: str,
    interpret: bool,
) -> jax.Array:
    # Small-problem legalisation keeps MXU alignment without huge padding
    # waste: cap block_k at the power of two covering the im2col reduction.
    k_red = f.shape[0] * f.shape[1] * f.shape[2]
    bk = min(block_k, max(128, 1 << (k_red - 1).bit_length()))
    return conv2d_call(
        x, f, stride=stride, padding=padding,
        block_m=block_m, block_n=block_n, block_k=bk,
        residency=residency, interpret=interpret,
    )


def apr_conv2d(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    residency: str = "apr",
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, w, c = x.shape
    hf, wf, _, m_out = f.shape
    cfg = resolve_config(
        KERNEL_NAME,
        shape_key(b, h, w, c, hf, wf, m_out, stride, padding, residency),
        jnp.dtype(x.dtype).name, jax.default_backend(),
        default=default_config(b, h, w, c, hf, wf, m_out, stride, padding),
        override=config,
        explicit={"block_m": block_m, "block_n": block_n, "block_k": block_k},
    )
    return _apr_conv2d_jit(
        x, f, stride=stride, padding=padding,
        block_m=cfg["block_m"], block_n=cfg["block_n"], block_k=cfg["block_k"],
        residency=residency, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "block_m", "block_n", "block_k",
                     "activation", "interpret"),
)
def _apr_conv2d_fused_jit(
    x: jax.Array,
    f: jax.Array,
    bias: jax.Array,
    *,
    stride: int,
    padding: int,
    block_m: int,
    block_n: int,
    block_k: int,
    activation: str,
    interpret: bool,
) -> jax.Array:
    k_red = f.shape[0] * f.shape[1] * f.shape[2]
    bk = min(block_k, max(128, 1 << (k_red - 1).bit_length()))
    return conv2d_fused_call(
        x, f, bias, stride=stride, padding=padding,
        block_m=block_m, block_n=block_n, block_k=bk,
        activation=activation, interpret=interpret,
    )


def apr_conv2d_fused(
    x: jax.Array,
    f: jax.Array,
    bias: Optional[jax.Array] = None,   # (M,) or (1, M)
    *,
    activation: str = "relu",
    stride: int = 1,
    padding: int = 0,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    interpret: Optional[bool] = None,
    config: Optional[BlockConfig] = None,
) -> jax.Array:
    """``activation(conv2d(x, f) + bias)`` with the epilogue folded into
    the im2col reduction's APR flush — the kernel the graph compiler's
    ``conv_epilogue`` clusters dispatch to (``repro.graph``).  Tuned under
    its own ``apr_conv_fused`` family name."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, w, c = x.shape
    hf, wf, _, m_out = f.shape
    if bias is None:
        bias = jnp.zeros((1, m_out), jnp.float32)
    cfg = resolve_config(
        FUSED_KERNEL_NAME,
        shape_key_from_dims(b=b, h=h, w=w, c=c, hf=hf, wf=wf, m=m_out,
                            s=stride, p=padding),
        jnp.dtype(x.dtype).name, jax.default_backend(),
        default=default_config(b, h, w, c, hf, wf, m_out, stride, padding),
        override=config,
        explicit={"block_m": block_m, "block_n": block_n, "block_k": block_k},
    )
    return _apr_conv2d_fused_jit(
        x, f, jnp.reshape(bias, (1, m_out)),
        stride=stride, padding=padding,
        block_m=cfg["block_m"], block_n=cfg["block_n"], block_k=cfg["block_k"],
        activation=activation, interpret=interpret,
    )
