"""jit'd public wrapper for APR-resident conv2d."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import conv2d_call


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "block_m", "block_n", "block_k",
                     "residency", "interpret"),
)
def apr_conv2d(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    residency: str = "apr",
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # Small-problem fallback keeps MXU alignment without huge padding waste.
    k_red = f.shape[0] * f.shape[1] * f.shape[2]
    bk = min(block_k, max(128, 1 << (k_red - 1).bit_length()))
    return conv2d_call(
        x, f, stride=stride, padding=padding,
        block_m=block_m, block_n=block_n, block_k=min(bk, block_k),
        residency=residency, interpret=interpret,
    )
