"""APR-resident 2-D convolution (NHWC).

The paper's benchmark operator.  TPU adaptation: convolution is lowered to
an im2col patch matrix times a reshaped filter bank, and the reduction over
C*Hf*Wf — the paper's l/m/n loops — runs through the same APR-resident
blocked matmul kernel, so the partial sum for every output pixel stays in
VMEM for the whole l/m/n reduction exactly as the APR holds it for the whole
inner loop in Fig. 1(c).

The im2col expansion itself is done by XLA (gather-free slicing): on TPU the
patch extraction is a layout change that overlaps with the first matmul
DMA; the FLOP-carrying reduction is the Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..apr_matmul.kernel import apr_matmul_call, apr_matmul_fused_call


def im2col(x: jax.Array, hf: int, wf: int, stride: int, padding: int) -> jax.Array:
    """(B, H, W, C) -> (B*Ho*Wo, Hf*Wf*C) patch matrix."""
    b, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
        h, w = h + 2 * padding, w + 2 * padding
    ho = (h - hf) // stride + 1
    wo = (w - wf) // stride + 1
    # Static slice per (di, dj) filter offset: Hf*Wf strided slices, no gather.
    cols = []
    for di in range(hf):
        for dj in range(wf):
            sl = jax.lax.slice(
                x,
                (0, di, dj, 0),
                (b, di + (ho - 1) * stride + 1, dj + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            cols.append(sl.reshape(b * ho * wo, c))
    return jnp.concatenate(cols, axis=-1), ho, wo


def conv2d_call(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    block_m: int,
    block_n: int,
    block_k: int,
    residency: str = "apr",
    interpret: bool = False,
) -> jax.Array:
    """x: (B,H,W,C), f: (Hf,Wf,C,M) -> (B,Ho,Wo,M).

    Block sizes are required here — tile choices live in the tuned-config
    layer (``repro.bench``), not at pallas_call sites."""
    b = x.shape[0]
    hf, wf, c, m_out = f.shape
    patches, ho, wo = im2col(x, hf, wf, stride, padding)
    fmat = f.reshape(hf * wf * c, m_out)
    # pad to block multiples
    mm, kk = patches.shape
    nn = m_out
    pad_m = (-mm) % block_m
    pad_k = (-kk) % block_k
    pad_n = (-nn) % block_n
    patches = jnp.pad(patches, ((0, pad_m), (0, pad_k)))
    fmat = jnp.pad(fmat, ((0, pad_k), (0, pad_n)))
    out = apr_matmul_call(
        patches, fmat,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=jnp.float32, residency=residency, interpret=interpret,
    )
    return out[:mm, :nn].reshape(b, ho, wo, m_out)


def conv2d_fused_call(
    x: jax.Array,
    f: jax.Array,
    bias: jax.Array,        # (1, M) fp32; zeros for "no bias"
    *,
    stride: int = 1,
    padding: int = 0,
    block_m: int,
    block_n: int,
    block_k: int,
    activation: str = "relu",
    interpret: bool = False,
) -> jax.Array:
    """``activation(conv2d(x, f) + bias)`` with the epilogue applied while
    the im2col reduction tile is still in the APR — conv+bias+relu costs
    one HBM write per output pixel, like the unfused conv alone."""
    b = x.shape[0]
    hf, wf, c, m_out = f.shape
    patches, ho, wo = im2col(x, hf, wf, stride, padding)
    fmat = f.reshape(hf * wf * c, m_out)
    mm, kk = patches.shape
    nn = m_out
    pad_m = (-mm) % block_m
    pad_k = (-kk) % block_k
    pad_n = (-nn) % block_n
    patches = jnp.pad(patches, ((0, pad_m), (0, pad_k)))
    fmat = jnp.pad(fmat, ((0, pad_k), (0, pad_n)))
    bmat = jnp.pad(bias.astype(jnp.float32), ((0, 0), (0, pad_n)))
    out = apr_matmul_fused_call(
        patches, fmat, bmat,
        block_m=block_m, block_n=block_n, block_k=block_k,
        activation=activation, out_dtype=jnp.float32, interpret=interpret,
    )
    return out[:mm, :nn].reshape(b, ho, wo, m_out)
