"""Pure-jnp oracle: lax.conv_general_dilated in NHWC/HWIO layout."""
import jax
import jax.numpy as jnp


def conv2d_ref(x, f, *, stride: int = 1, padding: int = 0):
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        f.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
