"""Pure-jnp oracle: lax.conv_general_dilated in NHWC/HWIO layout."""
import jax
import jax.numpy as jnp


def conv2d_ref(x, f, *, stride: int = 1, padding: int = 0):
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        f.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_fused_ref(x, f, bias=None, activation="relu", *, stride: int = 1,
                     padding: int = 0):
    from ..apr_matmul.ref import activation_ref

    out = conv2d_ref(x, f, stride=stride, padding=padding)
    if bias is not None:
        out = out + bias.reshape(1, 1, 1, -1).astype(jnp.float32)
    return activation_ref(out, activation)
