"""Mamba-2 (SSD) selective-state-space kernel with the SSM state as APR.

Per head (head dim P, state dim N) with scalar per-head decay:

    h_t = exp(a * dt_t) * h_{t-1} + dt_t * (x_t  B_t^T)     h: (P, N)
    y_t = h_t C_t + D * x_t

``h`` is a decaying accumulator of rank-1 updates — the APR pattern again.
The kernel keeps h in VMEM scratch across time-chunk grid steps; only the
x/B/C/dt chunk streams and y chunks touch HBM.

Grid: (B, H, T/chunk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba2_kernel(
    x_ref,    # (chunk, P)
    b_ref,    # (chunk, N)
    c_ref,    # (chunk, N)
    dt_ref,   # (chunk, 1)
    a_ref,    # (1, 1)  per-head log-decay (negative)
    d_ref,    # (1, 1)  per-head skip
    o_ref,    # (chunk, P)
    h_ref,    # VMEM (P, N)  APR: SSM state
    *,
    chunk: int,
):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _reset():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0, 0].astype(jnp.float32)
    d_skip = d_ref[0, 0].astype(jnp.float32)

    def step(t, h):
        x = x_ref[t, :].astype(jnp.float32)        # (P,)
        bt = b_ref[t, :].astype(jnp.float32)       # (N,)
        ct = c_ref[t, :].astype(jnp.float32)       # (N,)
        dt = dt_ref[t, 0].astype(jnp.float32)
        decay = jnp.exp(a * dt)
        h = decay * h + dt * (x[:, None] * bt[None, :])   # (P, N)
        y = h @ ct + d_skip * x
        o_ref[t, :] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def mamba2_call(
    x: jax.Array,    # (B, T, H, P)
    b: jax.Array,    # (B, T, N)    shared across heads (Mamba-2 style)
    c: jax.Array,    # (B, T, N)
    dt: jax.Array,   # (B, T, H)
    a: jax.Array,    # (H,)
    d: jax.Array,    # (H,)
    *,
    chunk: int,  # required: chunk choice lives in repro.bench, not here
    interpret: bool = False,
) -> jax.Array:
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0
    n_chunks = t // chunk

    xh = x.transpose(0, 2, 1, 3)                      # (B, H, T, P)
    bh = jnp.broadcast_to(b[:, None], (bsz, h, t, n))
    ch = jnp.broadcast_to(c[:, None], (bsz, h, t, n))
    dth = dt.transpose(0, 2, 1)[..., None]            # (B, H, T, 1)

    out = pl.pallas_call(
        functools.partial(_mamba2_kernel, chunk=chunk),
        grid=(bsz, h, n_chunks),
        in_specs=[
            pl.BlockSpec((None, None, chunk, p), lambda i, j, cc: (i, j, cc, 0)),
            pl.BlockSpec((None, None, chunk, n), lambda i, j, cc: (i, j, cc, 0)),
            pl.BlockSpec((None, None, chunk, n), lambda i, j, cc: (i, j, cc, 0)),
            pl.BlockSpec((None, None, chunk, 1), lambda i, j, cc: (i, j, cc, 0)),
            pl.BlockSpec((None, 1, 1), lambda i, j, cc: (j, 0, 0)),
            pl.BlockSpec((None, 1, 1), lambda i, j, cc: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, p), lambda i, j, cc: (i, j, cc, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, t, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xh, bh, ch, dth, a.reshape(h, 1, 1), d.reshape(h, 1, 1))
    return out.transpose(0, 2, 1, 3)
