"""jit'd public wrapper for the Mamba-2 SSD kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import mamba2_call


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x, b, c, dt, a, d, *, chunk: int = 64, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t = x.shape[1]
    ck = min(chunk, t)
    while t % ck:
        ck -= 1
    return mamba2_call(x, b, c, dt, a, d, chunk=ck, interpret=interpret)
