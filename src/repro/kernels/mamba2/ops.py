"""jit'd public wrapper for the Mamba-2 SSD kernel.

The time ``chunk`` (grid granularity over which the (P, N) SSM-state APR
stays VMEM-resident) resolves through the shared tuned-config cache
(``repro.bench.config``): explicit ``chunk`` kwarg > ``config`` object >
tuned cache entry for this (shape, dtype, backend) > :func:`default_config`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...bench.config import BlockConfig, resolve_config, shape_key_from_dims
from .kernel import mamba2_call

KERNEL_NAME = "mamba2"


def shape_key(b, t, h, p, n) -> str:
    return shape_key_from_dims(b=b, t=t, h=h, p=p, n=n)


def default_config(b, t, h, p, n) -> BlockConfig:
    """Untuned heuristic: 64-step chunks keep the x/B/C/dt streams small
    while amortising the sequential fori_loop launch."""
    return BlockConfig.make(chunk=64)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _mamba2_jit(x, b, c, dt, a, d, *, chunk: int, interpret: bool):
    t = x.shape[1]
    ck = min(chunk, t)
    while t % ck:  # legalise: chunk must divide T exactly
        ck -= 1
    return mamba2_call(x, b, c, dt, a, d, chunk=ck, interpret=interpret)


def mamba2_ssd(x, b, c, dt, a, d, *, chunk: Optional[int] = None,
               interpret: Optional[bool] = None,
               config: Optional[BlockConfig] = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    cfg = resolve_config(
        KERNEL_NAME, shape_key(bsz, t, h, p, n), jnp.dtype(x.dtype).name,
        jax.default_backend(),
        default=default_config(bsz, t, h, p, n), override=config,
        explicit={"chunk": chunk},
    )
    return _mamba2_jit(x, b, c, dt, a, d, chunk=cfg["chunk"],
                       interpret=interpret)
