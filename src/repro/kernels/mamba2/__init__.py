from .ops import mamba2_ssd  # noqa: F401
from .ref import mamba2_ref  # noqa: F401
