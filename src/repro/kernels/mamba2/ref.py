"""Pure-jnp oracle for the Mamba-2 SSD recurrence."""
import jax
import jax.numpy as jnp


def mamba2_ref(x, b, c, dt, a, d):
    """x: (B,T,H,P); b/c: (B,T,N); dt: (B,T,H); a/d: (H,) -> (B,T,H,P)."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    xf, bf, cf, dtf = (v.astype(jnp.float32) for v in (x, b, c, dt))
    af, df = a.astype(jnp.float32), d.astype(jnp.float32)

    def step(hstate, inputs):  # hstate: (B,H,P,N)
        xt, bt, ct, dtt = inputs  # (B,H,P), (B,N), (B,N), (B,H)
        decay = jnp.exp(af[None, :] * dtt)[..., None, None]   # (B,H,1,1)
        upd = dtt[..., None, None] * (xt[..., :, None] * bt[:, None, None, :])
        hstate = decay * hstate + upd
        y = jnp.einsum("bhpn,bn->bhp", hstate, ct) + df[None, :, None] * xt
        return hstate, y

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        xf.transpose(1, 0, 2, 3),
        bf.transpose(1, 0, 2),
        cf.transpose(1, 0, 2),
        dtf.transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
