"""Level-B Pallas TPU kernels.  Every kernel applies the paper's APR
(accumulator-residency) mechanism to a different reduction:

* ``apr_matmul``   — blocked matmul, fp32 APR tile across the K grid
* ``apr_conv``     — conv2d = im2col + apr_matmul (the paper's operator)
* ``flash_decode`` — online-softmax decode, (m, l, acc) APR per head
* ``rwkv6``        — data-dependent-decay state APR (Finch WKV)
* ``mamba2``       — SSD state APR
* ``quant_matmul`` — int8 x int8 matmul, int32 APR tile

The matmul/conv/quant families also ship fused-epilogue variants
(``apr_matmul_fused`` / ``apr_conv_fused`` / ``quant_matmul_fused``
bench families): bias + activation applied at the APR flush, zero extra
HBM round-trips — the kernels the graph compiler (``repro.graph``)
dispatches its epilogue clusters to.

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, auto-interpret off-TPU), ref.py (pure-jnp oracle).
"""
