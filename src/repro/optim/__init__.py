from .adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from .adafactor import AdafactorState, adafactor_init, adafactor_update  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
