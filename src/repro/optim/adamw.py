"""AdamW with fp32 state, bf16 params — functional, shard-spec aware.

Optimizer state (m, v, master) is sharded ZeRO-1 style over the ``data``
axis via ``parallel.sharding.zero1_spec`` — the update itself needs no
explicit collectives: GSPMD reshards gradients into the state sharding,
updates locally, and reshards the new params out (the classic
reduce-scatter / all-gather pair falls out of the specs).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 master copy of params


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(f32, params),
        v=jax.tree.map(f32, params),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
    )


def adamw_update(
    grads, state: AdamWState, lr: jax.Array,
    *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, grad_clip=1.0,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)) + 1e-12)
    scale = jnp.minimum(1.0, grad_clip / gnorm)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        return m, v, p

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), new_master)
    return new_params, AdamWState(step, new_m, new_v, new_master)
