"""Adafactor (factored second moment, no momentum) — the production choice
for the 400-480B MoE archs on 16 GB/chip parts: state is O(rows+cols) per
matrix instead of O(rows*cols), so arctic-480b's optimizer fits where AdamW
(12 bytes/param) cannot (see DESIGN.md §4 memory table)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row factors (or full v for <2D leaves)
    vc: Any   # col factors (zeros() sentinel for <2D leaves)


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params) -> AdafactorState:
    def row(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def col(p):
        if _factored(p.shape):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(row, params),
        vc=jax.tree.map(col, params),
    )


def adafactor_update(
    grads, state: AdafactorState, params, lr,
    *, decay=0.99, eps=1e-30, clip_threshold=1.0, weight_decay=0.0,
) -> Tuple[Any, AdafactorState]:
    step = state.step + 1

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(g.shape):
            vr = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            u = g / jnp.sqrt(jnp.maximum(r[..., None] * vc[..., None, :], eps))
        else:
            vr = decay * vr + (1 - decay) * g2
            u = g / jnp.sqrt(jnp.maximum(vr, eps))
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        new_p = p.astype(jnp.float32) - lr * u - lr * weight_decay * p.astype(jnp.float32)
        return vr, vc, new_p.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.vr)
    flat_c = treedef.flatten_up_to(state.vc)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, r, c, p) for g, r, c, p in zip(flat_g, flat_r, flat_c, flat_p)]
    new_vr = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_vc = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_params = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, AdafactorState(step, new_vr, new_vc)
