"""LR schedules (pure functions of the step counter)."""
import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr=3e-4, warmup=100, total=10_000, floor=0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(step / warmup, 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, peak_lr * cos)
