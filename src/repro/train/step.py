"""train_step / serve_step factories with full sharding assembly.

The train step is the function the multi-pod dry-run lowers: loss (masked
next-token CE), gradient accumulation over microbatches (lax.scan), optimizer
update (AdamW ZeRO-1 or Adafactor), all expressed at global shapes — GSPMD
inserts the grad all-reduce, ZeRO reduce-scatter/all-gather and TP
collectives from the in/out shardings.

Gradient compression (error-feedback int8) applies to the cross-pod stage
of the gradient reduction when enabled — see parallel/compression.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeSpec
from ..models.registry import ModelBundle
from ..optim import (adafactor_init, adafactor_update, adamw_init,
                     adamw_update, warmup_cosine)
from ..parallel.compression import compressed_value_and_grad
from ..parallel.sharding import ParallelContext, param_specs, zero1_spec


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    num_microbatches: int = 1
    compress_cross_pod: bool = False


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Gather-free CE: with the vocab dim sharded over ``model``, both the
    logsumexp and the one-hot contraction reduce over the sharded axis via
    all-reduce — no (B,S,V) all-gather ever materialises."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    correct = jnp.einsum("btv,btv->bt", logits, onehot)
    ll = correct - lse
    if mask is not None:
        return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.mean(ll)


def _loss_fn(bundle: ModelBundle, pctx: ParallelContext, params, batch):
    logits = bundle.forward(params, batch, pctx)
    mask = None
    if bundle.cfg.family == "vlm":  # vision prefix positions carry no labels
        s = logits.shape[1]
        mask = (jnp.arange(s) >= bundle.cfg.vision_tokens)[None, :].astype(jnp.float32)
        mask = jnp.broadcast_to(mask, logits.shape[:2])
    return cross_entropy_loss(logits, batch["labels"], mask)


def microbatches_for(cfg: ModelConfig, shape: ShapeSpec, pctx: ParallelContext) -> int:
    """Grad-accum depth: cap per-DP-shard microbatch at
    cfg.max_microbatch_tokens tokens."""
    local_batch = max(1, shape.global_batch // max(pctx.dp_degree, 1))
    tokens = local_batch * shape.seq_len
    mb = max(1, -(-tokens // cfg.max_microbatch_tokens))
    while local_batch % mb:
        mb += 1
    return min(mb, local_batch)


def init_optimizer(cfg: ModelConfig, params):
    if cfg.optimizer == "adafactor":
        return adafactor_init(params)
    return adamw_init(params)


def make_train_step(
    bundle: ModelBundle,
    pctx: ParallelContext,
    hyper: TrainHyper,
) -> Callable:
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics)."""
    cfg = bundle.cfg

    def train_step(params, opt_state, batch, step):
        lr = warmup_cosine(step, peak_lr=hyper.peak_lr, warmup=hyper.warmup,
                           total=hyper.total_steps)
        nmb = hyper.num_microbatches
        vg = functools.partial(
            compressed_value_and_grad,
            functools.partial(_loss_fn, bundle, pctx),
            pctx=pctx, enabled=hyper.compress_cross_pod,
        )
        if nmb <= 1:
            loss, grads = vg(params, batch)
        else:
            # split batch leading dim into (nmb, b/nmb, ...) and lax.scan
            def split(x):
                b = x.shape[0]
                return x.reshape(nmb, b // nmb, *x.shape[1:])
            mb_batch = {k: split(v) for k, v in batch.items()}

            def accum(carry, mb):
                loss, grads = vg(params, mb)
                acc_loss, acc_grads = carry
                return (acc_loss + loss,
                        jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                     acc_grads, grads)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zeros), mb_batch)
            loss = loss / nmb
            grads = jax.tree.map(lambda g: g / nmb, grads)

        if cfg.optimizer == "adafactor":
            new_params, new_opt = adafactor_update(grads, opt_state, params, lr)
        else:
            new_params, new_opt = adamw_update(grads, opt_state, lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


# ---------------------------------------------------------------------------
# Sharding assembly for pjit (params / optimizer / batch / cache specs).
# ---------------------------------------------------------------------------


def assemble_shardings(bundle: ModelBundle, pctx: ParallelContext):
    """Returns (param_spec_tree, opt_spec_fn, batch_spec_fn)."""
    cfg = bundle.cfg
    logical = bundle.logical_axes()
    pspecs = param_specs(logical, pctx, kv_heads=cfg.num_kv_heads,
                         fsdp=cfg.fsdp)
    shapes = {k: v.shape for k, v in bundle.abstract_params().items()}

    def opt_specs(opt_state):
        """Mirror param specs onto optimizer leaves, ZeRO-1 sharded."""
        def spec_for_leaf(path_params_spec, shape):
            return zero1_spec(path_params_spec, shape, pctx)

        if cfg.optimizer == "adafactor":
            vr = {k: P(*list(pspecs[k])[:-1]) if len(shapes[k]) >= 2 and
                  shapes[k][-1] > 1 and shapes[k][-2] > 1 else pspecs[k]
                  for k in pspecs}
            vc = {}
            for k in pspecs:
                if len(shapes[k]) >= 2 and shapes[k][-1] > 1 and shapes[k][-2] > 1:
                    entries = list(pspecs[k])
                    vc[k] = P(*(entries[:-2] + entries[-1:]))
                else:
                    vc[k] = P()
            return type(opt_state)(step=P(), vr=vr, vc=vc)
        m = {k: spec_for_leaf(pspecs[k], shapes[k]) for k in pspecs}
        return type(opt_state)(
            step=P(), m=dict(m), v=dict(m),
            master={k: spec_for_leaf(pspecs[k], shapes[k]) for k in pspecs},
        )

    def batch_specs(batch):
        return {k: P(tuple(pctx.dp_axes), *([None] * (v.ndim - 1)))
                for k, v in batch.items()}

    return pspecs, opt_specs, batch_specs


def cache_spec(cfg: ModelConfig, pctx: ParallelContext, cache_abstract):
    """KV-cache / state sharding for serving: batch over DP axes, the cache
    sequence axis over ``model`` (softmax reductions distribute — the
    sharded form of the APR online-softmax accumulator).  State tensors
    (ssm/wkv/conv) shard batch over DP and the inner dim over model."""
    tp = pctx.tp_axis
    dp = tuple(pctx.dp_axes)
    dp_deg = max(pctx.dp_degree, 1)
    tp_deg = max(pctx.tp_degree, 1)

    def spec_for(key: str, leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        if key in ("k", "v", "self_k", "self_v", "attn_k", "attn_v",
                   "cross_k", "cross_v"):
            # (..., B, S, Hkv, Dh): batch over dp, seq over model; if the
            # batch can't shard (long_500k b=1), the seq axis takes BOTH
            # mesh axis groups — distributed flash-decode over the cache.
            entries = [None] * nd
            b, s = shape[-4], shape[-3]
            if b % dp_deg == 0 and b >= dp_deg:
                entries[-4] = dp
                if s % tp_deg == 0:
                    entries[-3] = tp
            elif s % (dp_deg * tp_deg) == 0:
                entries[-3] = dp + (tp,)
            elif s % tp_deg == 0:
                entries[-3] = tp
            return P(*entries)

        def batch_or_none(idx=1):
            return dp if shape[idx] % dp_deg == 0 and shape[idx] >= dp_deg else None

        if key in ("wkv", "ssm"):   # (L, B, H, D/P, D/N)
            h = shape[2]
            return P(None, batch_or_none(), tp if h % tp_deg == 0 else None,
                     None, None)
        if key == "conv":           # (L, B, K-1, CH)
            ch = shape[-1]
            return P(None, batch_or_none(), None,
                     tp if ch % tp_deg == 0 else None)
        if key in ("tmix_x", "cmix_x"):  # (L, B, d)
            return P(None, batch_or_none(), None)
        return P()

    return {k: spec_for(k, v) for k, v in cache_abstract.items()}


def make_serve_steps(bundle: ModelBundle, pctx: ParallelContext):
    cfg = bundle.cfg

    def prefill_step(params, batch):
        return bundle.prefill(params, batch, pctx)

    def decode_step(params, cache, tokens, lengths):
        return bundle.decode_step(params, cache, tokens, lengths, pctx)

    return prefill_step, decode_step
