"""APR-style memory planner: graph-level traffic accounting + arena reuse.

Two planners, both analytic (backend-independent, like the Table-III
models in ``repro.core.apr``):

* :func:`memory_report` — the paper's "memory access frequency" metric at
  graph granularity: every intermediate value that materializes costs one
  write (producer flush) plus one read per consumer.  Fusion removes
  cluster-internal values from the count entirely — they live in the
  producer's register tile, the graph-level APR.  Comparing the report
  before/after fusion is the headline ``BENCH_graph.json`` number.

* :func:`arena_plan` — for the intermediates that still materialize, a
  first-fit offset assignment over liveness intervals (value live from its
  producing node to its last consuming node), so unfused intermediates
  reuse one arena the way freed KV pages are re-rented.  ``arena_bytes``
  (the plan's high-water mark) vs ``naive_bytes`` (every intermediate its
  own buffer) quantifies the reuse.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from .ir import Graph

_ALIGN = 128  # arena offsets stay TPU-lane aligned


@dataclasses.dataclass
class MemoryReport:
    """Graph-level traffic accounting (bytes are analytic, not measured)."""
    n_nodes: int
    n_intermediates: int
    intermediate_bytes: int      # one write per materialized intermediate
    intermediate_traffic: int    # write + one read per consumer
    output_bytes: int
    const_bytes: int

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def memory_report(g: Graph) -> MemoryReport:
    consumers = g.consumers()
    inter = g.intermediates()
    traffic = 0
    for v in inter:
        n_reads = len(consumers.get(v.id, []))
        traffic += v.nbytes * (1 + n_reads)
    return MemoryReport(
        n_nodes=len(g.nodes),
        n_intermediates=len(inter),
        intermediate_bytes=sum(v.nbytes for v in inter),
        intermediate_traffic=traffic,
        output_bytes=sum(g.values[vid].nbytes for vid in g.outputs),
        const_bytes=g.const_bytes(),
    )


@dataclasses.dataclass
class ArenaPlan:
    """First-fit arena layout for the materializing intermediates."""
    offsets: Dict[int, Tuple[int, int]]  # value id -> (offset, size)
    arena_bytes: int                     # high-water mark of the layout
    naive_bytes: int                     # sum of all intermediate sizes

    @property
    def reuse_factor(self) -> float:
        return self.naive_bytes / max(self.arena_bytes, 1)


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


def arena_plan(g: Graph) -> ArenaPlan:
    """Liveness-interval first-fit over the topological node order.

    A value is live from the step of its producing node through the step
    of its last consumer (graph outputs stay live to the end).  Offsets
    are assigned greedily at the lowest gap that fits among the blocks
    live at allocation time — the classic linear-scan register allocator,
    with HBM bytes in place of registers.
    """
    order = {n.id: i for i, n in enumerate(g.nodes)}
    consumers = g.consumers()
    last_use: Dict[int, int] = {}
    for n in g.nodes:
        for vid in n.outputs:
            ends = [order[c.id] for c in consumers.get(vid, [])]
            if vid in g.outputs:
                ends.append(len(g.nodes))
            last_use[vid] = max(ends, default=order[n.id])

    offsets: Dict[int, Tuple[int, int]] = {}
    live: List[Tuple[int, int, int]] = []  # (offset, size, end_step) blocks
    arena = 0
    naive = 0
    for n in g.nodes:
        step = order[n.id]
        live = [b for b in live if b[2] >= step]
        for vid in n.outputs:
            if vid in g.outputs:
                continue  # outputs are caller-owned, not arena blocks
            size = _align(g.values[vid].nbytes)
            naive += size
            # first-fit: lowest offset gap among live blocks that fits
            taken = sorted((off, off + sz) for off, sz, _ in live)
            off = 0
            for b0, b1 in taken:
                if off + size <= b0:
                    break
                off = max(off, b1)
            live.append((off, size, last_use[vid]))
            offsets[vid] = (off, size)
            arena = max(arena, off + size)
    return ArenaPlan(offsets=offsets, arena_bytes=arena, naive_bytes=naive)
