"""Fusion passes — pipeline renting at graph level.

Each pass rewrites a :class:`~repro.graph.ir.Graph` by merging primitive
nodes into fused cluster nodes.  A cluster executes as ONE compiled region
(one Pallas kernel when the executor recognizes the pattern, one jit/XLA
fusion otherwise), so the values on its internal edges never materialize —
exactly the APR keeping a partial result in the rented stage instead of
writing it back every step.

Legality rule shared by every pass (``_grow_chain``): a node may join its
producer's cluster iff

* it is the **sole consumer** of the producer's output (otherwise the
  value must materialize anyway),
* it is *cheap* (:data:`repro.graph.ir.CHEAP_OPS` — elementwise or
  layout-only; reductions/dots/convs never ride an epilogue),
* its **other** inputs come from consts, graph inputs, or nodes that
  precede the cluster in topological order (so merging cannot create a
  cycle — a residual edge into ``conv + add + relu`` is fine because the
  shortcut was produced before the conv).

Passes register with :func:`fusion_pass` under a stable name;
``tools/check_docs.py`` statically greps these registrations and fails CI
unless every name is documented in ``docs/graph.md``.  Any sequence /
subset of passes is legal and output-preserving (property-tested in
``tests/test_graph.py``).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .ir import CHEAP_OPS, Graph, Node, toposort

_PASSES: Dict[str, Callable[[Graph], Graph]] = {}

#: Activations the fused Pallas epilogue variants implement; pattern
#: detection maps a cheap-op tail onto one of these (see _match_epilogue).
PALLAS_ACTIVATIONS = ("none", "relu")

_EPILOGUE_MAX_OPS = 12  # longest cheap-op tail a producer may absorb


def fusion_pass(name: str):
    """Register a Graph -> Graph rewrite under ``name``."""
    def deco(fn):
        if name in _PASSES:
            raise ValueError(f"fusion pass {name!r} already registered")
        fn.pass_name = name
        _PASSES[name] = fn
        return fn
    return deco


def get_pass(name: str) -> Callable[[Graph], Graph]:
    try:
        return _PASSES[name]
    except KeyError:
        raise KeyError(
            f"no fusion pass {name!r}; known: {sorted(_PASSES)}") from None


def all_passes() -> Dict[str, Callable[[Graph], Graph]]:
    return dict(_PASSES)


def default_passes() -> List[str]:
    """The standard pipeline, in the order the compiler runs it.  Quant
    folding must precede epilogue fusion (it rewrites the matmul the
    epilogue then attaches to); the generic elementwise pass runs last to
    sweep up what the targeted passes left."""
    return ["fold_quant_dequant", "fuse_matmul_epilogue",
            "fuse_conv_epilogue", "fuse_elementwise_chains"]


def run_passes(graph: Graph, names: Optional[Sequence[str]] = None) -> Graph:
    for name in (default_passes() if names is None else names):
        graph = get_pass(name)(graph)
    return graph


# ---------------------------------------------------------------------------
# Shared chain-growing machinery.
# ---------------------------------------------------------------------------


def _node_order(g: Graph) -> Dict[int, int]:
    return {n.id: i for i, n in enumerate(g.nodes)}


def _const_subtree(g: Graph, vid: int, producers) -> Optional[List[Node]]:
    """If ``vid`` is computed purely from consts by cheap ops, return the
    producing nodes (topo-unsorted); None otherwise.  These subtrees (a
    ``broadcast_in_dim`` of a bias vector, the broadcast zero of a relu)
    are absorbed into the consuming cluster so they stop materializing and
    the epilogue's *origin* const stays visible as a cluster input."""
    nodes: List[Node] = []
    stack = [vid]
    seen = set()
    while stack:
        v = stack.pop()
        if v in seen:
            continue
        seen.add(v)
        val = g.values[v]
        if val.kind in ("const", "input"):  # leaves: depend on nothing
            continue
        prod = producers.get(v)
        if prod is None or prod.is_fused or prod.op not in CHEAP_OPS \
                or len(nodes) > _EPILOGUE_MAX_OPS:
            return None
        nodes.append(prod)
        stack.extend(prod.inputs)
    return nodes


def _depends_on(g: Graph, vid: int, forbidden_ids, producers) -> bool:
    """True if ``vid``'s producer cone touches any node in ``forbidden_ids``
    (used to keep cluster side inputs acyclic)."""
    stack = [vid]
    seen = set()
    while stack:
        v = stack.pop()
        if v in seen:
            continue
        seen.add(v)
        prod = producers.get(v)
        if prod is None:
            continue
        if prod.id in forbidden_ids:
            return True
        stack.extend(prod.inputs)
    return False


def _is_last_axis_vector(shape) -> bool:
    """All dims 1 except (at most) the last — the only layouts a glue op
    may pass through when the origin must land on the output's last axis."""
    return all(int(d) == 1 for d in shape[:-1]) if shape else True


def _const_origin(g: Graph, vid: int, producers,
                  last_axis: bool = False) -> Optional[int]:
    """Resolve ``vid`` through shape/dtype-only glue (broadcast, reshape,
    convert, squeeze, expand_dims) back to a const/input value id.

    With ``last_axis=True`` every glue step must provably keep the origin
    vector on the LAST output axis (the per-output-channel contract of the
    fused kernels' bias operand): a ``broadcast_in_dim`` must map the
    input's last dim onto the output's last dim at the same size, and
    reshapes may only move between ``(..., 1, N)``-style layouts — a
    per-ROW bias (``c[:, None]`` broadcast over columns) is rejected, so
    the cluster falls back to exact XLA execution instead of the Pallas
    epilogue adding along the wrong axis."""
    glue = {"broadcast_in_dim", "reshape", "convert_element_type",
            "squeeze", "expand_dims"}
    for _ in range(_EPILOGUE_MAX_OPS):
        val = g.values[vid]
        if val.kind in ("const", "input"):
            return vid
        prod = producers.get(vid)
        if prod is None or prod.is_fused or prod.op not in glue \
                or len(prod.inputs) != 1:
            return None
        if last_axis and prod.op == "broadcast_in_dim":
            in_shape = g.values[prod.inputs[0]].shape
            out_shape = val.shape
            bdims = tuple(prod.attrs.get("broadcast_dimensions", ()))
            if in_shape:  # rank-0 (scalar) broadcasts are axis-agnostic
                if not (_is_last_axis_vector(in_shape)
                        and bdims and bdims[-1] == len(out_shape) - 1
                        and int(in_shape[-1]) == int(out_shape[-1])):
                    return None
        elif last_axis and prod.op in ("reshape", "squeeze", "expand_dims"):
            in_shape = g.values[prod.inputs[0]].shape
            if not (_is_last_axis_vector(in_shape)
                    and _is_last_axis_vector(val.shape)
                    and (not in_shape or not val.shape
                         or int(in_shape[-1]) == int(val.shape[-1]))):
                return None
        vid = prod.inputs[0]
    return None


def _grow_chain(g: Graph, start: Node, consumers, producers,
                order) -> Tuple[List[Node], List[Node]]:
    """Maximal single-consumer cheap-op chain hanging off ``start``.

    Returns ``(chain, absorbed)``: the main producer-consumer path, plus
    const-only side subtrees its ops pull in (broadcast biases etc.).  A
    side input that is a real intermediate is allowed — and left outside
    the cluster — iff it does not depend on any cluster node."""
    chain = [start]
    absorbed: List[Node] = []
    cur = start
    while len(chain) <= _EPILOGUE_MAX_OPS:
        if len(cur.outputs) != 1 or cur.outputs[0] in g.outputs:
            break
        cons = consumers.get(cur.outputs[0], [])
        if len(cons) != 1:
            break
        nxt = cons[0]
        if nxt.is_fused or nxt.op not in CHEAP_OPS:
            break
        cluster_ids = {n.id for n in chain} | {n.id for n in absorbed}
        ok = True
        new_absorbed: List[Node] = []
        for vid in nxt.inputs:
            if vid == cur.outputs[0]:
                continue
            if g.values[vid].kind in ("const", "input"):
                continue
            sub = _const_subtree(g, vid, producers)
            if sub is not None:
                new_absorbed.extend(
                    n for n in sub if n.id not in cluster_ids)
            elif _depends_on(g, vid, cluster_ids, producers):
                ok = False  # side input fed by the cluster: fusing cycles
                break
        if not ok:
            break
        chain.append(nxt)
        absorbed.extend(new_absorbed)
        cur = nxt
    return chain, absorbed


def _make_cluster(g: Graph, body: List[Node], pattern: str, consumers,
                  attrs: Optional[dict] = None,
                  anchor_id: Optional[int] = None) -> Node:
    """Build the fused node replacing ``body`` (a convex node set in valid
    execution order).  ``consumers`` is the consumer map at sweep start —
    other disjoint clusters formed in the same sweep don't change whether
    a body value is used outside THIS body, so one map serves the whole
    sweep.  The caller splices the node list and re-toposorts once."""
    body_ids = {n.id for n in body}
    produced = {vid for n in body for vid in n.outputs}
    ext_inputs, seen = [], set()
    for n in body:
        for vid in n.inputs:
            if vid not in produced and vid not in seen:
                seen.add(vid)
                ext_inputs.append(vid)
    # cluster outputs: produced values still visible outside the cluster
    ext_outputs = []
    for n in body:
        for vid in n.outputs:
            used_outside = any(c.id not in body_ids
                               for c in consumers.get(vid, []))
            if used_outside or vid in g.outputs:
                ext_outputs.append(vid)
    return Node(
        id=g.next_node_id(),
        op="fused",
        inputs=tuple(ext_inputs),
        outputs=tuple(ext_outputs),
        attrs=dict(attrs or {},
                   anchor=body[0].id if anchor_id is None else anchor_id),
        body=list(body),
        pattern=pattern,
    )


def _apply_clusters(g: Graph, replacements) -> None:
    """Splice a sweep's disjoint ``(body_ids, fused_node)`` replacements
    into the node list (each fused node at its body's earliest position),
    then re-toposort once — a cluster's side inputs may sit later in the
    flat order than the cluster's first body node."""
    if not replacements:
        return
    pos_of = {}
    all_dead = set()
    for body_ids, fused in replacements:
        all_dead |= body_ids
    for i, n in enumerate(g.nodes):
        for body_ids, fused in replacements:
            if n.id in body_ids and fused.id not in pos_of:
                pos_of[fused.id] = i
    inserts = sorted(((pos, fused) for (body_ids, fused) in replacements
                      for pos in [pos_of[fused.id]]), key=lambda t: t[0])
    new_nodes: List[Node] = []
    it = iter(inserts)
    nxt = next(it, None)
    for i, n in enumerate(g.nodes):
        while nxt is not None and nxt[0] == i:
            new_nodes.append(nxt[1])
            nxt = next(it, None)
        if n.id not in all_dead:
            new_nodes.append(n)
    while nxt is not None:
        new_nodes.append(nxt[1])
        nxt = next(it, None)
    g.nodes = new_nodes
    g.nodes = toposort(g.nodes, g.producers())


def _match_epilogue(g: Graph, anchor: Node, tail: List[Node],
                    producers) -> Optional[dict]:
    """Try to describe a cheap-op tail as the Pallas kernels' epilogue
    (``act(anchor_out + bias)``) so the executor may dispatch the cluster
    to ``apr_matmul_fused`` / ``apr_conv2d_fused`` / ``quant_matmul_fused``.

    Recognized tails (any prefix of): optional bias add — the other
    operand resolves through broadcast/reshape glue to a per-output-channel
    const/input vector — then relu spelled as ``max(x, 0)``.  Returns
    ``{"bias": vid | None, "activation": str}`` or None when the tail does
    something else (the cluster still fuses — it just executes through
    XLA instead of the Pallas epilogue variant).
    """
    bias = None
    activation = "none"
    cur_out = anchor.outputs[0]
    for n in tail:
        other = [v for v in n.inputs if v != cur_out]
        if n.op == "add" and bias is None and activation == "none" \
                and len(other) == 1:
            origin = _const_origin(g, other[0], producers, last_axis=True)
            if origin is None:
                return None
            src = g.values[origin]
            n_out = g.values[anchor.outputs[0]].shape[-1]
            flat = 1
            for d in src.shape:
                flat *= int(d)
            if flat != n_out or not _is_last_axis_vector(src.shape) \
                    or (src.shape and int(src.shape[-1]) != n_out):
                return None  # not a per-output-channel (last-axis) bias
            bias = origin
        elif n.op == "max" and activation == "none" and len(other) == 1:
            origin = _const_origin(g, other[0], producers)
            if origin is None:
                return None
            v = g.values[origin]
            if v.kind != "const" or v.array is None \
                    or np.any(np.asarray(v.array) != 0):
                return None
            activation = "relu"
        elif n.op == "convert_element_type":
            pass  # dtype glue on the main path; kernel casts at the flush
        else:
            return None
        cur_out = n.outputs[0]
    return {"bias": bias, "activation": activation}


def _is_plain_2d_matmul(g: Graph, node: Node) -> bool:
    """dot_general that the 2-D Pallas matmul can serve after a row-major
    collapse: contraction = (last lhs dim) x (first rhs dim), no batch.
    Both contraction positions must be checked — a dot that contracts the
    lhs's FIRST dim (``einsum('km,kn->mn')``) is a transposed product the
    collapse would silently compute wrong."""
    if node.op != "matmul":
        return False
    dn = node.attrs.get("dimension_numbers")
    if dn is None:
        return False
    (lc, rc), (lb, rb) = dn
    lhs_rank = len(g.values[node.inputs[0]].shape)
    return (lb == () and rb == () and len(lc) == 1 and len(rc) == 1
            and rc[0] == 0 and lc[0] == lhs_rank - 1)


# ---------------------------------------------------------------------------
# The passes.
# ---------------------------------------------------------------------------


def _fuse_anchored(g: Graph, anchor_pred, pattern: str) -> Graph:
    """Generic anchored-epilogue driver: for every node matching
    ``anchor_pred``, absorb its maximal cheap tail.

    One *sweep* walks the node list once with the maps built at sweep
    start, collecting clusters that are node-disjoint (a chain touching
    an already-claimed node waits for the next sweep); all of a sweep's
    replacements are spliced and re-toposorted together, so the map
    rebuilds are O(sweeps), not O(clusters)."""
    changed = True
    while changed:
        changed = False
        order = _node_order(g)
        consumers = g.consumers()
        producers = g.producers()
        claimed: set = set()
        replacements = []
        for node in list(g.nodes):
            if node.is_fused or node.id in claimed \
                    or not anchor_pred(node):
                continue
            chain, absorbed = _grow_chain(g, node, consumers, producers,
                                          order)
            if len(chain) < 2:
                continue
            body_ids = {n.id for n in chain} | {n.id for n in absorbed}
            if body_ids & claimed:
                changed = True  # contested nodes: retry next sweep
                continue
            epi = _match_epilogue(g, node, chain[1:], producers)
            attrs = {"pallas_ok": epi is not None}
            if epi is not None:
                attrs.update(epi)
            body = sorted({n.id: n for n in chain + absorbed}.values(),
                          key=lambda n: order[n.id])
            replacements.append(
                (body_ids, _make_cluster(g, body, pattern, consumers,
                                         attrs, anchor_id=node.id)))
            claimed |= body_ids
            changed = True
        _apply_clusters(g, replacements)
    return g


@fusion_pass("fuse_matmul_epilogue")
def fuse_matmul_epilogue(g: Graph) -> Graph:
    """matmul + bias + activation -> one cluster (``apr_matmul_fused`` /
    ``quant_matmul_fused`` when the tail matches the Pallas epilogue)."""
    return _fuse_anchored(
        g, lambda n: n.op in ("matmul", "quant_matmul"), "matmul_epilogue")


@fusion_pass("fuse_conv_epilogue")
def fuse_conv_epilogue(g: Graph) -> Graph:
    """conv2d + (folded-bn scale/bias | bias | residual add) + relu -> one
    cluster (``apr_conv_fused`` when the tail is bias+relu)."""
    return _fuse_anchored(g, lambda n: n.op == "conv2d", "conv_epilogue")


@fusion_pass("fuse_elementwise_chains")
def fuse_elementwise_chains(g: Graph) -> Graph:
    """Sweep-up pass: any >= 2-long single-consumer chain of cheap ops
    (norm bodies, softmax tails, rope trig, dequant glue) fuses into one
    cluster so its internal values stop materializing."""
    return _fuse_anchored(
        g, lambda n: n.op in CHEAP_OPS, "elementwise_chain")


@fusion_pass("fold_quant_dequant")
def fold_quant_dequant(g: Graph) -> Graph:
    """Rewrite ``x @ dequantize(w_int8)`` into a ``quant_matmul`` node.

    ``materialize_weight`` lowers an int8 :class:`QuantizedTensor` to
    ``convert(w_q) * scale`` (+ a convert to the activation dtype) feeding
    the dot.  This pass matches that producer chain on the RHS of a plain
    2-D matmul and replaces the pair with a single ``quant_matmul`` node
    whose inputs are ``(x, w_q, scale)`` — the dequant multiply folds into
    the matmul flush (per-output-channel scales distribute over the
    contraction), the executor streams the weight at 1 byte/element, and
    the int8 weight flows through later epilogue fusion unchanged.
    Numerics follow ``kernels/quant_matmul`` (dynamic per-row activation
    quantization, int32 accumulation, scales applied once).
    """
    producers = g.producers()
    consumers = g.consumers()
    for node in list(g.nodes):
        if not _is_plain_2d_matmul(g, node) \
                or len(g.values[node.inputs[1]].shape) != 2:
            continue
        match = _match_dequant(g, node.inputs[1], producers, consumers)
        if match is None:
            continue
        wq_vid, scale_vid, dequant_nodes = match
        qnode = Node(
            id=g.next_node_id(),
            op="quant_matmul",
            inputs=(node.inputs[0], wq_vid, scale_vid),
            outputs=node.outputs,
            attrs={"out_dtype": g.values[node.outputs[0]].dtype},
        )
        dead = {n.id for n in dequant_nodes} | {node.id}
        pos = min(i for i, n in enumerate(g.nodes) if n.id in dead)
        g.nodes = ([n for n in g.nodes[:pos] if n.id not in dead]
                   + [qnode]
                   + [n for n in g.nodes[pos:] if n.id not in dead])
        producers = g.producers()
        consumers = g.consumers()
    return g


def _match_dequant(g: Graph, w_vid: int, producers, consumers):
    """Walk the weight operand's producer chain looking for
    convert(int8 const) * scale-const [-> convert].  Every node on the
    chain must feed only this chain (single consumer) so deleting it is
    safe, and the scale must be a scalar or a per-OUTPUT-channel vector
    (``(1, N)``-broadcastable) — only then does the multiply distribute
    over the contraction (``x @ (q * s) == (x @ q) * s``); a per-row
    ``(K, 1)`` scale does not, and folding it would silently change the
    product.  Returns (w_q vid, scale vid, nodes-to-delete) or None."""
    dead = []
    vid = w_vid
    # optional trailing dtype convert(s)
    for _ in range(2):
        prod = producers.get(vid)
        if prod is None or prod.is_fused:
            break
        if prod.op == "convert_element_type" and len(consumers.get(vid, [])) == 1:
            dead.append(prod)
            vid = prod.inputs[0]
        else:
            break
    prod = producers.get(vid)
    if prod is None or prod.is_fused or prod.op != "mul" \
            or len(consumers.get(vid, [])) != 1:
        return None
    dead.append(prod)
    qside = scale_vid = None
    for ivid in prod.inputs:
        v = g.values[ivid]
        p = producers.get(ivid)
        if p is not None and not p.is_fused \
                and p.op == "convert_element_type" \
                and g.values[p.inputs[0]].kind == "const" \
                and jnp.dtype(g.values[p.inputs[0]].dtype) == jnp.int8 \
                and len(consumers.get(ivid, [])) == 1:
            qside = p.inputs[0]
            dead.append(p)
        elif v.kind == "const":
            scale_vid = ivid
    if qside is None or scale_vid is None:
        return None
    n_out = int(g.values[w_vid].shape[-1])
    sshape = g.values[scale_vid].shape
    if sshape and not (_is_last_axis_vector(sshape)
                       and int(sshape[-1]) == n_out):
        return None  # per-row / elementwise scale: not foldable
    return qside, scale_vid, dead
