"""jaxpr -> op-graph tracer.

``trace(fn, *example_args)`` stages ``fn`` with :func:`jax.make_jaxpr` and
lowers the jaxpr to the :mod:`repro.graph.ir` vocabulary, one IR node per
primitive equation.  Two things make the result a *compiler* IR rather
than a jaxpr mirror:

* **call-like equations are inlined** — ``pjit``, ``custom_jvp_call`` /
  ``custom_vjp_call`` (every ``jax.nn`` activation is a custom_jvp
  function) and remat wrappers are flattened into their body equations, so
  a ``jax.nn.relu`` shows up as the fusable ``max(x, 0)`` it is instead of
  an opaque call;
* **weights become graph consts** — anything ``fn`` closes over
  (``lambda x: forward(params, x)``) lands in ``Value(kind="const")``, so
  the passes can distinguish streamed weights from activations (quant
  folding keys on int8 consts).

Control flow: short ``scan`` equations (length <= ``SCAN_UNROLL_CAP``)
are **unrolled** — the body is evaluated once per iteration, carries are
threaded through, and the per-iteration outputs are re-stacked — so a
recurrent decode step written as a layer scan still exposes its matmuls
to the fusion passes.  Longer scans, ``while`` and ``cond`` stay opaque
single nodes the executor re-binds.  Model entry points meant for graph
compilation should still trace with ``scan_layers=False`` when they can
(the compiler does this for you; see
:func:`repro.graph.compiler.compile_prefill_step`) — unrolling at the
source beats unrolling in the tracer.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

try:  # jax >= 0.6 moved the public core types
    from jax.extend import core as jcore  # type: ignore
    _ = (jcore.Literal, jcore.DropVar, jcore.ClosedJaxpr)
except Exception:  # pragma: no cover - 0.4.x image
    from jax import core as jcore

from .ir import Graph, Node, Value, canonical_op

#: Call-like primitives that are pure wrappers around a body jaxpr: the
#: tracer flattens them.  param key -> how to find the body.
_INLINE_CALLS = ("pjit", "custom_jvp_call", "custom_vjp_call",
                 "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr",
                 "remat2", "checkpoint", "closed_call", "core_call",
                 "xla_call")
_BODY_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")

#: Longest ``lax.scan`` the tracer unrolls into the graph.  A deep layer
#: scan past this produces a graph too large to fuse profitably (and to
#: compile node-by-node), so it stays an opaque node instead.
SCAN_UNROLL_CAP = 64


def _scan_unrolled_body(eqn) -> Any:
    """A ClosedJaxpr equivalent to a ``scan`` equation with the loop
    unrolled: ``length`` sequential body evaluations, carries threaded
    through, per-iteration outputs re-stacked along axis 0.  ``None`` when
    the scan is too long (or zero-length) to unroll."""
    p = eqn.params
    length, n_consts, n_carry = p["length"], p["num_consts"], p["num_carry"]
    if not 0 < length <= SCAN_UNROLL_CAP:
        return None
    body = p["jaxpr"]  # ClosedJaxpr

    def unrolled(*flat):
        consts = flat[:n_consts]
        carry = list(flat[n_consts:n_consts + n_carry])
        xs = flat[n_consts + n_carry:]
        ys = []
        order = range(length - 1, -1, -1) if p["reverse"] else range(length)
        for i in order:
            outs = jax.core.eval_jaxpr(
                body.jaxpr, body.consts, *consts, *carry,
                *[x[i] for x in xs])
            carry = list(outs[:n_carry])
            ys.append(outs[n_carry:])
        if p["reverse"]:
            ys.reverse()  # ys are stacked in xs index order either way
        stacked = [jnp.stack(col) for col in zip(*ys)]
        return (*carry, *stacked)

    examples = [jax.ShapeDtypeStruct(v.aval.shape, v.aval.dtype)
                for v in eqn.invars]
    return jax.make_jaxpr(unrolled)(*examples)


def _closed_body(eqn) -> Any:
    """The body ClosedJaxpr of a call-like equation, or None."""
    if eqn.primitive.name not in _INLINE_CALLS:
        return None
    for key in _BODY_PARAM_KEYS:
        body = eqn.params.get(key)
        if body is None:
            continue
        if hasattr(body, "jaxpr"):          # already a ClosedJaxpr
            return body
        return jcore.ClosedJaxpr(body, ())  # open Jaxpr (remat2)
    return None


def trace(fn: Callable, *example_args, name: str = "graph") -> Graph:
    """Lower ``fn(*example_args)`` to a :class:`~repro.graph.ir.Graph`.

    ``example_args`` may be concrete arrays or ``jax.ShapeDtypeStruct``
    pytrees — only shapes/dtypes matter.  Values ``fn`` closes over become
    graph consts; the graph's ``in_tree``/``out_tree`` record the pytree
    signature so the executor can be called exactly like ``fn``.
    """
    closed = jax.make_jaxpr(fn)(*example_args)
    out_tree = jax.tree_util.tree_structure(
        jax.eval_shape(fn, *example_args))
    flat_args, in_tree = jax.tree_util.tree_flatten(example_args)

    g = Graph(values={}, nodes=[], inputs=[], outputs=[],
              in_tree=in_tree, out_tree=out_tree, name=name)
    env: Dict[Any, int] = {}  # jaxpr Var -> value id

    jaxpr = closed.jaxpr
    assert len(jaxpr.invars) == len(flat_args), \
        (len(jaxpr.invars), len(flat_args))
    for var in jaxpr.invars:
        v = g.new_value(var.aval.shape, var.aval.dtype, kind="input")
        env[var] = v.id
        g.inputs.append(v.id)
    _bind_consts(g, env, jaxpr.constvars, closed.consts)
    _lower_eqns(g, env, jaxpr.eqns)
    for var in jaxpr.outvars:
        g.outputs.append(_read(g, env, var))
    return g


def _bind_consts(g: Graph, env, constvars, consts) -> None:
    for var, const in zip(constvars, consts):
        v = g.new_value(var.aval.shape, var.aval.dtype, kind="const",
                        array=jnp.asarray(const))
        env[var] = v.id


def _read(g: Graph, env, var) -> int:
    if isinstance(var, jcore.Literal):
        v = g.new_value(var.aval.shape, var.aval.dtype, kind="const",
                        array=jnp.asarray(var.val, var.aval.dtype))
        return v.id
    return env[var]


def _lower_eqns(g: Graph, env, eqns) -> None:
    for eqn in eqns:
        body = _closed_body(eqn)
        if body is None and eqn.primitive.name == "scan":
            body = _scan_unrolled_body(eqn)
        if body is not None:
            # Inline: wire the call's operands to the body's invars, lower
            # the body equations into the same graph, then alias the
            # call's outvars to the body's outvars.
            sub_env: Dict[Any, int] = {}
            assert len(body.jaxpr.invars) == len(eqn.invars), eqn.primitive
            for ivar, ovar in zip(body.jaxpr.invars, eqn.invars):
                sub_env[ivar] = _read(g, env, ovar)
            _bind_consts(g, sub_env, body.jaxpr.constvars, body.consts)
            _lower_eqns(g, sub_env, body.jaxpr.eqns)
            for call_out, body_out in zip(eqn.outvars, body.jaxpr.outvars):
                if not isinstance(call_out, jcore.DropVar):
                    env[call_out] = _read(g, sub_env, body_out)
            continue

        in_ids = tuple(_read(g, env, v) for v in eqn.invars)
        out_ids = []
        for ovar in eqn.outvars:
            v = g.new_value(ovar.aval.shape, ovar.aval.dtype)
            out_ids.append(v.id)
            if not isinstance(ovar, jcore.DropVar):
                env[ovar] = v.id
        g.nodes.append(Node(
            id=g.next_node_id(),
            op=canonical_op(eqn.primitive.name),
            inputs=in_ids,
            outputs=tuple(out_ids),
            attrs=dict(eqn.params),
            prim=eqn.primitive,
        ))


def eval_node(node: Node, invals) -> tuple:
    """Re-bind one primitive node on concrete (or traced) arguments.

    This is :func:`jax.core.eval_jaxpr`'s inner loop applied to a single
    equation; fused clusters eval their ``body`` nodes through it inside a
    single jit region.
    """
    assert node.prim is not None, "eval_node on a synthetic node"
    subfuns, bind_params = node.prim.get_bind_params(dict(node.attrs))
    out = node.prim.bind(*subfuns, *invals, **bind_params)
    return tuple(out) if node.prim.multiple_results else (out,)
