"""repro.graph — the rented-pipeline graph compiler.

The paper's APR keeps a running reduction resident in a pipeline register so
memory sees one write per produced result.  Inside a single Pallas kernel
this repo already does that (``repro.kernels``); *between* ops, every
intermediate still round-trips through HBM.  This package is the same
mechanism one level up — a small op-graph compiler:

* :mod:`repro.graph.ir`     — the op-graph IR (values + primitive nodes),
* :mod:`repro.graph.trace`  — jaxpr-based tracer lowering ``models/``
  forward functions into graphs,
* :mod:`repro.graph.passes` — fusion passes (the software analogue of
  pipeline renting: epilogues stay in the producer's register tile),
* :mod:`repro.graph.plan`   — the memory-traffic planner (the paper's
  "memory access frequency" metric at graph level) + arena reuse plan,
* :mod:`repro.graph.executor` — cluster-at-a-time executor (per-node
  execution = the HBM baseline; fused clusters = APR residency), with
  optional dispatch of recognized epilogue clusters to the fused Pallas
  kernel variants,
* :mod:`repro.graph.compiler` — the one-call entry points + compile cache,
  including the ``PagedServeEngine(use_graph=True)`` prefill path.

See ``docs/graph.md`` for the full guide.
"""
from .compiler import (clear_compile_cache, compile_fn,  # noqa: F401
                       compile_prefill_step)
from .executor import GraphExecutor  # noqa: F401
from .ir import Graph, Node, Value  # noqa: F401
from .passes import (all_passes, default_passes, get_pass,  # noqa: F401
                     run_passes)
from .plan import arena_plan, memory_report  # noqa: F401
from .trace import trace  # noqa: F401
