"""Op-graph IR: SSA values + nodes in one topologically-ordered list.

The IR is deliberately thin: a :class:`Node` is either a single jax
primitive application (``prim`` + ``attrs`` captured from the traced
jaxpr equation) or a *fused cluster* (``body`` holds the original
primitive nodes, executed together so their interface values never
materialize — the graph-level APR).  Passes rewrite the node list; the
executor only ever needs ``inputs``/``outputs`` ids plus, per primitive
node, enough to re-``bind`` the primitive.

Canonical op names (``Node.op``) abstract over jax primitive spellings so
the fusion passes pattern-match one vocabulary:

* ``matmul``  — ``dot_general`` (any rank; attrs keep dimension_numbers)
* ``conv2d``  — ``conv_general_dilated``
* everything else keeps its primitive name (``add``, ``max``, ``exp``,
  ``convert_element_type``, ``gather``, ``scatter`` ...)

Fused nodes get ``op = "fused"`` and a ``pattern`` label from the pass
that built them (``matmul_epilogue`` / ``conv_epilogue`` /
``quant_matmul`` / ``elementwise_chain``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

_CANONICAL = {
    "dot_general": "matmul",
    "conv_general_dilated": "conv2d",
}

#: Cheap ops a fusion pass may pull into a producer's cluster: elementwise
#: arithmetic plus layout-only ops whose output is a relabelling of the
#: input.  Reductions, gathers/scatters, dots and convs are never "cheap".
CHEAP_OPS = frozenset({
    "add", "sub", "mul", "div", "max", "min", "pow", "integer_pow",
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "erf", "rsqrt",
    "sqrt", "neg", "sign", "abs", "floor", "ceil", "round", "clamp",
    "select_n", "and", "or", "not", "xor", "ge", "gt", "le", "lt", "eq",
    "ne", "is_finite", "stop_gradient", "square",
    "convert_element_type", "broadcast_in_dim", "reshape", "squeeze",
    "transpose", "rev", "slice", "expand_dims",
})


@dataclasses.dataclass
class Value:
    """One SSA value: an array with a fixed shape/dtype.

    ``kind`` is ``"input"`` (a traced argument), ``"const"`` (a weight or
    literal captured at trace time; ``array`` holds it), or
    ``"intermediate"`` (produced by a node).
    """
    id: int
    shape: Tuple[int, ...]
    dtype: Any
    kind: str = "intermediate"
    array: Any = None

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        import numpy as np
        return n * np.dtype(self.dtype).itemsize


@dataclasses.dataclass
class Node:
    """One computation step: a primitive application or a fused cluster."""
    id: int
    op: str
    inputs: Tuple[int, ...]
    outputs: Tuple[int, ...]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    prim: Any = None                       # jax primitive (None if fused)
    body: Optional[List["Node"]] = None    # inner primitive nodes if fused
    pattern: Optional[str] = None          # fusion-pass label if fused

    @property
    def is_fused(self) -> bool:
        return self.body is not None

    def body_nodes(self) -> List["Node"]:
        return self.body if self.body is not None else [self]


@dataclasses.dataclass
class Graph:
    """Topologically-ordered op graph plus pytree metadata.

    ``inputs``/``outputs`` are value ids in flattened-pytree order;
    ``in_tree``/``out_tree`` let callers round-trip the original function
    signature (the executor's ``__call__`` uses them).
    """
    values: Dict[int, Value]
    nodes: List[Node]
    inputs: List[int]
    outputs: List[int]
    in_tree: Any = None
    out_tree: Any = None
    name: str = "graph"
    _node_counter: int = 0  # monotonic: ids stay unique even for nodes
                            # built before they are spliced into `nodes`

    # -- id allocation ----------------------------------------------------
    def new_value(self, shape, dtype, kind="intermediate", array=None) -> Value:
        vid = (max(self.values) + 1) if self.values else 0
        v = Value(id=vid, shape=tuple(int(d) for d in shape), dtype=dtype,
                  kind=kind, array=array)
        self.values[vid] = v
        return v

    def next_node_id(self) -> int:
        nid = max(self._node_counter,
                  max((n.id for n in self.nodes), default=-1) + 1)
        object.__setattr__(self, "_node_counter", nid + 1)
        return nid

    # -- structure queries ------------------------------------------------
    def producers(self) -> Dict[int, Node]:
        """value id -> node producing it (fused nodes count as one)."""
        out = {}
        for n in self.nodes:
            for vid in n.outputs:
                out[vid] = n
        return out

    def consumers(self) -> Dict[int, List[Node]]:
        """value id -> nodes consuming it (fused nodes count as one)."""
        out: Dict[int, List[Node]] = {vid: [] for vid in self.values}
        for n in self.nodes:
            for vid in n.inputs:
                out.setdefault(vid, []).append(n)
        return out

    def intermediates(self) -> List[Value]:
        """Values that would materialize between nodes: produced by a node,
        consumed (or returned) outside the producing cluster.  Cluster-
        internal values of fused nodes are *not* intermediates — they live
        in the producer's register tile, never in HBM."""
        out = [self.values[vid] for n in self.nodes for vid in n.outputs
               if vid not in self.outputs]
        return out

    def const_bytes(self) -> int:
        return sum(v.nbytes for v in self.values.values() if v.kind == "const")

    def summary(self) -> Dict[str, int]:
        return {
            "n_nodes": len(self.nodes),
            "n_fused": sum(1 for n in self.nodes if n.is_fused),
            "n_primitive_ops": sum(len(n.body_nodes()) for n in self.nodes),
            "n_values": len(self.values),
        }

    def pretty(self, max_nodes: int = 40) -> str:
        lines = [f"graph {self.name}: {len(self.inputs)} inputs, "
                 f"{len(self.outputs)} outputs, {len(self.nodes)} nodes"]
        for n in self.nodes[:max_nodes]:
            outs = ", ".join(f"%{v}" for v in n.outputs)
            ins = ", ".join(f"%{v}" for v in n.inputs)
            tag = f" [{n.pattern}:{len(n.body)} ops]" if n.is_fused else ""
            lines.append(f"  {outs} = {n.op}{tag}({ins})")
        if len(self.nodes) > max_nodes:
            lines.append(f"  ... {len(self.nodes) - max_nodes} more")
        return "\n".join(lines)


def canonical_op(prim_name: str) -> str:
    return _CANONICAL.get(prim_name, prim_name)


def toposort(nodes: Sequence[Node], producers: Dict[int, Node]) -> List[Node]:
    """Deterministic topological order of ``nodes`` (Kahn's with a FIFO
    ready queue — O(V + E); initial ready set keeps the given order).
    Fusion can only ever *merge* adjacent dependency chains, so passes use
    this to re-legalise the node list after a rewrite sweep."""
    import collections

    node_by_id = {id(n): n for n in nodes}
    indeg: Dict[int, int] = {id(n): 0 for n in nodes}
    dependents: Dict[int, List[int]] = {id(n): [] for n in nodes}
    for n in nodes:
        preds = set()
        for vid in n.inputs:
            p = producers.get(vid)
            if p is not None and id(p) in node_by_id and p is not n:
                preds.add(id(p))
        indeg[id(n)] = len(preds)
        for pid in preds:
            dependents[pid].append(id(n))
    ready = collections.deque(id(n) for n in nodes if indeg[id(n)] == 0)
    ordered: List[Node] = []
    while ready:
        nid = ready.popleft()
        ordered.append(node_by_id[nid])
        for did in dependents[nid]:
            indeg[did] -= 1
            if indeg[did] == 0:
                ready.append(did)
    if len(ordered) != len(nodes):
        raise ValueError("cycle in graph node list (illegal fusion?)")
    return ordered
