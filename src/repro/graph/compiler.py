"""One-call compile entry points + the process compile cache.

:func:`compile_fn` is the generic path: trace -> fusion passes ->
:class:`~repro.graph.executor.GraphExecutor`.  Pass ``key=`` to memoize
the built executor in the process-wide compile cache (repeated
``compile_fn`` calls for the same shapes — benchmark reps, examples —
skip retracing; the executor's own per-node jit cache handles repeated
*calls*).

:func:`compile_prefill_step` / :func:`compile_decode_step` are the
serving integration: ``PagedServeEngine(use_graph=True)`` routes its
chunked-prefill step and its batched T=1 decode tick through them.  The
model's paged decode contract is traced **unrolled** (``scan_layers=
False`` — a deep ``lax.scan`` would hide the per-layer matmuls from the
fusion passes inside one opaque node; short scans that survive get
unrolled by the tracer itself, see ``repro.graph.trace``) at the
engine's fixed shapes (prefill: B=1, T=chunk; decode: B=slots, T=1),
the default pass pipeline fuses it, and the wrappers keep the engine's
``(params, cache, tokens, lengths, counts, block_tables)`` call
signature — params are baked into the graph as consts at compile time,
which is exactly the serving deployment shape (weights never change
under an engine).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, Hashable, Optional, Sequence

import jax
import jax.numpy as jnp

from .executor import GraphExecutor
from .passes import run_passes
from .trace import trace

_COMPILE_CACHE: Dict[Hashable, GraphExecutor] = {}

_COST_MODEL_ENV = "REPRO_COST_MODEL"


def _cost_model_enabled(flag: Optional[bool]) -> bool:
    """Explicit flag > ``$REPRO_COST_MODEL`` (``0``/``off``/``false``
    disables) > on by default."""
    if flag is not None:
        return flag
    return os.environ.get(_COST_MODEL_ENV, "").strip().lower() not in (
        "0", "off", "false", "no")


def clear_compile_cache() -> None:
    _COMPILE_CACHE.clear()


def compile_fn(fn: Callable, *example_args,
               passes: Optional[Sequence[str]] = None,
               fused: bool = True,
               impl: str = "xla",
               key: Optional[Hashable] = None,
               cost_model: Optional[bool] = None,
               name: str = "graph") -> GraphExecutor:
    """Trace ``fn`` at ``example_args``, fuse, and wrap in an executor.

    ``fused=False`` skips the passes entirely — every primitive runs as
    its own compiled call, materializing every intermediate (the HBM
    baseline the benchmarks compare against).  ``passes`` selects/orders a
    subset of :func:`repro.graph.passes.default_passes` and bypasses the
    cost model (an explicit pipeline is an override, not a candidate set).

    With the cost model on (the default; ``cost_model=False`` or
    ``$REPRO_COST_MODEL=off`` reverts to the fixed pipeline) the fused
    path routes through :func:`repro.cost.plan_graph`: the schedule cache
    in the active :class:`~repro.bench.config.ConfigCache` is consulted by
    graph signature, and on a miss each registered rewrite is kept only on
    a predicted HBM-traffic win.  The chosen
    :class:`~repro.cost.ScheduleDecision` is attached to the executor as
    ``.schedule`` (None on the legacy paths) for ``--explain`` consumers.
    """
    if key is not None and key in _COMPILE_CACHE:
        return _COMPILE_CACHE[key]
    g = trace(fn, *example_args, name=name)
    schedule = None
    if fused:
        if passes is None and _cost_model_enabled(cost_model):
            # Lazy import: repro.cost imports repro.graph.ir, whose package
            # __init__ imports this module.
            from ..cost import plan_graph
            schedule = plan_graph(g)   # mutates g in place, like run_passes
        else:
            g = run_passes(g, passes)
    ex = GraphExecutor(g, impl=impl)
    ex.schedule = schedule
    if key is not None:
        _COMPILE_CACHE[key] = ex
    return ex


def compile_prefill_step(bundle, params, cache, *, chunk: int,
                         table_width: int, pctx,
                         fused: bool = True, impl: Optional[str] = None,
                         passes: Optional[Sequence[str]] = None,
                         cost_model: Optional[bool] = None,
                         name: Optional[str] = None) -> Callable:
    """Graph-compile one chunked-prefill step of the paged serve contract.

    Returns a callable with the engine's prefill signature
    ``(params, cache, tokens, lengths, counts, block_tables) ->
    (logits, new_cache)``; the ``params`` argument is accepted for
    signature compatibility but ignored — the graph baked this engine's
    params in as consts (int8 :class:`~repro.quant.QuantizedTensor`
    entries included, which is what lets ``fold_quant_dequant`` see the
    int8 payloads).

    ``impl=None`` auto-selects like the kernel wrappers do: ``"pallas"``
    on TPU (recognized epilogue clusters dispatch to the fused kernel
    variants at full speed), ``"xla"`` everywhere else (Pallas interpret
    mode would be pathologically slow for a whole prefill step).
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    cfg = dataclasses.replace(bundle.cfg, scan_layers=False)
    unrolled = dataclasses.replace(bundle, cfg=cfg)

    def step(cache, tokens, lengths, counts, block_tables):
        return unrolled.decode_paged(params, cache, tokens, lengths,
                                     counts, block_tables, pctx)

    sds = lambda shape, dtype: jax.ShapeDtypeStruct(shape, dtype)
    example = (
        jax.tree.map(lambda a: sds(a.shape, a.dtype), cache),
        sds((1, chunk), jnp.int32),
        sds((1,), jnp.int32),
        sds((1,), jnp.int32),
        sds((1, table_width), jnp.int32),
    )
    ex = compile_fn(step, *example, passes=passes, fused=fused, impl=impl,
                    cost_model=cost_model,
                    name=name or f"{cfg.name}-prefill-t{chunk}")

    def prefill(_params, cache, tokens, lengths, counts, block_tables):
        return ex(cache, tokens, lengths, counts, block_tables)

    prefill.executor = ex  # introspection: metrics/benchmarks read the graph
    return prefill


def compile_decode_step(bundle, params, cache, *, slots: int,
                        table_width: int, pctx,
                        fused: bool = True, impl: Optional[str] = None,
                        passes: Optional[Sequence[str]] = None,
                        cost_model: Optional[bool] = None,
                        name: Optional[str] = None) -> Callable:
    """Graph-compile the batched T=1 decode tick of the paged serve
    contract — :func:`compile_prefill_step`'s sibling at the decode
    shapes (B=slots, T=1, the engine's fixed decode geometry).  Same
    wrapper contract: params baked in as consts, ``.executor`` exposed
    for graph introspection, ``impl=None`` auto-selects pallas on TPU.

    Note the engine refuses to route the *hybrid* family here (see
    ``PagedServeEngine``): cluster boundaries are compilation boundaries,
    and the hybrid's interleaved f32 SSD update + bf16 attention is
    sensitive to cross-op FMA contraction — a 1-ulp f32 shift at a
    cluster cut can cross a bf16 rounding boundary and flip a greedy
    token, violating the token-identity invariant the serving matrix is
    built on.  Attention-only and attention-free stacks compile stably.
    """
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    cfg = dataclasses.replace(bundle.cfg, scan_layers=False)
    unrolled = dataclasses.replace(bundle, cfg=cfg)

    def step(cache, tokens, lengths, counts, block_tables):
        return unrolled.decode_paged(params, cache, tokens, lengths,
                                     counts, block_tables, pctx)

    sds = lambda shape, dtype: jax.ShapeDtypeStruct(shape, dtype)
    example = (
        jax.tree.map(lambda a: sds(a.shape, a.dtype), cache),
        sds((slots, 1), jnp.int32),
        sds((slots,), jnp.int32),
        sds((slots,), jnp.int32),
        sds((slots, table_width), jnp.int32),
    )
    ex = compile_fn(step, *example, passes=passes, fused=fused, impl=impl,
                    cost_model=cost_model,
                    name=name or f"{cfg.name}-decode-b{slots}")

    def decode(_params, cache, tokens, lengths, counts, block_tables):
        return ex(cache, tokens, lengths, counts, block_tables)

    decode.executor = ex
    return decode
