"""Cluster-at-a-time graph executor.

Execution model: every graph node — a primitive or a fused cluster —
becomes one compiled callable, and the executor walks the node list
feeding buffers.  A node boundary is therefore a *materialization
boundary* (XLA cannot fuse across separately-jitted calls, so every
inter-node value becomes a committed device buffer — the HBM round-trip
of the paper's F-extension baseline), while everything inside a cluster
compiles as one region and its internal values stay in registers/VMEM —
the APR.  Running the same graph unfused vs fused is the graph-level
version of the kernels' ``residency="hbm"`` vs ``"apr"`` comparison.

``impl="xla"`` (default; the only option off-TPU worth timing) compiles
each cluster by re-binding its equations inside one ``jax.jit`` region.
``impl="pallas"`` additionally dispatches *recognized* epilogue clusters
to the fused Pallas kernel variants — ``apr_matmul_fused``,
``apr_conv2d_fused``, ``quant_matmul_fused`` — and executes everything
else as XLA clusters; unrecognized patterns never error, they just miss
the kernel path.

Compiled callables are built lazily and cached per node (the executor's
compile cache); jit caching below that makes repeated calls cheap.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .ir import Graph, Node
from .trace import eval_node


class GraphExecutor:
    """Callable wrapper around a (possibly fused) :class:`Graph`.

    Calling convention matches the traced function: positional pytree args
    flatten against the graph's ``in_tree``; the return value is rebuilt
    with ``out_tree``.
    """

    def __init__(self, graph: Graph, *, impl: str = "xla",
                 interpret: Optional[bool] = None):
        if impl not in ("xla", "pallas"):
            raise ValueError(f"unknown impl {impl!r}")
        self.graph = graph
        self.impl = impl
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self._consts = {vid: v.array for vid, v in graph.values.items()
                        if v.kind == "const"}
        self._compiled: Dict[int, Callable] = {}  # node id -> callable

    # -- compile cache ----------------------------------------------------
    def _fn_for(self, node: Node) -> Callable:
        fn = self._compiled.get(node.id)
        if fn is None:
            fn = self._build(node)
            self._compiled[node.id] = fn
        return fn

    def _build(self, node: Node) -> Callable:
        if self.impl == "pallas":
            fn = self._build_pallas(node)
            if fn is not None:
                return fn
        if node.op == "quant_matmul" and node.prim is None:
            # standalone folded node (no epilogue got attached to it)
            out_dtype = node.attrs["out_dtype"]
            return jax.jit(lambda x, wq, scale: (
                _quant_matmul_xla(x, wq, scale, out_dtype=out_dtype),))
        body = node.body_nodes()
        in_ids, out_ids = node.inputs, node.outputs

        def run(*xs):
            env = dict(zip(in_ids, xs))
            for bn in body:
                if bn.op == "quant_matmul" and bn.prim is None:
                    outs = (_quant_matmul_xla(*(env[i] for i in bn.inputs),
                                              out_dtype=bn.attrs["out_dtype"]),)
                else:
                    outs = eval_node(bn, [env[i] for i in bn.inputs])
                env.update(zip(bn.outputs, outs))
            return tuple(env[o] for o in out_ids)

        return jax.jit(run)

    # -- Pallas dispatch for recognized epilogue clusters -----------------
    def _build_pallas(self, node: Node) -> Optional[Callable]:
        if node.op == "quant_matmul" and node.prim is None:
            from ..kernels.quant_matmul.ops import quant_matmul
            out_dtype = node.attrs["out_dtype"]

            def run_q(x, wq, scale):
                y = quant_matmul(_as2d(x), wq, jnp.reshape(scale, (1, -1)),
                                 out_dtype=out_dtype,
                                 interpret=self.interpret)
                return (jnp.reshape(y, self.graph.values[node.outputs[0]].shape),)
            return run_q
        if not (node.is_fused and node.attrs.get("pallas_ok")
                and len(node.outputs) == 1):
            return None
        anchor_id = node.attrs.get("anchor", node.body[0].id)
        anchor = next(n for n in node.body if n.id == anchor_id)
        activation = node.attrs.get("activation", "none")
        bias_vid = node.attrs.get("bias")
        if bias_vid is not None and bias_vid not in node.inputs:
            return None  # bias origin not visible at the cluster boundary
        bias_pos = node.inputs.index(bias_vid) if bias_vid in node.inputs else None
        out_shape = self.graph.values[node.outputs[0]].shape
        out_dtype = self.graph.values[node.outputs[0]].dtype

        if node.pattern == "matmul_epilogue" and anchor.op == "matmul":
            from .passes import _is_plain_2d_matmul
            if not _is_plain_2d_matmul(self.graph, anchor):
                return None
            from ..kernels.apr_matmul.ops import apr_matmul_fused
            x_pos = node.inputs.index(anchor.inputs[0])
            w_pos = node.inputs.index(anchor.inputs[1])

            def run_mm(*xs):
                bias = (jnp.reshape(xs[bias_pos], (-1,))
                        if bias_pos is not None else None)
                y = apr_matmul_fused(_as2d(xs[x_pos]), xs[w_pos], bias=bias,
                                     activation=activation,
                                     out_dtype=out_dtype,
                                     interpret=self.interpret)
                return (jnp.reshape(y, out_shape),)
            return run_mm

        if node.pattern == "matmul_epilogue" and anchor.op == "quant_matmul":
            from ..kernels.quant_matmul.ops import quant_matmul_fused
            x_pos = node.inputs.index(anchor.inputs[0])
            w_pos = node.inputs.index(anchor.inputs[1])
            s_pos = node.inputs.index(anchor.inputs[2])

            def run_qmm(*xs):
                bias = (jnp.reshape(xs[bias_pos], (-1,))
                        if bias_pos is not None else None)
                y = quant_matmul_fused(
                    _as2d(xs[x_pos]), xs[w_pos],
                    jnp.reshape(xs[s_pos], (1, -1)), bias=bias,
                    activation=activation, out_dtype=out_dtype,
                    interpret=self.interpret)
                return (jnp.reshape(y, out_shape),)
            return run_qmm

        if node.pattern == "conv_epilogue" and anchor.op == "conv2d":
            geo = _conv_geometry(anchor)
            if geo is None:
                return None
            stride, padding = geo
            from ..kernels.apr_conv.ops import apr_conv2d_fused
            x_pos = node.inputs.index(anchor.inputs[0])
            f_pos = node.inputs.index(anchor.inputs[1])

            def run_conv(*xs):
                bias = (jnp.reshape(xs[bias_pos], (-1,))
                        if bias_pos is not None else None)
                y = apr_conv2d_fused(xs[x_pos], xs[f_pos], bias=bias,
                                     activation=activation,
                                     stride=stride, padding=padding,
                                     interpret=self.interpret)
                return (jnp.reshape(y.astype(out_dtype), out_shape),)
            return run_conv
        return None

    # -- execution --------------------------------------------------------
    def __call__(self, *args):
        flat, in_tree = jax.tree_util.tree_flatten(args)
        if in_tree != self.graph.in_tree:
            raise TypeError(
                f"argument pytree mismatch: expected {self.graph.in_tree}, "
                f"got {in_tree}")
        buf = dict(self._consts)
        buf.update(zip(self.graph.inputs, flat))
        for node in self.graph.nodes:
            outs = self._fn_for(node)(*(buf[i] for i in node.inputs))
            buf.update(zip(node.outputs, outs))
        out_flat = [buf[vid] for vid in self.graph.outputs]
        return jax.tree_util.tree_unflatten(self.graph.out_tree, out_flat)


def _as2d(x):
    """Collapse leading dims for the 2-D Pallas matmul families."""
    return jnp.reshape(x, (-1, x.shape[-1]))


def _conv_geometry(node: Node) -> Optional[Tuple[int, int]]:
    """(stride, padding) if the conv matches apr_conv2d's contract
    (NHWC x HWIO, square stride, symmetric padding, no dilation/groups)."""
    a = node.attrs
    dn = a.get("dimension_numbers")
    spec = (getattr(dn, "lhs_spec", None), getattr(dn, "rhs_spec", None),
            getattr(dn, "out_spec", None))
    if spec != ((0, 3, 1, 2), (3, 2, 0, 1), (0, 3, 1, 2)):  # NHWC,HWIO,NHWC
        return None
    if a.get("feature_group_count", 1) != 1 or a.get("batch_group_count", 1) != 1:
        return None
    if tuple(a.get("lhs_dilation", (1, 1))) != (1, 1):
        return None
    if tuple(a.get("rhs_dilation", (1, 1))) != (1, 1):
        return None
    strides = tuple(a.get("window_strides", (1, 1)))
    pads = tuple(tuple(p) for p in a.get("padding", ((0, 0), (0, 0))))
    if strides[0] != strides[1]:
        return None
    p = pads[0][0]
    if any(x != p for pair in pads for x in pair):
        return None
    return strides[0], p


def _quant_matmul_xla(x, wq, scale, *, out_dtype):
    """XLA execution of a folded ``quant_matmul`` node — the same math as
    ``kernels/quant_matmul`` (dynamic per-row int8 activations, int32
    accumulation, scales applied once to the integer total)."""
    from ..kernels.quant_matmul.ops import quantize_activations
    x2 = _as2d(x)
    x_q, x_scale = quantize_activations(x2)
    acc = jnp.dot(x_q, wq, preferred_element_type=jnp.int32)
    y = (acc.astype(jnp.float32) * x_scale
         * jnp.reshape(scale, (1, -1))).astype(out_dtype)
    return jnp.reshape(y, x.shape[:-1] + (wq.shape[1],))
