"""Symmetric per-channel int8 weight quantization.

The multi-precision analogue of the paper's efficiency story (see
``docs/quantization.md``): decode is weight-bandwidth-bound, so storing
matmul weights as int8 + one fp32 scale per output channel moves 4x fewer
bytes than fp32 (2x fewer than the bf16 the models train in) per decode
step, while the reduction itself still accumulates at full width — int32 in
the ``quant_matmul`` Pallas kernel's APR, mirroring the paper's 32-bit
accumulate-in-register discipline.

Scheme
------
For a weight ``w`` whose last two dims are ``(in, out)`` — every stored
matmul weight in this repo, including stacked ``(layers, in, out)`` and MoE
``(experts, in, out)`` tensors —

    scale[..., 1, o] = max(|w[..., :, o]|) / 127          (fp32)
    q[..., i, o]     = clip(round(w / scale), -127, 127)  (int8)

i.e. symmetric (no zero point), per-**output**-channel, contraction axis
reduced.  Dequantization ``q * scale`` therefore distributes over the
contraction: ``x @ w  ≈  (x @ q) * scale`` — which is what lets the kernel
accumulate in int32 and apply scales once at the flush.

:class:`QuantizedTensor` is a registered pytree, so quantized params flow
through ``jax.jit`` / ``lax.scan`` / checkpoint trees exactly like plain
arrays; the model layers dequantize at the use site via
``repro.models.layers.materialize_weight``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """int8 payload + fp32 per-output-channel scales (broadcastable)."""

    q: jax.Array        # int8, original weight shape
    scale: jax.Array    # fp32, q's shape with the contraction axis = 1

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def nbytes(self) -> int:
        return self.q.size * 1 + self.scale.size * 4

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)

    # -- pytree protocol --------------------------------------------------
    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q=q, scale=scale)


def quantize_channelwise(w: jax.Array, axis: int = -2) -> QuantizedTensor:
    """Symmetric int8 quantization reducing ``axis`` (the contraction dim).

    ``axis=-2`` matches every stored ``(in, out)`` matmul weight; per-token
    KV quantization uses ``axis=-1`` (the head dim).
    """
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax, 1.0) / INT8_MAX
    q = jnp.clip(jnp.round(wf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32))


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jax.Array:
    return qt.dequantize(dtype)


# ---------------------------------------------------------------------------
# Param-tree quantization.
# ---------------------------------------------------------------------------

#: Name fragments that keep full precision regardless of shape: embeddings
#: and positional tables are gathered/indexed, not streamed through a
#: matmul (encdec reads ``pos_dec[...]`` by position), norms are 1D gains,
#: and the MoE router's top-k selection is too accuracy-sensitive for a
#: bandwidth win measured in kilobytes.
DEFAULT_SKIP = ("embed", "pos_", ".ln", "norm", ".router")


def default_predicate(name: str, w: Any) -> bool:
    """Should ``name`` be int8-quantized?  Matmul weights only."""
    if not hasattr(w, "ndim") or w.ndim < 2:
        return False
    if not jnp.issubdtype(jnp.asarray(w).dtype, jnp.floating):
        return False
    return not any(frag in name for frag in DEFAULT_SKIP)


def quantize_params(
    params: Dict[str, Any],
    *,
    predicate: Optional[Callable[[str, Any], bool]] = None,
) -> Dict[str, Any]:
    """Return a copy of a flat params dict with matmul weights quantized.

    Entries selected by ``predicate`` (default: :func:`default_predicate`)
    become :class:`QuantizedTensor`; everything else is passed through
    untouched.  The result is a drop-in replacement for the original dict —
    the model layers dequantize at the use site.
    """
    predicate = predicate or default_predicate
    return {
        name: quantize_channelwise(w) if predicate(name, w) else w
        for name, w in params.items()
    }


def weight_bytes(params: Dict[str, Any]) -> Dict[str, int]:
    """Analytic streamed-weight byte accounting for the bandwidth story.

    Counts every param that is (or would be, under
    :func:`default_predicate`) a streamed matmul weight, at three storage
    widths: fp32, bf16 (the training dtype), and the actual footprint of
    this dict (int8 + scales for :class:`QuantizedTensor` entries, native
    width otherwise).  Embeddings/norms/router are excluded — they are
    either gathered per token or negligible.
    """
    fp32 = bf16 = actual = 0
    quantized = skipped = 0
    for name, w in params.items():
        if isinstance(w, QuantizedTensor):
            n = w.q.size
            fp32 += 4 * n
            bf16 += 2 * n
            actual += w.nbytes
            quantized += 1
        elif default_predicate(name, w):
            n = w.size
            fp32 += 4 * n
            bf16 += 2 * n
            actual += w.size * jnp.asarray(w).dtype.itemsize
            skipped += 1
        else:
            skipped += 1
    return {
        "bytes_fp32": fp32,
        "bytes_bf16": bf16,
        "bytes_actual": actual,
        "n_quantized": quantized,
        "n_passthrough": skipped,
    }
