"""Multi-precision quantized inference (int8 weights, int8 paged KV).

Public surface:

* :func:`quantize_params` / :class:`QuantizedTensor` — turn any model's
  params dict into an int8-weight variant the model layers consume
  directly (dequant at the use site),
* :func:`quantize_channelwise` / :func:`dequantize` — the underlying
  symmetric per-channel scheme (also used per (token, head) by the int8
  paged-KV cache),
* the ``quant_matmul`` Pallas kernel family
  (``repro.kernels.quant_matmul``) — int8 x int8 MXU contraction with an
  **int32 APR** accumulator, registered as a ``repro.bench`` family.

Architecture guide: ``docs/quantization.md``.
"""
from .quantize import (DEFAULT_SKIP, INT8_MAX, QuantizedTensor,
                       default_predicate, dequantize, quantize_channelwise,
                       quantize_params, weight_bytes)

__all__ = [
    "DEFAULT_SKIP",
    "INT8_MAX",
    "QuantizedTensor",
    "default_predicate",
    "dequantize",
    "quantize_channelwise",
    "quantize_params",
    "weight_bytes",
]
