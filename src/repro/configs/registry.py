"""--arch registry: the 10 assigned architectures (exact published
geometries) + reduced SMOKE variants.  Sources per the assignment sheet."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig, scale_down

# [arXiv:2402.19173; hf] — GQA, RoPE
STARCODER2_15B = ModelConfig(
    name="starcoder2-15b", family="dense", num_layers=40, d_model=6144,
    num_heads=48, num_kv_heads=4, head_dim=128, d_ff=24576, vocab_size=49152,
    rope_theta=100_000.0,
)

# [arXiv:2407.21783; unverified] — GQA, 128k vocab
LLAMA3_8B = ModelConfig(
    name="llama3-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=128256,
    rope_theta=500_000.0,
)

# [arXiv:2406.12793; hf] — RoPE 2d (half-dim rotary), GQA kv=2
CHATGLM3_6B = ModelConfig(
    name="chatglm3-6b", family="dense", num_layers=28, d_model=4096,
    num_heads=32, num_kv_heads=2, head_dim=128, d_ff=13696, vocab_size=65024,
    rope_fraction=0.5,
)

# [arXiv:2401.14196; hf] — llama-arch
DEEPSEEK_CODER_33B = ModelConfig(
    name="deepseek-coder-33b", family="dense", num_layers=62, d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=19200, vocab_size=32256,
    rope_theta=100_000.0,
)

# [hf:Snowflake/snowflake-arctic-base; hf] — 128e top-2 + dense residual
ARCTIC_480B = ModelConfig(
    name="arctic-480b", family="moe", num_layers=35, d_model=7168,
    num_heads=56, num_kv_heads=8, head_dim=128, d_ff=4864, vocab_size=32000,
    num_experts=128, experts_per_token=2, moe_every=1, dense_residual_ff=4864,
    optimizer="adafactor",
)

# [hf:meta-llama/Llama-4-Scout...; unverified] — 128e top-1, interleaved MoE
LLAMA4_MAVERICK_400B = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", num_layers=48, d_model=5120,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_every=2,
    optimizer="adafactor", rope_theta=500_000.0,
)

# [arXiv:2404.16821; hf] — InternViT stub + InternLM2 backbone
INTERNVL2_1B = ModelConfig(
    name="internvl2-1b", family="vlm", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, head_dim=64, d_ff=4864, vocab_size=151655,
    vision_tokens=256, rope_theta=1_000_000.0,
)

# [arXiv:2404.05892; hf] — Finch: attention-free, data-dependent decay
RWKV6_3B = ModelConfig(
    name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=0, num_kv_heads=0, d_ff=8960, vocab_size=65536,
    rwkv_head_dim=64,
)

# [arXiv:2212.04356; unverified] — enc-dec, conv frontend stubbed
WHISPER_LARGE_V3 = ModelConfig(
    name="whisper-large-v3", family="audio", num_layers=32, d_model=1280,
    num_heads=20, num_kv_heads=20, head_dim=64, d_ff=5120, vocab_size=51866,
    encoder_layers=32, encoder_frames=1500,
)

# [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention blocks
ZAMBA2_1P2B = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
)

# --- serving extras (not assigned archs) -----------------------------------
# Like the drafts below, EXTRAS deliberately do NOT live in ARCHS: the
# per-arch smoke/sharding/dryrun matrices cover the 10 assigned
# architectures only.  mamba2-2.7b exists to exercise the *pure*-recurrent
# paged-state serving path (rwkv6 covers attention-free-with-token-shift,
# zamba2 covers hybrid; plain mamba2 is the canonical SSD state machine).
# [arXiv:2405.21060; hf:state-spaces/mamba2-2.7b]
MAMBA2_2P7B = ModelConfig(
    name="mamba2-2.7b", family="mamba", num_layers=64, d_model=2560,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50288,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)

EXTRAS: Dict[str, ModelConfig] = {c.name: c for c in (MAMBA2_2P7B,)}


# --- speculative-decoding draft pairings (repro.spec) ----------------------
# A draft model shares the target's token space (same tokenizer, hence the
# same vocab_size — enforced by repro.models.registry.check_draft_pair) and
# is small enough that spec_k draft steps cost less than the one target
# forward they amortise.  Drafts deliberately do NOT live in ARCHS: they are
# serving accessories, not assigned architectures, so the per-arch
# smoke/sharding/dryrun test matrices never pick them up.
LLAMA3_8B_DRAFT = ModelConfig(
    name="llama3-8b-draft", family="dense", num_layers=4, d_model=1024,
    num_heads=8, num_kv_heads=2, head_dim=64, d_ff=4096, vocab_size=128256,
    rope_theta=500_000.0,
)

DRAFTS: Dict[str, ModelConfig] = {c.name: c for c in (LLAMA3_8B_DRAFT,)}

#: target arch -> registered draft arch (the launcher's ``--draft-model auto``)
DRAFT_FOR: Dict[str, str] = {"llama3-8b": "llama3-8b-draft"}


def get_draft_config(arch: str, smoke: bool = False, *,
                     pairing: bool = True):
    """Draft config lookup; ``None`` when nothing is registered.

    With ``pairing=True`` (default) ``arch`` names a *target* and resolves
    through ``DRAFT_FOR``; with ``pairing=False`` it must name a draft in
    ``DRAFTS`` directly — the two namespaces are kept separate so an
    explicit draft name that happens to be a target arch errors instead of
    silently serving the target's paired draft.  Smoke drafts scale down
    one notch further than the target's smoke config (single layer) so the
    draft stays cheaper than its target even at smoke scale."""
    name = (DRAFT_FOR.get(arch) if pairing
            else (arch if arch in DRAFTS else None))
    if name is None:
        return None
    cfg = DRAFTS[name]
    return scale_down(cfg, num_layers=1) if smoke else cfg


ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        STARCODER2_15B, LLAMA3_8B, CHATGLM3_6B, DEEPSEEK_CODER_33B,
        ARCTIC_480B, LLAMA4_MAVERICK_400B, INTERNVL2_1B, RWKV6_3B,
        WHISPER_LARGE_V3, ZAMBA2_1P2B,
    )
}

SMOKE: Dict[str, ModelConfig] = {}
for _n, _c in ARCHS.items():
    _over = {}
    if _c.family == "hybrid":
        _over = dict(num_layers=5, attn_every=2)      # 2 super-blocks + tail
    elif _c.family == "moe":
        _over = dict(num_layers=2 * max(_c.moe_every, 1))
    SMOKE[_n] = scale_down(_c, **_over)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE if smoke else ARCHS
    if arch in table:
        return table[arch]
    if arch in EXTRAS:
        cfg = EXTRAS[arch]
        return scale_down(cfg) if smoke else cfg
    raise KeyError(f"unknown arch {arch!r}; available: "
                   f"{sorted(set(table) | set(EXTRAS))}")
