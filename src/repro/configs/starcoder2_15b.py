"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import STARCODER2_15B, SMOKE

CONFIG = STARCODER2_15B
SMOKE_CONFIG = SMOKE[CONFIG.name]
