"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import DEEPSEEK_CODER_33B, SMOKE

CONFIG = DEEPSEEK_CODER_33B
SMOKE_CONFIG = SMOKE[CONFIG.name]
