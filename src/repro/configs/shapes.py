"""Assigned input-shape set + ``input_specs()`` ShapeDtypeStruct stand-ins.

Four shapes per LM-family arch (40 cells total):
  train_4k     seq 4,096  x batch 256   (training)
  prefill_32k  seq 32,768 x batch 32    (inference prefill)
  decode_32k   seq 32,768 x batch 128   (one new token, 32k KV/state)
  long_500k    seq 524,288 x batch 1    (long-context decode; sub-quadratic
                                         archs only — ssm/hybrid)

``decode_*``/``long_*`` lower ``serve_step`` (token + cache), never
``train_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic decode (ssm/hybrid); every assigned
    arch has a decoder, so decode shapes otherwise always apply."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str:
    if not applicable(cfg, shape):
        return "skipped_full_attention (0.5M-token full attention out of scope; see DESIGN.md)"
    return ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Weak-type-correct, shardable, no device allocation.  Cache/state specs
    come from the per-family model module so dry-run serve_step signatures
    match the real serving path exactly.
    """
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model

    if shape.kind == "train":
        specs: Dict[str, Any] = {}
        text = s
        if cfg.family == "vlm":
            text = s - cfg.vision_tokens
            specs["vision_embeds"] = _sds((b, cfg.vision_tokens, d), jnp.bfloat16)
        if cfg.family == "audio":
            specs["frames"] = _sds((b, cfg.encoder_frames, d), jnp.bfloat16)
        specs["tokens"] = _sds((b, text), jnp.int32)
        specs["labels"] = _sds((b, s), jnp.int32)
        return specs

    if shape.kind == "prefill":
        specs = {}
        text = s
        if cfg.family == "vlm":
            text = s - cfg.vision_tokens
            specs["vision_embeds"] = _sds((b, cfg.vision_tokens, d), jnp.bfloat16)
        if cfg.family == "audio":
            specs["frames"] = _sds((b, cfg.encoder_frames, d), jnp.bfloat16)
        specs["tokens"] = _sds((b, text), jnp.int32)
        return specs

    # decode: one new token against a seq_len-deep cache/state
    specs = {
        "tokens": _sds((b, 1), jnp.int32),
        "lengths": _sds((b,), jnp.int32),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        from ..models.lm import init_cache_abstract
        specs["cache"] = init_cache_abstract(cfg, b, s)
    elif cfg.family == "audio":
        from ..models.encdec import init_cache_abstract
        specs["cache"] = init_cache_abstract(cfg, b, s)
    elif cfg.family == "ssm":
        from ..models.rwkv_lm import init_state_abstract
        specs["cache"] = init_state_abstract(cfg, b)
    elif cfg.family == "hybrid":
        from ..models.hybrid import init_state_abstract
        specs["cache"] = init_state_abstract(cfg, b, s)
    return specs
