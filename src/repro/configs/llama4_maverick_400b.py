"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import LLAMA4_MAVERICK_400B, SMOKE

CONFIG = LLAMA4_MAVERICK_400B
SMOKE_CONFIG = SMOKE[CONFIG.name]
