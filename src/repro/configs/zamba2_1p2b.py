"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import ZAMBA2_1P2B, SMOKE

CONFIG = ZAMBA2_1P2B
SMOKE_CONFIG = SMOKE[CONFIG.name]
