"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import ARCTIC_480B, SMOKE

CONFIG = ARCTIC_480B
SMOKE_CONFIG = SMOKE[CONFIG.name]
