"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import WHISPER_LARGE_V3, SMOKE

CONFIG = WHISPER_LARGE_V3
SMOKE_CONFIG = SMOKE[CONFIG.name]
