"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import CHATGLM3_6B, SMOKE

CONFIG = CHATGLM3_6B
SMOKE_CONFIG = SMOKE[CONFIG.name]
