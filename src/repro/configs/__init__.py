from .base import ModelConfig, scale_down  # noqa: F401
from .registry import (ARCHS, DRAFT_FOR, DRAFTS, EXTRAS, SMOKE,  # noqa: F401
                       get_config, get_draft_config)
from .shapes import SHAPES, ShapeSpec, applicable, input_specs, skip_reason  # noqa: F401
