from .base import ModelConfig, scale_down  # noqa: F401
from .registry import ARCHS, SMOKE, get_config  # noqa: F401
from .shapes import SHAPES, ShapeSpec, applicable, input_specs, skip_reason  # noqa: F401
