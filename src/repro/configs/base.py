"""Model/config system: one frozen dataclass drives every architecture
family (dense / moe / vlm / ssm / audio / hybrid) plus the paper's CNNs.

Every assigned architecture gets a module in this package exposing
``CONFIG`` (the exact published geometry) and ``SMOKE`` (a reduced same-
family config for CPU tests).  ``repro.configs.registry`` maps ``--arch``
ids to them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str         # dense | moe | vlm | ssm | mamba | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1              # every Nth layer is MoE (llama4: 2)
    moe_capacity_factor: float = 1.25
    dense_residual_ff: int = 0      # arctic: parallel dense MLP width

    # --- SSM / hybrid ---
    ssm_state: int = 0              # N (state size per channel)
    ssm_head_dim: int = 64          # P
    ssm_expand: int = 2             # d_inner = expand * d_model
    attn_every: int = 0             # zamba2: shared attn block cadence

    # --- RWKV ---
    rwkv_head_dim: int = 64

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500      # whisper 30 s @ 50 Hz (post-conv stub)

    # --- VLM ---
    vision_tokens: int = 0          # stubbed patch embeddings per image

    # --- common knobs ---
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0      # chatglm3 rotates half the head dim
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- training-system knobs ---
    optimizer: str = "adamw"        # "adamw" | "adafactor" (giant MoE)
    remat: bool = True
    max_microbatch_tokens: int = 8192   # per-DP-shard grad-accum slice
    use_pallas_kernels: bool = False    # TPU hot path; XLA path for dry-run
    scan_layers: bool = True            # False: unroll (cost-extrapolation)

    # --- beyond-paper performance knobs (§Perf; default = paper-faithful
    # baseline behaviour) ---
    bf16_reduce: bool = False       # bf16 partial sums across TP boundaries
    remat_policy: str = "nothing"   # "nothing" | "save_coll" | "dots"
    rwkv_pad_heads_to: int = 0      # pad WKV heads to a TP multiple (0=off)
    fsdp: bool = False              # weight-gathered parallelism: batch over
                                    # ALL mesh axes, params 2D-sharded; wire
                                    # cost ~ params/layer instead of
                                    # activations (wins when tokens*d >>
                                    # layer params — the train_4k regime)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 (Megatron-style padding) so the vocab dim
        always divides the TP degree; padded logit slots are masked."""
        return -(-self.vocab_size // 256) * 256

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family in ("ssm", "mamba")

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: recurrent-state families only."""
        return self.family in ("ssm", "mamba", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def moe_layer_ids(self) -> Tuple[int, ...]:
        if not self.num_experts:
            return ()
        return tuple(i for i in range(self.num_layers) if (i % self.moe_every) == self.moe_every - 1)

    # ------------------------------------------------------------------
    # Parameter / FLOP accounting (roofline MODEL_FLOPS = 6*N*D).
    # ------------------------------------------------------------------

    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        dense_mlp = 3 * d * ff
        n = 0
        if self.family in ("dense", "moe", "vlm"):
            moe_ids = set(self.moe_layer_ids())
            for i in range(self.num_layers):
                n += attn + 2 * d
                if i in moe_ids:
                    n += self.num_experts * 3 * d * ff + d * self.num_experts
                    n += 3 * d * self.dense_residual_ff
                else:
                    n += dense_mlp
        elif self.family == "ssm":  # rwkv6: 5 dxd tmix mats + cr + relu^2 ffn
            per = 6 * d * d + 2 * d * ff + 64 * d + 12 * d
            n = self.num_layers * per
        elif self.family == "mamba":  # mamba2: mixer blocks only (no FFN)
            di = self.d_inner
            per = (d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                   + 4 * (di + 2 * self.ssm_state) + di * d + di + d)
            n = self.num_layers * per + d
        elif self.family == "hybrid":  # zamba2: mamba blocks + one shared attn
            di = self.d_inner
            per = (d * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                   + 4 * (di + 2 * self.ssm_state) + di * d + di + d)
            n = self.num_layers * per + (attn + d) + d
        elif self.family == "audio":
            gelu_mlp = 2 * d * ff
            enc = self.encoder_layers * (attn + gelu_mlp + 2 * d)
            dec = self.num_layers * (2 * attn + gelu_mlp + 3 * d)
            n = enc + dec + self.encoder_frames * d + 32768 * d + 2 * d
        n += v * d * (1 if self.tie_embeddings else 2)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        moe_layers = len(self.moe_layer_ids())
        all_experts = moe_layers * self.num_experts * 3 * self.d_model * self.d_ff
        active = moe_layers * self.experts_per_token * 3 * self.d_model * self.d_ff
        return full - all_experts + active

    def model_flops_per_token(self, training: bool = True) -> float:
        """6*N_active per token for train (fwd+bwd), 2*N_active for inference."""
        mult = 6.0 if training else 2.0
        return mult * self.active_param_count()


def with_depth(cfg: ModelConfig, k: int) -> ModelConfig:
    """Depth-k variant (k scan units, layers UNROLLED) for the dry-run's
    cost extrapolation: cost(full) = cost(k=1) + (D-1)*(cost(k=2)-cost(k=1)),
    because XLA's cost_analysis counts a scanned body once (see
    roofline/analysis.py).  Structure per family:
      dense/moe/vlm: k super-blocks;  ssm: k layers;
      hybrid: k super-blocks (tail dropped);  audio: k enc + k dec layers.
    """
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    over = dict(scan_layers=False, name=f"{cfg.name}-d{k}")
    if cfg.family == "hybrid":
        over["num_layers"] = k * cfg.attn_every
    elif cfg.family == "audio":
        over["num_layers"] = k
        over["encoder_layers"] = k
    else:
        over["num_layers"] = k * me
    return dataclasses.replace(cfg, **over)


def depth_units(cfg: ModelConfig) -> int:
    """Number of scan units D in the full config (matches with_depth)."""
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every  # tail counted via remainder
    if cfg.family == "audio":
        return cfg.num_layers  # enc and dec both scale with k
    return cfg.num_layers // me


def scale_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build the reduced same-family SMOKE config."""
    base = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_frames=16 if cfg.encoder_layers else cfg.encoder_frames,
        num_experts=8 if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.num_experts else 0,
        # drop-free capacity so decode == teacher-forced forward exactly
        moe_capacity_factor=64.0 if cfg.num_experts else cfg.moe_capacity_factor,
        vision_tokens=8 if cfg.vision_tokens else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if (cfg.ssm_state or cfg.family == "ssm") else cfg.ssm_head_dim,
        rwkv_head_dim=16,
        attn_every=2 if cfg.attn_every else 0,
        max_microbatch_tokens=1 << 30,  # no grad accum in smoke tests
        name=cfg.name + "-smoke",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
