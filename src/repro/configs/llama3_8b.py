"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import LLAMA3_8B, SMOKE

CONFIG = LLAMA3_8B
SMOKE_CONFIG = SMOKE[CONFIG.name]
