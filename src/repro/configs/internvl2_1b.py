"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import INTERNVL2_1B, SMOKE

CONFIG = INTERNVL2_1B
SMOKE_CONFIG = SMOKE[CONFIG.name]
