"""Selectable config module for --arch (see registry.py for the
full annotated definition and source citation)."""
from .registry import RWKV6_3B, SMOKE

CONFIG = RWKV6_3B
SMOKE_CONFIG = SMOKE[CONFIG.name]
