"""Kernel registry: each Pallas family declares *what can be swept*.

A :class:`KernelSpec` packages everything the autotuner and the benchmark
driver need to treat a kernel family generically:

* ``make_inputs(shape, dtype, seed)`` — build random operands for a shape,
* ``run(args, config, interpret)`` — invoke the Pallas wrapper at a given
  :class:`~repro.bench.config.BlockConfig`,
* ``ref(args)`` — the pure-jnp oracle from the family's ``ref.py`` (the
  correctness gate compares against this),
* ``tune_space(shape)`` — the legal candidate configs for this shape,
* ``default_config(shape)`` — the heuristic used when nothing is tuned,
* ``flops(shape)`` / ``hbm_bytes(shape, config)`` — analytic work and
  memory-traffic models for GFLOP/s and Table-III-style reporting,
* ``vmem_bytes(shape, config)`` (optional) — the tile working-set a config
  keeps resident on-chip; the cost model (``repro.cost``) penalises
  candidates that overflow the active hardware profile's VMEM ceiling.

Families register via :func:`register`; the built-in families live in
:mod:`repro.bench.specs` and are loaded lazily on first lookup so that
``repro.kernels`` -> ``repro.bench.config`` imports never cycle back through
the kernel packages.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Tuple)

from .config import BlockConfig

Shape = Mapping[str, int]


@dataclasses.dataclass(frozen=True)
class TuneSpace:
    """Declarative sweep space: parameter name -> candidate values.

    ``constraint(config, shape)`` prunes illegal combinations (e.g. a chunk
    that does not divide the sequence length, or a tile bigger than the
    padded operand).
    """

    params: Tuple[Tuple[str, Tuple[int, ...]], ...]
    constraint: Callable[[BlockConfig, Shape], bool] = lambda cfg, shape: True

    @classmethod
    def make(cls, constraint=None, **params: Iterable[int]) -> "TuneSpace":
        items = tuple(sorted((k, tuple(v)) for k, v in params.items()))
        return cls(items, constraint or (lambda cfg, shape: True))

    def candidates(self, shape: Shape) -> List[BlockConfig]:
        configs = [BlockConfig()]
        for name, values in self.params:
            configs = [c.replace(**{name: v}) for c in configs for v in values]
        return [c for c in configs if self.constraint(c, shape)]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str
    make_inputs: Callable[[Shape, str, int], Tuple[Any, ...]]
    run: Callable[[Tuple[Any, ...], BlockConfig, bool], Any]
    ref: Callable[[Tuple[Any, ...]], Any]
    tune_space: Callable[[Shape], TuneSpace]
    default_config: Callable[[Shape], BlockConfig]
    shape_key: Callable[[Shape], str]
    flops: Callable[[Shape], int]
    hbm_bytes: Callable[[Shape, BlockConfig], int]
    vmem_bytes: Optional[Callable[[Shape, BlockConfig], int]] = None
    rtol: float = 2e-3
    atol: float = 2e-3

    def candidates(self, shape: Shape) -> List[BlockConfig]:
        return self.tune_space(shape).candidates(shape)


_REGISTRY: Dict[str, KernelSpec] = {}
_defaults_loaded = False


def register(spec: KernelSpec) -> KernelSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"kernel spec {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_defaults() -> None:
    # Lazy so `repro.kernels` -> `repro.bench` imports don't cycle: specs.py
    # imports the kernel wrappers, which import repro.bench.config.
    global _defaults_loaded
    if not _defaults_loaded:
        _defaults_loaded = True
        from . import specs  # noqa: F401


def get_spec(name: str) -> KernelSpec:
    _ensure_defaults()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no kernel spec {name!r}; known: {sorted(_REGISTRY)}") from None


def all_specs() -> Dict[str, KernelSpec]:
    _ensure_defaults()
    return dict(_REGISTRY)
