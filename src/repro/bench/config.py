"""Block-configuration objects and the tuned-config JSON cache.

A :class:`BlockConfig` is an immutable, hashable bag of integer-ish
parameters (``block_m``, ``chunk``, ...).  Hashability matters: resolved
parameters are handed to jit'd kernels as static arguments, and configs act
as dict keys inside the autotuner.

A :class:`ConfigCache` persists tuned winners to JSON.  Entries are keyed by
``kernel|shape_key|dtype|backend`` so a cache tuned on TPU never leaks into
CPU interpret-mode runs and vice versa.  On-disk schema (version 1)::

    {
      "version": 1,
      "entries": {
        "apr_matmul|m256_k512_n256|float32|cpu": {
          "config":  {"block_m": 128, "block_n": 128, "block_k": 128},
          "metrics": {"us": 812.4, "gflops": 82.5},
          "tuned_at": "2026-07-26T00:00:00"
        }
      }
    }

Resolution (:func:`resolve_config`) consults the *active* cache: the
innermost :func:`scoped_cache` on the current thread (each serve engine
scopes its own ``tune_cache`` around warm-up and every ``step()``, so two
engines with different tuned profiles coexist in one process), falling
back to the process-wide default (:func:`default_cache` — loads from
``$REPRO_TUNE_CACHE`` if set, else ``~/.cache/repro/tune_cache.json``).
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import threading
from pathlib import Path
from typing import Dict, Iterable, Mapping, Optional, Tuple

SCHEMA_VERSION = 1
_ENV_VAR = "REPRO_TUNE_CACHE"


@dataclasses.dataclass(frozen=True, order=True)
class BlockConfig:
    """Immutable set of sweepable kernel parameters (tile/chunk sizes)."""

    items: Tuple[Tuple[str, int], ...] = ()

    def __post_init__(self):
        # frozen dataclass: stash the lookup dict once instead of rebuilding
        # it on every accessor call (these run inside timed benchmark loops)
        object.__setattr__(self, "_map", dict(self.items))

    @classmethod
    def make(cls, **params: int) -> "BlockConfig":
        return cls(tuple(sorted(params.items())))

    @classmethod
    def from_dict(cls, d: Mapping[str, int]) -> "BlockConfig":
        return cls.make(**dict(d))

    def to_dict(self) -> Dict[str, int]:
        return dict(self._map)

    def get(self, key: str, default: Optional[int] = None) -> Optional[int]:
        return self._map.get(key, default)

    def __getitem__(self, key: str) -> int:
        return self._map[key]

    def __contains__(self, key: str) -> bool:
        return key in self._map

    def replace(self, **params: int) -> "BlockConfig":
        merged = dict(self.items)
        merged.update(params)
        return BlockConfig.make(**merged)

    def __repr__(self) -> str:  # compact: BlockConfig(block_k=128, block_m=64)
        inner = ", ".join(f"{k}={v}" for k, v in self.items)
        return f"BlockConfig({inner})"


def cache_key(kernel: str, shape_key: str, dtype: str, backend: str) -> str:
    """Canonical ``kernel|shape|dtype|backend`` entry key."""
    return "|".join((kernel, shape_key, dtype, backend))


def shape_key_from_dims(**dims: int) -> str:
    """``m=256, k=512`` -> ``"k512_m256"`` (sorted for stability)."""
    return "_".join(f"{k}{v}" for k, v in sorted(dims.items()))


class ConfigCache:
    """JSON-backed map of tuned :class:`BlockConfig` winners.

    Thread-safe for the engine's admit/step interleaving; writes are
    whole-file atomic (tmp + rename) so a crashed sweep never corrupts a
    previously-good cache.
    """

    def __init__(self, path: Optional[os.PathLike] = None, *,
                 autosave: bool = True):
        if path is None:
            path = os.environ.get(_ENV_VAR) or (
                Path.home() / ".cache" / "repro" / "tune_cache.json")
        self.path = Path(path)
        self.autosave = autosave
        self._lock = threading.Lock()
        self._entries: Dict[str, dict] = {}
        if self.path.exists():
            self.load()

    # -- persistence ------------------------------------------------------
    def load(self) -> None:
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return
        if raw.get("version") != SCHEMA_VERSION:
            return
        with self._lock:
            self._entries = dict(raw.get("entries", {}))

    def save(self) -> None:
        # hold the lock across snapshot AND rename: two concurrent stores
        # must not land their files in reversed order and drop an entry
        with self._lock:
            payload = {"version": SCHEMA_VERSION, "entries": self._entries}
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
            os.replace(tmp, self.path)

    # -- entry access -----------------------------------------------------
    def lookup(self, kernel: str, shape_key: str, dtype: str,
               backend: str) -> Optional[BlockConfig]:
        entry = self._entries.get(cache_key(kernel, shape_key, dtype, backend))
        if not entry:
            return None
        return BlockConfig.from_dict(entry["config"])

    def store(self, kernel: str, shape_key: str, dtype: str, backend: str,
              config: BlockConfig,
              metrics: Optional[Mapping[str, float]] = None) -> None:
        entry = {
            "config": config.to_dict(),
            "metrics": dict(metrics or {}),
            "tuned_at": datetime.datetime.now().isoformat(timespec="seconds"),
        }
        with self._lock:
            self._entries[cache_key(kernel, shape_key, dtype, backend)] = entry
        if self.autosave:
            self.save()

    def entries(self) -> Dict[str, dict]:
        with self._lock:
            return dict(self._entries)

    def keys_for_kernel(self, kernel: str) -> Iterable[str]:
        prefix = kernel + "|"
        return [k for k in self.entries() if k.startswith(prefix)]

    def __len__(self) -> int:
        return len(self._entries)


_default_cache: Optional[ConfigCache] = None
_default_lock = threading.Lock()
_scope = threading.local()  # per-thread stack of scoped caches


def default_cache() -> ConfigCache:
    """Process-wide fallback cache (``$REPRO_TUNE_CACHE`` or the user
    cache dir); resolution consults it only when no scope is active."""
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = ConfigCache()
        return _default_cache


def set_default_cache(cache: Optional[ConfigCache]) -> None:
    """Swap the process-wide *fallback* cache (tests; ``None`` restores
    the env-derived default).  Engine-owned caches do NOT go through here
    anymore — they are scoped with :func:`scoped_cache`, so two engines
    with different ``tune_cache`` paths (or dtypes) coexist without
    clobbering each other's resolution.  The old last-engine-wins footgun
    is retired; regression:
    tests/test_autotune.py::test_two_engine_tune_caches_coexist.
    """
    global _default_cache
    with _default_lock:
        _default_cache = cache


class scoped_cache:
    """Context manager: make ``cache`` the active resolution cache on this
    thread for the dynamic extent of the block.

    Scopes nest (innermost wins) and ``scoped_cache(None)`` is a no-op, so
    call sites can wrap unconditionally.  The serve engines wrap their
    warm-up and every ``step()`` in their own scope — kernel config
    resolution happens at trace time, inside the step's first jit call, so
    the scope is exactly wide enough."""

    def __init__(self, cache: Optional["ConfigCache"]):
        self.cache = cache

    def __enter__(self):
        if self.cache is not None:
            if not hasattr(_scope, "stack"):
                _scope.stack = []
            _scope.stack.append(self.cache)
        return self.cache

    def __exit__(self, *exc):
        if self.cache is not None:
            _scope.stack.pop()
        return False


def active_cache() -> ConfigCache:
    """The cache config resolution uses *right now*: the innermost active
    :func:`scoped_cache`, else the process-wide default."""
    stack = getattr(_scope, "stack", None)
    if stack:
        return stack[-1]
    return default_cache()


def resolve_config(
    kernel: str,
    shape_key: str,
    dtype: str,
    backend: str,
    *,
    default: BlockConfig,
    override: Optional[BlockConfig] = None,
    explicit: Optional[Mapping[str, Optional[int]]] = None,
) -> BlockConfig:
    """Resolution order used by every ``ops.py`` wrapper.

    1. per-parameter ``explicit`` kwargs the caller pinned (non-None values),
    2. an ``override`` config object passed by the caller,
    3. the tuned winner in the default :class:`ConfigCache`,
    4. the kernel's shape-derived ``default`` heuristic.
    """
    base = default
    cached = active_cache().lookup(kernel, shape_key, dtype, backend)
    if cached is not None:
        base = base.replace(**cached.to_dict())
    if override is not None:
        base = base.replace(**override.to_dict())
    if explicit:
        pinned = {k: v for k, v in explicit.items() if v is not None}
        if pinned:
            base = base.replace(**pinned)
    return base
