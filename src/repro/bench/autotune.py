"""Sweep driver: time every legal candidate, gate on correctness, persist.

The autotuner is deliberately boring: for each candidate
:class:`~repro.bench.config.BlockConfig` in the spec's
:class:`~repro.bench.registry.TuneSpace` it

1. runs the kernel once and compares against the family's ``ref.py`` oracle
   (``numpy.allclose`` at the spec's tolerances) — candidates that produce
   wrong numbers are *rejected*, never timed, never cached;
2. times the survivor with ``jax.block_until_ready`` (median of ``iters``
   timed calls after ``warmup`` untimed ones);
3. stores the fastest validated candidate in the :class:`ConfigCache` under
   ``kernel|shape|dtype|backend`` so every later ``ops.py`` call resolves it.

Timing off-TPU runs the interpret path, so absolute numbers are a
correctness-path proxy; relative ordering of block configs is still
meaningful for cache plumbing and the JSON report marks the backend.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import numpy as np

from .config import BlockConfig, ConfigCache, active_cache
from .registry import KernelSpec, Shape


def time_callable(fn, *, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-clock seconds per call, synchronised on device completion."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples))


@dataclasses.dataclass
class TuneResult:
    kernel: str
    shape_key: str
    dtype: str
    backend: str
    config: Optional[BlockConfig]     # None if every candidate failed the gate
    us: float                         # best median microseconds per call
    gflops: float                     # analytic FLOPs / best time
    hbm_bytes: int                    # analytic traffic at the best config
    n_candidates: int
    rejected: List[Tuple[BlockConfig, str]]  # (config, reason) for failures

    @property
    def ok(self) -> bool:
        return self.config is not None


def _validate(spec: KernelSpec, out, ref) -> Optional[str]:
    out = np.asarray(out, dtype=np.float32)
    ref = np.asarray(ref, dtype=np.float32)
    if out.shape != ref.shape:
        return f"shape {out.shape} != ref {ref.shape}"
    if not np.allclose(out, ref, rtol=spec.rtol, atol=spec.atol):
        err = float(np.max(np.abs(out - ref)))
        return f"max abs err {err:.3e} exceeds rtol={spec.rtol} atol={spec.atol}"
    return None


def autotune(
    spec: KernelSpec,
    shape: Shape,
    *,
    dtype: str = "float32",
    seed: int = 0,
    cache: Optional[ConfigCache] = None,
    interpret: Optional[bool] = None,
    max_candidates: Optional[int] = None,
    iters: int = 3,
    warmup: int = 1,
    validate: bool = True,
) -> TuneResult:
    """Sweep ``spec``'s tune space for one (shape, dtype); cache the winner."""
    backend = jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    cache = cache if cache is not None else active_cache()
    shape_key = spec.shape_key(shape)

    args = spec.make_inputs(shape, dtype, seed)
    ref = np.asarray(spec.ref(args), dtype=np.float32) if validate else None

    candidates = spec.candidates(shape)
    if max_candidates is not None:
        candidates = candidates[:max_candidates]

    best: Optional[BlockConfig] = None
    best_t = float("inf")
    rejected: List[Tuple[BlockConfig, str]] = []
    for cfg in candidates:
        try:
            out = spec.run(args, cfg, interpret)
            jax.block_until_ready(out)
        except Exception as exc:  # illegal tiling the constraint missed
            rejected.append((cfg, f"raised {type(exc).__name__}: {exc}"))
            continue
        if validate:
            reason = _validate(spec, out, ref)
            if reason is not None:
                rejected.append((cfg, reason))
                continue
        t = time_callable(lambda: spec.run(args, cfg, interpret),
                          iters=iters, warmup=warmup)
        if t < best_t:
            best, best_t = cfg, t

    gflops = 0.0
    traffic = 0
    if best is not None:
        gflops = spec.flops(shape) / best_t / 1e9
        traffic = spec.hbm_bytes(shape, best)
        cache.store(spec.name, shape_key, dtype, backend, best,
                    metrics={"us": best_t * 1e6, "gflops": gflops})
    return TuneResult(
        kernel=spec.name, shape_key=shape_key, dtype=dtype, backend=backend,
        config=best, us=best_t * 1e6 if best is not None else float("inf"),
        gflops=gflops, hbm_bytes=traffic,
        n_candidates=len(candidates), rejected=rejected,
    )


def warm_cache(
    kernels_and_shapes,
    *,
    dtype: str = "float32",
    cache: Optional[ConfigCache] = None,
    sweep: bool = False,
    **tune_kwargs,
) -> dict:
    """Resolve (and optionally tune) configs for a list of (kernel, shape).

    With ``sweep=False`` (the default — cheap, used by the serve engine at
    start-up) this only *reads*: it reports which shapes already have tuned
    winners in the cache.  With ``sweep=True`` it runs :func:`autotune` for
    every miss.  Returns ``{f"{kernel}|{shape_key}": BlockConfig | None}``.
    """
    from .registry import get_spec

    backend = jax.default_backend()
    cache = cache if cache is not None else active_cache()
    resolved = {}
    for kernel, shape in kernels_and_shapes:
        spec = get_spec(kernel)
        key = spec.shape_key(shape)
        cfg = cache.lookup(kernel, key, dtype, backend)
        if cfg is None and sweep:
            result = autotune(spec, shape, dtype=dtype, cache=cache,
                              **tune_kwargs)
            cfg = result.config
        resolved[f"{kernel}|{key}"] = cfg
    return resolved
