"""Sweep driver: time candidates, gate on correctness, persist the winner.

The autotuner is deliberately boring: for each candidate
:class:`~repro.bench.config.BlockConfig` in the spec's
:class:`~repro.bench.registry.TuneSpace` it

1. runs the kernel once and compares against the family's ``ref.py`` oracle
   (``numpy.allclose`` at the spec's tolerances) — candidates that produce
   wrong numbers are *rejected*, never timed, never cached;
2. times the survivor with ``jax.block_until_ready`` (median of ``iters``
   timed calls after ``warmup`` untimed ones; ``$REPRO_BENCH_ITERS`` /
   ``$REPRO_BENCH_WARMUP`` override the defaults when the caller does not
   pass explicit values, and the min–max spread of the samples is recorded
   so consumers can tell a real win from timer noise);
3. stores the fastest validated candidate in the :class:`ConfigCache` under
   ``kernel|shape|dtype|backend`` so every later ``ops.py`` call resolves it.

``prune_top_k`` turns on cost-model pruning: candidates are ranked by
:func:`repro.cost.rank_candidates` (analytic roofline-with-leak price per
config on the active :class:`~repro.roofline.hw.HardwareProfile`) and only
the cheapest-predicted K are *timed*.  Exhaustive timing stays the default
and the fallback, and the correctness gate is evaluated for every timed
candidate exactly as before — pruning can never cache a config the oracle
has not blessed.  The result records ``predicted_us`` for the winner so
``BENCH_kernels.json`` can report predicted-vs-measured error per family.

Timing off-TPU runs the interpret path, so absolute numbers are a
correctness-path proxy; relative ordering of block configs is still
meaningful for cache plumbing and the JSON report marks the backend.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Tuple

import jax
import numpy as np

from .config import BlockConfig, ConfigCache, active_cache
from .registry import KernelSpec, Shape

_ITERS_ENV = "REPRO_BENCH_ITERS"
_WARMUP_ENV = "REPRO_BENCH_WARMUP"
_DEFAULT_ITERS = 3
_DEFAULT_WARMUP = 1


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        return max(0, int(raw))
    except ValueError:
        return default


def resolve_timing(iters: Optional[int] = None,
                   warmup: Optional[int] = None) -> Tuple[int, int]:
    """(iters, warmup) with explicit args > env overrides > defaults (3, 1)."""
    if iters is None:
        iters = _env_int(_ITERS_ENV, _DEFAULT_ITERS)
    if warmup is None:
        warmup = _env_int(_WARMUP_ENV, _DEFAULT_WARMUP)
    return max(1, iters), warmup


def time_stats(fn, *, iters: Optional[int] = None,
               warmup: Optional[int] = None) -> Tuple[float, float]:
    """(median, max-min spread) wall-clock seconds per call, synchronised on
    device completion.  None iters/warmup defer to ``$REPRO_BENCH_ITERS`` /
    ``$REPRO_BENCH_WARMUP`` then the 3/1 defaults."""
    iters, warmup = resolve_timing(iters, warmup)
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples)), float(max(samples) - min(samples))


def time_callable(fn, *, iters: Optional[int] = None,
                  warmup: Optional[int] = None) -> float:
    """Median wall-clock seconds per call (see :func:`time_stats`)."""
    return time_stats(fn, iters=iters, warmup=warmup)[0]


@dataclasses.dataclass
class TuneResult:
    kernel: str
    shape_key: str
    dtype: str
    backend: str
    config: Optional[BlockConfig]     # None if every candidate failed the gate
    us: float                         # best median microseconds per call
    gflops: float                     # analytic FLOPs / best time
    hbm_bytes: int                    # analytic traffic at the best config
    n_candidates: int
    rejected: List[Tuple[BlockConfig, str]]  # (config, reason) for failures
    spread_us: float = 0.0            # max-min sample spread at the winner
    predicted_us: Optional[float] = None  # cost-model price of the winner
    n_timed: int = 0                  # candidates actually timed
    pruned_from: Optional[int] = None  # pre-pruning candidate count, if pruned

    @property
    def ok(self) -> bool:
        return self.config is not None


def _validate(spec: KernelSpec, out, ref) -> Optional[str]:
    out = np.asarray(out, dtype=np.float32)
    ref = np.asarray(ref, dtype=np.float32)
    if out.shape != ref.shape:
        return f"shape {out.shape} != ref {ref.shape}"
    if not np.allclose(out, ref, rtol=spec.rtol, atol=spec.atol):
        err = float(np.max(np.abs(out - ref)))
        return f"max abs err {err:.3e} exceeds rtol={spec.rtol} atol={spec.atol}"
    return None


def autotune(
    spec: KernelSpec,
    shape: Shape,
    *,
    dtype: str = "float32",
    seed: int = 0,
    cache: Optional[ConfigCache] = None,
    interpret: Optional[bool] = None,
    max_candidates: Optional[int] = None,
    iters: Optional[int] = None,
    warmup: Optional[int] = None,
    validate: bool = True,
    prune_top_k: Optional[int] = None,
    profile=None,
) -> TuneResult:
    """Sweep ``spec``'s tune space for one (shape, dtype); cache the winner.

    With ``prune_top_k`` set, only the K cheapest candidates under the
    analytic cost model are timed (the rest are never run); the correctness
    gate still applies to every timed candidate.
    """
    backend = jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    cache = cache if cache is not None else active_cache()
    shape_key = spec.shape_key(shape)

    args = spec.make_inputs(shape, dtype, seed)
    ref = np.asarray(spec.ref(args), dtype=np.float32) if validate else None

    candidates = spec.candidates(shape)
    if max_candidates is not None:
        candidates = candidates[:max_candidates]

    pruned_from: Optional[int] = None
    predicted: dict = {}
    if prune_top_k is not None and len(candidates) > prune_top_k:
        # Lazy import: repro.cost imports repro.bench.config, which pulls in
        # this module via the package __init__.
        from ..cost import rank_candidates
        ranked = rank_candidates(spec, shape, candidates, profile=profile)
        predicted = {cfg: est for cfg, est in ranked}
        pruned_from = len(candidates)
        candidates = [cfg for cfg, _ in ranked[:prune_top_k]]
    elif prune_top_k is not None:
        from ..cost import rank_candidates
        predicted = dict(rank_candidates(spec, shape, candidates,
                                         profile=profile))

    best: Optional[BlockConfig] = None
    best_t = float("inf")
    best_spread = 0.0
    n_timed = 0
    rejected: List[Tuple[BlockConfig, str]] = []
    for cfg in candidates:
        try:
            out = spec.run(args, cfg, interpret)
            jax.block_until_ready(out)
        except Exception as exc:  # illegal tiling the constraint missed
            rejected.append((cfg, f"raised {type(exc).__name__}: {exc}"))
            continue
        if validate:
            reason = _validate(spec, out, ref)
            if reason is not None:
                rejected.append((cfg, reason))
                continue
        t, spread = time_stats(lambda: spec.run(args, cfg, interpret),
                               iters=iters, warmup=warmup)
        n_timed += 1
        if t < best_t:
            best, best_t, best_spread = cfg, t, spread

    gflops = 0.0
    traffic = 0
    predicted_us: Optional[float] = None
    if best is not None:
        gflops = spec.flops(shape) / best_t / 1e9
        traffic = spec.hbm_bytes(shape, best)
        est = predicted.get(best)
        if est is not None:
            predicted_us = est.predicted_us
        cache.store(spec.name, shape_key, dtype, backend, best,
                    metrics={"us": best_t * 1e6, "gflops": gflops})
    return TuneResult(
        kernel=spec.name, shape_key=shape_key, dtype=dtype, backend=backend,
        config=best, us=best_t * 1e6 if best is not None else float("inf"),
        gflops=gflops, hbm_bytes=traffic,
        n_candidates=len(candidates), rejected=rejected,
        spread_us=best_spread * 1e6 if best is not None else 0.0,
        predicted_us=predicted_us, n_timed=n_timed, pruned_from=pruned_from,
    )


def warm_cache(
    kernels_and_shapes,
    *,
    dtype: str = "float32",
    cache: Optional[ConfigCache] = None,
    sweep: bool = False,
    **tune_kwargs,
) -> dict:
    """Resolve (and optionally tune) configs for a list of (kernel, shape).

    With ``sweep=False`` (the default — cheap, used by the serve engine at
    start-up) this only *reads*: it reports which shapes already have tuned
    winners in the cache.  With ``sweep=True`` it runs :func:`autotune` for
    every miss.  Returns ``{f"{kernel}|{shape_key}": BlockConfig | None}``.
    """
    from .registry import get_spec

    backend = jax.default_backend()
    cache = cache if cache is not None else active_cache()
    resolved = {}
    for kernel, shape in kernels_and_shapes:
        spec = get_spec(kernel)
        key = spec.shape_key(shape)
        cfg = cache.lookup(kernel, key, dtype, backend)
        if cfg is None and sweep:
            result = autotune(spec, shape, dtype=dtype, cache=cache,
                              **tune_kwargs)
            cfg = result.config
        resolved[f"{kernel}|{key}"] = cfg
    return resolved
