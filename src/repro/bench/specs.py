"""KernelSpec registrations for the Pallas kernel families (the five seed
families, the paged-KV decode-attention variant, the int8 quantized
matmul, and the fused-epilogue variants the graph compiler dispatches to:
``apr_matmul_fused`` / ``apr_conv_fused`` / ``quant_matmul_fused``).

Each spec wires a family's public wrapper (``ops.py``), its pure-jnp oracle
(``ref.py``), a shape-aware :class:`TuneSpace`, and analytic FLOP /
HBM-traffic models.  The traffic models charge every streamed operand once
per pass it is re-read plus the accumulator term from
:func:`repro.core.apr.reduction_hbm_traffic` — the APR's whole point is that
the accumulator term collapses to one write per output element.

``make_inputs`` may pack static parameters (conv stride/padding) into the
args tuple; the paired ``run``/``ref`` callables unpack them.  All byte
counts assume fp32 operands (itemsize 4); they are analytic Table-III-style
models, not hardware counters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.apr import reduction_hbm_traffic
from ..kernels.apr_conv import ops as conv_ops
from ..kernels.apr_conv.ref import conv2d_fused_ref, conv2d_ref
from ..kernels.apr_matmul import ops as matmul_ops
from ..kernels.apr_matmul.ref import matmul_fused_ref, matmul_ref
from ..kernels.flash_decode import ops as decode_ops
from ..kernels.flash_decode.ref import (decode_attention_ref,
                                        paged_decode_attention_q_ref,
                                        paged_decode_attention_ref)
from ..kernels.mamba2 import ops as mamba_ops
from ..kernels.mamba2.ref import mamba2_ref
from ..kernels.quant_matmul import ops as qmm_ops
from ..kernels.quant_matmul.ref import (quant_matmul_fused_ref,
                                        quant_matmul_ref)
from ..kernels.rwkv6 import ops as rwkv_ops
from ..kernels.rwkv6.ref import rwkv6_ref
from .config import shape_key_from_dims
from .registry import KernelSpec, TuneSpace, register

_F32 = 4  # analytic traffic models assume fp32 operands
_I8 = 1   # quantized operands stream 1 byte/element


def spec_verify_shapes(cfg, slots: int, spec_k: int):
    """Kernel shapes the speculative verify pass (``repro.spec``) adds on
    top of the plain decode tick's: one batched ``decode_paged`` forward
    verifies ``spec_k`` drafted tokens plus the pending token per slot, so
    the slot-batch GEMM widens from ``slots`` rows to
    ``slots * (spec_k + 1)``.  The attention side needs no new family — the
    T = K+1 verify rides the same chunked-prefill contract as T = chunk
    prefill (and its paged gather is the ``flash_decode_paged`` shape the
    engine already warms).  Used by
    ``SpeculativeServeEngine._decode_kernel_shapes``.
    """
    return [("apr_matmul", {"m": slots * (spec_k + 1), "k": cfg.d_model,
                            "n": cfg.d_ff})]


def _keys(seed: int, n: int):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def _normal(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _divisor_chunks(t: int, candidates=(16, 32, 64, 128)) -> TuneSpace:
    return TuneSpace.make(
        chunk=candidates,
        constraint=lambda cfg, s: cfg["chunk"] <= t and t % cfg["chunk"] == 0,
    )


# ---------------------------------------------------------------- apr_matmul
def _matmul_inputs(shape, dtype, seed):
    kx, ky = _keys(seed, 2)
    return (_normal(kx, (shape["m"], shape["k"]), dtype),
            _normal(ky, (shape["k"], shape["n"]), dtype))


def _matmul_space(shape):
    def fits(cfg, s):
        # prune tiles absurdly larger than the (padded) problem; the ops
        # wrapper legalises anyway, so this only removes duplicate timings.
        # The 128 floor always keeps the MXU-aligned base tile in play.
        return (cfg["block_m"] <= max(128, 2 * s["m"])
                and cfg["block_n"] <= max(128, 2 * s["n"])
                and cfg["block_k"] <= max(128, 2 * s["k"]))
    return TuneSpace.make(
        block_m=(64, 128, 256),
        block_n=(128, 256),
        block_k=(128, 256, 512),
        constraint=fits,
    )


def _matmul_vmem(shape, cfg, *, w_bytes=_F32):
    """Resident tile working set of the blocked matmul: one LHS tile, one
    RHS tile (``w_bytes`` wide — int8 for the quant family), and the fp32
    APR accumulator tile.  Used by the repro.cost occupancy term."""
    bm, bn, bk = cfg["block_m"], cfg["block_n"], cfg["block_k"]
    return bm * bk * _F32 + bk * bn * w_bytes + bm * bn * _F32


def _matmul_traffic(shape, cfg):
    m, k, n = shape["m"], shape["k"], shape["n"]
    x_reads = m * k * _F32 * _cdiv(n, cfg["block_n"])
    y_reads = k * n * _F32 * _cdiv(m, cfg["block_m"])
    acc = reduction_hbm_traffic(m * n, _cdiv(k, cfg["block_k"]), _F32, "apr")
    return x_reads + y_reads + acc


register(KernelSpec(
    name="apr_matmul",
    make_inputs=_matmul_inputs,
    run=lambda args, cfg, interpret: matmul_ops.apr_matmul(
        *args, config=cfg, interpret=interpret),
    ref=lambda args: matmul_ref(*args),
    tune_space=_matmul_space,
    default_config=lambda s: matmul_ops.default_config(s["m"], s["k"], s["n"]),
    shape_key=lambda s: matmul_ops.shape_key(s["m"], s["k"], s["n"]),
    flops=lambda s: 2 * s["m"] * s["k"] * s["n"],
    hbm_bytes=_matmul_traffic,
    vmem_bytes=_matmul_vmem,
    rtol=5e-4, atol=5e-4,
))


# --------------------------------------------------------------- quant_matmul
def _qmm_inputs(shape, dtype, seed):
    """Activations stay float (`dtype`); the weight operand is quantized
    offline exactly as `repro.quant.quantize_params` would store it."""
    kx, ky = _keys(seed, 2)
    x = _normal(kx, (shape["m"], shape["k"]), dtype)
    w = _normal(ky, (shape["k"], shape["n"]), jnp.float32)
    w_q, w_scale = qmm_ops.quantize_weights(w)
    return (x, w_q, w_scale)


def _qmm_traffic(shape, cfg):
    """Same streaming pattern as apr_matmul, at int8 width: both operands
    move 1 byte/element (plus the fp32 scale vectors, one element per row /
    output channel per pass); the int32 APR still collapses the accumulator
    term to one fp32 write per output element."""
    m, k, n = shape["m"], shape["k"], shape["n"]
    n_pass_x = _cdiv(n, cfg["block_n"])
    n_pass_y = _cdiv(m, cfg["block_m"])
    x_reads = (m * k * _I8 + m * _F32) * n_pass_x
    y_reads = (k * n * _I8 + n * _F32) * n_pass_y
    acc = reduction_hbm_traffic(m * n, _cdiv(k, cfg["block_k"]), _F32, "apr")
    return x_reads + y_reads + acc


register(KernelSpec(
    name="quant_matmul",
    make_inputs=_qmm_inputs,
    run=lambda args, cfg, interpret: qmm_ops.quant_matmul(
        *args, config=cfg, interpret=interpret),
    ref=lambda args: quant_matmul_ref(*args),
    tune_space=_matmul_space,
    default_config=lambda s: qmm_ops.default_config(s["m"], s["k"], s["n"]),
    shape_key=lambda s: qmm_ops.shape_key(s["m"], s["k"], s["n"]),
    flops=lambda s: 2 * s["m"] * s["k"] * s["n"],
    hbm_bytes=_qmm_traffic,
    vmem_bytes=lambda s, cfg: _matmul_vmem(s, cfg, w_bytes=_I8),
    # the oracle mirrors the kernel's integer arithmetic exactly; only the
    # final fp32 scale multiplies can differ in rounding
    rtol=1e-4, atol=1e-4,
))


# --------------------------------------------------------- fused epilogues
# The fused-epilogue variants (repro.graph dispatch targets) tune under
# their own family names: an epilogue-bearing GEMM may pick different
# tiles than a bare one (the flush does more VPU work per APR drain), and
# a winner tuned for one must never silently apply to the other.  The
# benchmark shape fixes the canonical epilogue (bias + relu); the ops
# wrappers accept any ACTIVATIONS member at the same tiles.


def _fused_matmul_inputs(shape, dtype, seed):
    kx, ky, kb = _keys(seed, 3)
    return (_normal(kx, (shape["m"], shape["k"]), dtype),
            _normal(ky, (shape["k"], shape["n"]), dtype),
            _normal(kb, (shape["n"],), jnp.float32))


register(KernelSpec(
    name="apr_matmul_fused",
    make_inputs=_fused_matmul_inputs,
    run=lambda args, cfg, interpret: matmul_ops.apr_matmul_fused(
        args[0], args[1], bias=args[2], activation="relu",
        config=cfg, interpret=interpret),
    ref=lambda args: matmul_fused_ref(args[0], args[1], args[2], "relu"),
    tune_space=_matmul_space,
    default_config=lambda s: matmul_ops.default_config(s["m"], s["k"], s["n"]),
    shape_key=lambda s: shape_key_from_dims(m=s["m"], k=s["k"], n=s["n"]),
    flops=lambda s: 2 * s["m"] * s["k"] * s["n"] + 2 * s["m"] * s["n"],
    hbm_bytes=lambda s, cfg: _matmul_traffic(s, cfg)
    + s["n"] * _F32 * _cdiv(s["m"], cfg["block_m"]),
    vmem_bytes=lambda s, cfg: _matmul_vmem(s, cfg)
    + cfg["block_n"] * _F32,
    rtol=5e-4, atol=5e-4,
))


def _fused_qmm_inputs(shape, dtype, seed):
    kx, ky, kb = _keys(seed, 3)
    x = _normal(kx, (shape["m"], shape["k"]), dtype)
    w = _normal(ky, (shape["k"], shape["n"]), jnp.float32)
    w_q, w_scale = qmm_ops.quantize_weights(w)
    return (x, w_q, w_scale, _normal(kb, (shape["n"],), jnp.float32))


register(KernelSpec(
    name="quant_matmul_fused",
    make_inputs=_fused_qmm_inputs,
    run=lambda args, cfg, interpret: qmm_ops.quant_matmul_fused(
        args[0], args[1], args[2], bias=args[3], activation="relu",
        config=cfg, interpret=interpret),
    ref=lambda args: quant_matmul_fused_ref(args[0], args[1], args[2],
                                            args[3], "relu"),
    tune_space=_matmul_space,
    default_config=lambda s: qmm_ops.default_config(s["m"], s["k"], s["n"]),
    shape_key=lambda s: qmm_ops.shape_key(s["m"], s["k"], s["n"]),
    flops=lambda s: 2 * s["m"] * s["k"] * s["n"] + 2 * s["m"] * s["n"],
    hbm_bytes=lambda s, cfg: _qmm_traffic(s, cfg)
    + s["n"] * _F32 * _cdiv(s["m"], cfg["block_m"]),
    vmem_bytes=lambda s, cfg: _matmul_vmem(s, cfg, w_bytes=_I8)
    + cfg["block_n"] * _F32,
    rtol=1e-4, atol=1e-4,
))


# ------------------------------------------------------------------ apr_conv
def _conv_dims(shape):
    ho = (shape["h"] + 2 * shape["padding"] - shape["hf"]) // shape["stride"] + 1
    wo = (shape["w"] + 2 * shape["padding"] - shape["wf"]) // shape["stride"] + 1
    return ho, wo


def _conv_inputs(shape, dtype, seed):
    kx, kf = _keys(seed, 2)
    x = _normal(kx, (shape["b"], shape["h"], shape["w"], shape["c"]), dtype)
    f = _normal(kf, (shape["hf"], shape["wf"], shape["c"], shape["m"]), dtype)
    return (x, f, shape["stride"], shape["padding"])


def _conv_traffic(shape, cfg):
    ho, wo = _conv_dims(shape)
    mm = shape["b"] * ho * wo                       # im2col rows
    kk = shape["hf"] * shape["wf"] * shape["c"]     # im2col reduction depth
    nn = shape["m"]
    patches = mm * kk * _F32 * _cdiv(nn, cfg["block_n"])
    filters = kk * nn * _F32 * _cdiv(mm, cfg["block_m"])
    acc = reduction_hbm_traffic(mm * nn, _cdiv(kk, cfg["block_k"]), _F32, "apr")
    return patches + filters + acc


register(KernelSpec(
    name="apr_conv",
    make_inputs=_conv_inputs,
    run=lambda args, cfg, interpret: conv_ops.apr_conv2d(
        args[0], args[1], stride=args[2], padding=args[3],
        config=cfg, interpret=interpret),
    ref=lambda args: conv2d_ref(args[0], args[1], stride=args[2],
                                padding=args[3]),
    tune_space=lambda shape: TuneSpace.make(
        block_m=(64, 128, 256), block_n=(128,), block_k=(128, 256)),
    default_config=lambda s: conv_ops.default_config(
        s["b"], s["h"], s["w"], s["c"], s["hf"], s["wf"], s["m"],
        s["stride"], s["padding"]),
    shape_key=lambda s: conv_ops.shape_key(
        s["b"], s["h"], s["w"], s["c"], s["hf"], s["wf"], s["m"],
        s["stride"], s["padding"]),
    flops=lambda s: 2 * s["b"] * _conv_dims(s)[0] * _conv_dims(s)[1]
    * s["hf"] * s["wf"] * s["c"] * s["m"],
    hbm_bytes=_conv_traffic,
    vmem_bytes=_matmul_vmem,   # im2col tiles: same residency as the matmul
    rtol=2e-3, atol=2e-3,
))


def _fused_conv_inputs(shape, dtype, seed):
    kx, kf, kb = _keys(seed, 3)
    x = _normal(kx, (shape["b"], shape["h"], shape["w"], shape["c"]), dtype)
    f = _normal(kf, (shape["hf"], shape["wf"], shape["c"], shape["m"]), dtype)
    bias = _normal(kb, (shape["m"],), jnp.float32)
    return (x, f, bias, shape["stride"], shape["padding"])


def _fused_conv_traffic(shape, cfg):
    # unfused conv streams plus the (1, M) bias read once per output-tile
    # row of the im2col matmul — same bias term as the fused matmul specs
    ho, wo = _conv_dims(shape)
    mm = shape["b"] * ho * wo
    return (_conv_traffic(shape, cfg)
            + shape["m"] * _F32 * _cdiv(mm, cfg["block_m"]))


register(KernelSpec(
    name="apr_conv_fused",
    make_inputs=_fused_conv_inputs,
    run=lambda args, cfg, interpret: conv_ops.apr_conv2d_fused(
        args[0], args[1], bias=args[2], activation="relu",
        stride=args[3], padding=args[4], config=cfg, interpret=interpret),
    ref=lambda args: conv2d_fused_ref(args[0], args[1], args[2], "relu",
                                      stride=args[3], padding=args[4]),
    tune_space=lambda shape: TuneSpace.make(
        block_m=(64, 128, 256), block_n=(128,), block_k=(128, 256)),
    default_config=lambda s: conv_ops.default_config(
        s["b"], s["h"], s["w"], s["c"], s["hf"], s["wf"], s["m"],
        s["stride"], s["padding"]),
    shape_key=lambda s: shape_key_from_dims(
        b=s["b"], h=s["h"], w=s["w"], c=s["c"], hf=s["hf"], wf=s["wf"],
        m=s["m"], s=s["stride"], p=s["padding"]),
    flops=lambda s: 2 * s["b"] * _conv_dims(s)[0] * _conv_dims(s)[1]
    * s["hf"] * s["wf"] * s["c"] * s["m"],
    hbm_bytes=_fused_conv_traffic,
    vmem_bytes=lambda s, cfg: _matmul_vmem(s, cfg)
    + cfg["block_n"] * _F32,
    rtol=2e-3, atol=2e-3,
))


# -------------------------------------------------------------- flash_decode
def _decode_inputs(shape, dtype, seed):
    kq, kk, kv = _keys(seed, 3)
    b, hq, hkv, d, s = (shape["b"], shape["hq"], shape["hkv"], shape["d"],
                        shape["s"])
    q = _normal(kq, (b, hq, d), dtype)
    k = _normal(kk, (b, s, hkv, d), dtype)
    v = _normal(kv, (b, s, hkv, d), dtype)
    lengths = jnp.full((b,), s, jnp.int32)
    return (q, k, v, lengths)


def _decode_traffic(shape, cfg):
    b, hq, hkv, d, s = (shape["b"], shape["hq"], shape["hkv"], shape["d"],
                        shape["s"])
    streams = (2 * b * s * hkv * d + 2 * b * hq * d) * _F32  # K,V in; Q,O
    acc = reduction_hbm_traffic(b * hq * d, _cdiv(s, cfg["chunk"]), _F32,
                                "apr")
    return streams + acc


register(KernelSpec(
    name="flash_decode",
    make_inputs=_decode_inputs,
    run=lambda args, cfg, interpret: decode_ops.flash_decode(
        *args, config=cfg, interpret=interpret),
    ref=lambda args: decode_attention_ref(*args),
    tune_space=lambda shape: TuneSpace.make(
        chunk=(64, 128, 256, 512),
        constraint=lambda cfg, s: (cfg["chunk"] <= s["s"]
                                   and s["s"] % cfg["chunk"] == 0)),
    default_config=lambda s: decode_ops.default_config(
        s["b"], s["hq"], s["hkv"], s["d"], s["s"]),
    shape_key=lambda s: decode_ops.shape_key(
        s["b"], s["hq"], s["hkv"], s["d"], s["s"]),
    flops=lambda s: 4 * s["b"] * s["hq"] * s["s"] * s["d"],  # QK^T + PV
    hbm_bytes=_decode_traffic,
    rtol=2e-3, atol=2e-3,
))


# ------------------------------------------------------- flash_decode_paged
def _paged_decode_inputs(shape, dtype, seed):
    """Pages are deliberately assigned out of order (striped across the
    pool) so the benchmark actually exercises block-table gathering rather
    than a secretly-contiguous layout.  With ``kv_int8`` set in the shape,
    the pools are quantized per (token, head) exactly as the serve engine
    stores them (``kv_dtype="int8"``) and the int8 gather-dequant kernel
    variant is exercised under its own ``_kvint8`` tuned-config key."""
    kq, kk, kv = _keys(seed, 3)
    b, hq, hkv, d = shape["b"], shape["hq"], shape["hkv"], shape["d"]
    pages, ps = shape["pages"], shape["ps"]
    pool = b * pages + 1                      # + reserved null page 0
    q = _normal(kq, (b, hq, d), dtype)
    k_pages = _normal(kk, (pool, ps, hkv, d), dtype)
    v_pages = _normal(kv, (pool, ps, hkv, d), dtype)
    # slot i's j-th logical page -> physical page 1 + j*b + i
    bt = (1 + jnp.arange(pages)[None, :] * b
          + jnp.arange(b)[:, None]).astype(jnp.int32)
    lengths = jnp.full((b,), pages * ps, jnp.int32)
    if shape.get("kv_int8"):
        from ..quant import quantize_channelwise
        kq_ = quantize_channelwise(k_pages, axis=-1)
        vq_ = quantize_channelwise(v_pages, axis=-1)
        return (q, kq_.q, vq_.q, kq_.scale[..., 0], vq_.scale[..., 0],
                lengths, bt)
    return (q, k_pages, v_pages, lengths, bt)


def _paged_decode_run(args, cfg, interpret):
    if len(args) == 7:                        # int8 pools + scale pools
        q, kp, vp, ks, vs, lengths, bt = args
        return decode_ops.flash_decode_paged(
            q, kp, vp, lengths, bt, k_scales=ks, v_scales=vs,
            config=cfg, interpret=interpret)
    return decode_ops.flash_decode_paged(*args, config=cfg,
                                         interpret=interpret)


def _paged_decode_ref(args):
    if len(args) == 7:
        return paged_decode_attention_q_ref(*args)
    return paged_decode_attention_ref(*args)


def _paged_decode_traffic(shape, cfg):
    b, hq, hkv, d = shape["b"], shape["hq"], shape["hkv"], shape["d"]
    s = shape["pages"] * shape["ps"]          # live logical tokens per seq
    if shape.get("kv_int8"):                  # int8 payload + fp32 head scale
        kv_bytes = 2 * b * s * hkv * (d * _I8 + _F32)
    else:
        kv_bytes = 2 * b * s * hkv * d * _F32
    streams = kv_bytes + 2 * b * hq * d * _F32           # K,V in; Q,O
    acc = reduction_hbm_traffic(b * hq * d, _cdiv(s, cfg["chunk"]), _F32,
                                "apr")
    return streams + acc


def _paged_shape_key(s):
    key = decode_ops.paged_shape_key(
        s["b"], s["hq"], s["hkv"], s["d"], s["pages"], s["ps"])
    # must match the suffix flash_decode_paged's wrapper resolves under
    return key + ("_kvint8" if s.get("kv_int8") else "")


register(KernelSpec(
    name="flash_decode_paged",
    make_inputs=_paged_decode_inputs,
    run=_paged_decode_run,
    ref=_paged_decode_ref,
    tune_space=lambda shape: TuneSpace.make(
        chunk=(16, 32, 64, 128, 256),
        constraint=lambda cfg, s: (cfg["chunk"] <= s["ps"]
                                   and s["ps"] % cfg["chunk"] == 0)),
    default_config=lambda s: decode_ops.paged_default_config(
        s["b"], s["hq"], s["hkv"], s["d"], s["pages"], s["ps"]),
    shape_key=_paged_shape_key,
    flops=lambda s: 4 * s["b"] * s["hq"] * s["pages"] * s["ps"] * s["d"],
    hbm_bytes=_paged_decode_traffic,
    rtol=2e-3, atol=2e-3,
))


# -------------------------------------------------------------------- mamba2
def _mamba_inputs(shape, dtype, seed):
    kx, kb, kc, kdt, ka, kd = _keys(seed, 6)
    b, t, h, p, n = (shape["b"], shape["t"], shape["h"], shape["p"],
                     shape["n"])
    x = _normal(kx, (b, t, h, p), dtype)
    bmat = _normal(kb, (b, t, n), dtype)
    cmat = _normal(kc, (b, t, n), dtype)
    dt = jax.random.uniform(kdt, (b, t, h), jnp.float32, 1e-3, 0.1)
    a = -jax.random.uniform(ka, (h,), jnp.float32, 0.5, 1.5)
    d = _normal(kd, (h,), jnp.float32)
    return (x, bmat, cmat, dt, a, d)


def _mamba_traffic(shape, cfg):
    b, t, h, p, n = (shape["b"], shape["t"], shape["h"], shape["p"],
                     shape["n"])
    # x/dt/y streams plus B/C broadcast per head; the (P, N) state is APR
    streams = (2 * b * t * h * p + 2 * b * t * h * n + b * t * h) * _F32
    acc = reduction_hbm_traffic(b * h * p * n, _cdiv(t, cfg["chunk"]), _F32,
                                "apr")
    return streams + acc


register(KernelSpec(
    name="mamba2",
    make_inputs=_mamba_inputs,
    run=lambda args, cfg, interpret: mamba_ops.mamba2_ssd(
        *args, config=cfg, interpret=interpret),
    ref=lambda args: mamba2_ref(*args),
    tune_space=lambda shape: _divisor_chunks(shape["t"]),
    default_config=lambda s: mamba_ops.default_config(
        s["b"], s["t"], s["h"], s["p"], s["n"]),
    shape_key=lambda s: mamba_ops.shape_key(
        s["b"], s["t"], s["h"], s["p"], s["n"]),
    flops=lambda s: 6 * s["b"] * s["t"] * s["h"] * s["p"] * s["n"],
    hbm_bytes=_mamba_traffic,
    rtol=2e-3, atol=2e-3,
))


# --------------------------------------------------------------------- rwkv6
def _rwkv_inputs(shape, dtype, seed):
    kr, kk, kv, kw, ku = _keys(seed, 5)
    b, t, h, d = shape["b"], shape["t"], shape["h"], shape["d"]
    r = _normal(kr, (b, t, h, d), dtype)
    k = _normal(kk, (b, t, h, d), dtype)
    v = _normal(kv, (b, t, h, d), dtype)
    w = jax.random.uniform(kw, (b, t, h, d), jnp.float32, 0.3, 0.99)
    u = _normal(ku, (h, d), jnp.float32)
    return (r, k, v, w.astype(dtype) if dtype != "float32" else w, u)


def _rwkv_traffic(shape, cfg):
    b, t, h, d = shape["b"], shape["t"], shape["h"], shape["d"]
    streams = 5 * b * t * h * d * _F32     # r/k/v/w in, y out
    acc = reduction_hbm_traffic(b * h * d * d, _cdiv(t, cfg["chunk"]), _F32,
                                "apr")
    return streams + acc


register(KernelSpec(
    name="rwkv6",
    make_inputs=_rwkv_inputs,
    run=lambda args, cfg, interpret: rwkv_ops.rwkv6_wkv(
        *args, config=cfg, interpret=interpret),
    ref=lambda args: rwkv6_ref(*args),
    tune_space=lambda shape: _divisor_chunks(shape["t"]),
    default_config=lambda s: rwkv_ops.default_config(
        s["b"], s["t"], s["h"], s["d"]),
    shape_key=lambda s: rwkv_ops.shape_key(s["b"], s["t"], s["h"], s["d"]),
    flops=lambda s: 6 * s["b"] * s["t"] * s["h"] * s["d"] * s["d"],
    hbm_bytes=_rwkv_traffic,
    rtol=2e-3, atol=2e-3,
))
