"""``repro.bench`` — unified benchmark + autotune subsystem.

The paper's Table III is only reproducible here if every Pallas kernel runs
at its best achievable block configuration *and* the numbers are captured in
a machine-readable form.  This package provides the three layers that make
that systematic:

* :mod:`repro.bench.config` — :class:`BlockConfig` (an immutable bag of
  sweepable tile/chunk parameters) and :class:`ConfigCache` (a JSON cache of
  tuned winners keyed by ``kernel|shape|dtype|backend``).  The kernel
  wrappers in ``repro.kernels.*.ops`` resolve their tile sizes through
  :func:`resolve_config`, so a tuned cache transparently retunes every call
  site — there are no hardcoded tile constants at ``kernel.py`` call sites.
* :mod:`repro.bench.registry` — :class:`KernelSpec`: each kernel family
  registers its runner, its pure-jnp correctness reference (``ref.py``), and
  a :class:`TuneSpace` declaring which parameters may be swept for a given
  shape.  All families — the five seed ones (``apr_matmul``, ``apr_conv``,
  ``flash_decode``, ``mamba2``, ``rwkv6``), the paged/quantized additions
  (``flash_decode_paged``, ``quant_matmul``), and the fused-epilogue
  variants (``apr_matmul_fused``, ``apr_conv_fused``,
  ``quant_matmul_fused``) — register themselves lazily from
  :mod:`repro.bench.specs`.
* :mod:`repro.bench.autotune` — the sweep driver: times every legal
  candidate with ``jax.block_until_ready``, rejects candidates whose output
  diverges from the reference (the correctness gate), and persists the
  winner to the cache.

Usage::

    from repro.bench import autotune, get_spec, default_cache

    spec = get_spec("apr_matmul")
    shape = {"m": 256, "k": 512, "n": 256}
    result = autotune(spec, shape, dtype="float32")   # sweeps + validates
    print(result.config, result.us, result.gflops)

    # later calls pick the winner up automatically:
    from repro.kernels import apr_matmul
    out = apr_matmul(x, y)          # resolves blocks via default_cache()

``benchmarks/bench_kernels.py`` drives this over all registered families and
emits ``BENCH_kernels.json`` (schema documented in ``benchmarks/README.md``).
"""
from .config import (  # noqa: F401
    BlockConfig,
    ConfigCache,
    active_cache,
    cache_key,
    default_cache,
    resolve_config,
    scoped_cache,
    set_default_cache,
)
from .registry import KernelSpec, TuneSpace, all_specs, get_spec, register  # noqa: F401
from .autotune import TuneResult, autotune, time_callable, warm_cache  # noqa: F401
