"""Async streaming front end over the serving engines.

:class:`AsyncServeFrontend` turns the tick-driven engines
(:class:`~repro.serve.engine.PagedServeEngine`, its speculative subclass,
or the contiguous :class:`~repro.serve.engine.ServeEngine`) into an
asyncio server: callers ``await submit(...)`` and receive a
:class:`TokenStream` that yields tokens as the engine emits them, instead
of blocking until the whole batch drains.  This replaces the batch-drain
loop ``repro.launch.serve`` shipped with: requests now arrive *while* the
engine runs, which is what makes SLO classes and the prefix cache earn
their keep (a TTFT-class request can jump the admission queue mid-flight;
a late request can attach KV pages that an earlier wave published).

Design: everything runs on one asyncio loop, no threads.  A single driver
task alternates ``engine.step()`` (host-blocking, device-synchronous — the
same tick the batch loop ran) with an ``await`` checkpoint, so submissions
and consumers interleave between ticks, never during one.  Because
submission and stepping never overlap, the engines need no locking and
keep their deterministic tick semantics — greedy outputs are identical to
feeding the same requests through ``run_until_drained()``.  When nothing
is queued or active the driver parks on an :class:`asyncio.Event` and
costs nothing until the next ``submit`` wakes it.

Streaming: after each tick the driver diffs every live request's
``output`` against what its stream has already delivered and pushes the
new tokens into that stream's queue (then a sentinel when the request
finishes).  Consumers iterate ``async for tok in stream`` — per-token
latency is one engine tick, not one request lifetime.

SLO classes ride on the scheduler (:data:`repro.serve.scheduler.SLO_TTFT`
jumps the admission queue, :data:`~repro.serve.scheduler.SLO_THROUGHPUT`
is FIFO with aged anti-starvation; see ``FifoScheduler._pick_next``), and
per-request metrics — TTFT, end-to-end latency, tokens, preemptions,
queue-jump count — are collected on :meth:`TokenStream.metrics` when the
stream ends.
"""
from __future__ import annotations

import asyncio
import itertools
import time
from typing import Dict, List, Optional

from .scheduler import SLO_THROUGHPUT, Request

_DONE = object()   # stream sentinel: the request finished


class TokenStream:
    """Per-request async token stream.

    ``async for tok in stream`` yields generated token ids as the engine
    produces them; iteration ends when the request finishes.  The
    underlying :class:`~repro.serve.scheduler.Request` is exposed as
    ``.request`` for callers that want scheduling state mid-flight.
    """

    def __init__(self, request: Request):
        self.request = request
        self._queue: "asyncio.Queue" = asyncio.Queue()
        self._delivered = 0    # tokens pushed into the queue so far

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        tok = await self._queue.get()
        if tok is _DONE:
            raise StopAsyncIteration
        return tok

    async def drain(self) -> List[int]:
        """Collect the remaining tokens into a list (convenience for
        callers that don't need per-token streaming)."""
        return [tok async for tok in self]

    def metrics(self) -> Dict[str, object]:
        """Per-request serving metrics (meaningful once the stream ends)."""
        r = self.request
        return {
            "rid": r.rid,
            "slo": r.slo,
            "tokens": len(r.output),
            "ttft_s": round(r.ttft, 4) if r.first_token_at else None,
            "latency_s": (round(r.finished_at - r.submitted_at, 4)
                          if r.finished_at else None),
            "preemptions": r.preemptions,
            "queue_jumped": r.skips,
            "prefill_tokens": len(r.prompt),
        }


class AsyncServeFrontend:
    """Asyncio front end driving one serving engine.

    Works with any engine exposing ``submit(Request)`` / ``step()`` — the
    paged engine (+ speculative subclass) and the contiguous slot engine.
    Use as an async context manager, or call :meth:`start` / :meth:`stop`::

        async with AsyncServeFrontend(engine) as front:
            stream = await front.submit([1, 2, 3], max_new_tokens=16)
            async for tok in stream:
                ...
    """

    def __init__(self, engine):
        self.engine = engine
        self._streams: List[TokenStream] = []
        self._rid = itertools.count()
        self._wake = asyncio.Event()
        self._running = False
        self._driver: Optional["asyncio.Task"] = None

    # -- lifecycle --------------------------------------------------------
    async def start(self) -> "AsyncServeFrontend":
        if self._driver is not None:
            raise RuntimeError("frontend already started")
        self._running = True
        self._driver = asyncio.get_running_loop().create_task(self._drive())
        return self

    async def stop(self) -> None:
        """Stop the driver after the current tick.  In-flight requests stay
        un-finished; their streams end with what was already delivered."""
        self._running = False
        self._wake.set()
        if self._driver is not None:
            await self._driver
            self._driver = None
        for stream in self._streams:   # unblock any waiting consumers
            stream._queue.put_nowait(_DONE)
        self._streams.clear()

    async def __aenter__(self) -> "AsyncServeFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- request intake ---------------------------------------------------
    async def submit(self, prompt: List[int], *, max_new_tokens: int = 32,
                     eos_id: Optional[int] = None,
                     slo: str = SLO_THROUGHPUT,
                     rid: Optional[int] = None) -> TokenStream:
        """Queue a generation request; returns its :class:`TokenStream`.
        Raises whatever the engine's ``submit`` raises (empty prompt,
        prompt larger than the page pool, ...) before anything is queued.
        """
        if self._driver is None:
            raise RuntimeError("frontend not started (use `async with` or "
                               "await start())")
        req = Request(rid=rid if rid is not None else next(self._rid),
                      prompt=list(prompt), max_new_tokens=max_new_tokens,
                      eos_id=eos_id, slo=slo)
        req.submitted_at = time.perf_counter()
        # safe between ticks: the driver only mutates engine state inside
        # step(), and this coroutine never runs concurrently with it
        self.engine.submit(req)
        stream = TokenStream(req)
        self._streams.append(stream)
        self._wake.set()
        return stream

    async def generate(self, prompt: List[int], **kw) -> List[int]:
        """Submit and drain in one call (non-streaming convenience)."""
        stream = await self.submit(prompt, **kw)
        return await stream.drain()

    # -- driver -----------------------------------------------------------
    def _has_work(self) -> bool:
        eng = self.engine
        if any(r is not None for r in eng.active):
            return True
        sched = getattr(eng, "sched", None)
        if sched is not None:                 # paged engines
            return bool(sched.waiting)
        return not eng.pending.empty()        # contiguous slot engine

    def _pump(self) -> None:
        """Push tokens emitted since the last tick into their streams."""
        live = []
        for stream in self._streams:
            req = stream.request
            for tok in req.output[stream._delivered:]:
                stream._queue.put_nowait(tok)
            stream._delivered = len(req.output)
            if req.done:
                stream._queue.put_nowait(_DONE)
            else:
                live.append(stream)
        self._streams = live

    async def _drive(self) -> None:
        while self._running:
            if self._has_work():
                self.engine.step()
                self._pump()
                # yield so submissions/consumers interleave between ticks
                await asyncio.sleep(0)
            else:
                self._wake.clear()
                if self._running and not self._has_work():
                    await self._wake.wait()
