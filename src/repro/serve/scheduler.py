"""FIFO admission scheduler with token-budgeted chunked prefill + preemption.

Pure host-side policy, no JAX: the engine asks it *what* to run each tick
(admissions, prefill chunks, the preemption victim) and executes the device
work itself.  Keeping the policy side-effect-free against engine state makes
the invariants unit-testable without building a model.

Request lifecycle::

    QUEUED --admit--> PREFILLING --prompt cached--> DECODING --eos/len--> DONE
       ^                  |                            |
       +---- preempt (pages freed, recompute) ---------+

Scheduler invariants (tested in tests/test_serve_engine.py):

* **FIFO admission** — requests enter PREFILLING in submit order; a
  preempted request re-enters at the *front* of the queue, so overall
  completion order remains submit order under greedy decoding.
* **Token-budgeted prefill** — at most ``prefill_budget`` prompt tokens are
  processed per tick across all PREFILLING slots, in admission order, in
  chunks of at most ``prefill_chunk`` tokens; decode ticks for already-
  DECODING slots continue regardless (chunked prefill never starves decode).
* **Youngest-first preemption** — when the page pool cannot cover a
  mandatory allocation, the most recently admitted active request is
  preempted: its pages are freed in one step and its prompt *plus generated
  tokens* are requeued for recompute, so its visible output is unchanged
  (greedy decode is deterministic).
* **Row-budgeted verify** (speculative decoding, ``repro.spec``) — a
  speculative tick spends at most ``verify_budget`` verify rows (drafted
  tokens + one pending token per request) across all DECODING slots, in
  admission order; a request that gets no rows simply skips the tick.
  Per-request draft accounting (``spec_steps`` / ``draft_proposed`` /
  ``draft_accepted``) lives on :class:`Request`.
* **SLO-aware admission with bounded aging** — each request carries an SLO
  class: :data:`SLO_TTFT` (latency-sensitive; jumps the admission queue) or
  :data:`SLO_THROUGHPUT` (the default; plain FIFO).  Every time a waiting
  request is passed over by a later-submitted TTFT request its ``skips``
  counter grows; at ``starvation_limit`` it is force-admitted ahead of any
  TTFT traffic, so a throughput request waits at most ``starvation_limit``
  queue-jumps regardless of offered TTFT load (no livelock — tested in
  tests/test_server.py).  With a single class in play admission reduces to
  exact FIFO and no skips accumulate.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional, Tuple

# request lifecycle states
QUEUED = "queued"
PREFILLING = "prefilling"
DECODING = "decoding"
DONE = "done"

# SLO classes (Request.slo)
SLO_TTFT = "ttft"              # latency-sensitive: priority admission
SLO_THROUGHPUT = "throughput"  # default: FIFO, protected by aging


@dataclasses.dataclass
class Request:
    """One generation request.  ``output`` accumulates generated tokens;
    ``done`` mirrors ``state == DONE`` for seed-engine API compatibility."""
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slo: str = SLO_THROUGHPUT     # admission class (SLO_TTFT jumps the queue)

    # -- scheduling state (engine/scheduler internal) --------------------
    state: str = QUEUED
    slot: int = -1
    skips: int = 0                # admissions that passed this request over
                                  #   while it waited (aging anti-starvation)
    prefill_pos: int = 0          # tokens of ``prefill_tokens()`` cached
    admit_seq: int = -1           # admission order; youngest = max
    preemptions: int = 0
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0

    # -- speculative-decoding state (repro.spec; zeros on the plain path) --
    spec_steps: int = 0           # verify steps run for this request
    draft_proposed: int = 0       # draft tokens proposed across all steps
    draft_accepted: int = 0       # ... of which the target model confirmed

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the target verified (0 when the
        request never ran speculatively)."""
        return self.draft_accepted / max(self.draft_proposed, 1)

    def prefill_tokens(self) -> List[int]:
        """What must be in the KV cache before decode can proceed: the
        prompt, plus — after a preemption — every token generated so far
        (recompute-style preemption keeps outputs identical)."""
        return self.prompt + self.output

    @property
    def ttft(self) -> float:
        return (self.first_token_at - self.submitted_at
                if self.first_token_at else float("nan"))


class FifoScheduler:
    """Admission queue + per-tick prefill planning + preemption policy."""

    def __init__(self, *, prefill_chunk: int = 16,
                 prefill_budget: Optional[int] = None,
                 verify_budget: Optional[int] = None,
                 starvation_limit: int = 8):
        if prefill_chunk <= 0:
            raise ValueError("prefill_chunk must be positive")
        if starvation_limit < 1:
            raise ValueError("starvation_limit must be >= 1")
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = prefill_budget or prefill_chunk
        # verify_budget caps the *verify rows* (drafted tokens + the pending
        # token, i.e. model positions) one speculative tick may spend across
        # all DECODING slots; None = every slot verifies at full spec_k.
        self.verify_budget = verify_budget
        # how many queue-jumps a waiting request tolerates before it is
        # force-admitted ahead of TTFT traffic (bounded-wait guarantee)
        self.starvation_limit = starvation_limit
        self.waiting: Deque[Request] = collections.deque()
        self._admit_seq = 0

    # -- queue ------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.state = QUEUED
        if not req.submitted_at:
            req.submitted_at = time.perf_counter()
        self.waiting.append(req)

    def requeue_preempted(self, req: Request) -> None:
        """Preempted requests go to the *front*: they were admitted before
        anything still waiting, so FIFO order is preserved."""
        req.state = QUEUED
        req.slot = -1
        req.prefill_pos = 0
        req.preemptions += 1
        self.waiting.appendleft(req)

    def _pick_next(self) -> Request:
        """Next request to admit: a starved request (skips at the limit)
        beats everything, then the oldest waiting TTFT-class request, then
        plain FIFO.  Requests the pick jumped over age one skip each —
        since only TTFT picks can jump, skips grow at most once per TTFT
        admission and the wait is bounded by ``starvation_limit``."""
        pick = None
        for r in self.waiting:
            if r.skips >= self.starvation_limit:
                pick = r
                break
        if pick is None:
            pick = next((r for r in self.waiting if r.slo == SLO_TTFT),
                        self.waiting[0])
        for r in self.waiting:
            if r is pick:
                break
            r.skips += 1
        self.waiting.remove(pick)
        return pick

    def admit(self, free_slots: List[int]) -> List[Tuple[int, Request]]:
        """Assign waiting requests to free slots, one per slot: FIFO within
        an SLO class, TTFT class first, aged-out requests before both
        (see :meth:`_pick_next`)."""
        placed = []
        for slot in free_slots:
            if not self.waiting:
                break
            req = self._pick_next()
            req.state = PREFILLING
            req.slot = slot
            req.prefill_pos = 0
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
            placed.append((slot, req))
        return placed

    # -- per-tick plans ---------------------------------------------------
    def prefill_plan(self, prefilling: List[Request]) -> List[Tuple[Request, int]]:
        """(request, n_tokens) chunks for this tick, admission order, total
        capped at ``prefill_budget`` tokens."""
        plan = []
        budget = self.prefill_budget
        for req in sorted(prefilling, key=lambda r: r.admit_seq):
            if budget <= 0:
                break
            remaining = len(req.prefill_tokens()) - req.prefill_pos
            n = min(self.prefill_chunk, remaining, budget)
            if n > 0:
                plan.append((req, n))
                budget -= n
        return plan

    def verify_plan(self, decoding: List[Request],
                    spec_k: int) -> List[Tuple[Request, int]]:
        """(request, k) speculative verify chunks for this tick.

        Each DECODING request gets ``k <= spec_k`` drafted tokens to verify
        (plus its pending token — ``k + 1`` model positions).  ``k`` is
        additionally capped at ``max_new_tokens`` headroom: a verify step
        can emit at most ``k + 1`` tokens, so drafting past the remaining
        quota is wasted draft *and* wasted verify compute.  With a
        ``verify_budget``, rows are granted in admission order until the
        budget runs out; a request that cannot get even its pending row is
        deferred to the next tick (it simply does not decode this tick —
        outputs are unaffected, only latency).
        """
        if spec_k < 0:
            raise ValueError("spec_k must be >= 0")
        plan = []
        budget = (self.verify_budget if self.verify_budget is not None
                  else (spec_k + 1) * max(len(decoding), 1))
        for req in sorted(decoding, key=lambda r: r.admit_seq):
            if budget < 1:
                break
            remaining = req.max_new_tokens - len(req.output)
            k = max(0, min(spec_k, remaining - 1, budget - 1))
            plan.append((req, k))
            budget -= k + 1
        return plan

    def preemption_victim(self, active: List[Request],
                          exclude: Optional[Request] = None) -> Optional[Request]:
        """Youngest-admitted active request (LIFO preemption: the request
        that has consumed the least scheduler time loses its pages)."""
        pool = [r for r in active if r is not exclude]
        if not pool:
            return None
        return max(pool, key=lambda r: r.admit_seq)
