"""Paged cache for fixed-size recurrent state (the software APR).

Attention KV grows with the sequence, so ``PagedKVCache`` pages it.  A
recurrent layer's state — rwkv6's wkv matrix + token-shift rows, mamba2's
SSD state + causal-conv window — is a *fixed-size register file* per
sequence: the paper's architectural pipeline register, in software.  It
cannot shrink by dropping pages (every state is a running reduction over
the whole history), so rollback needs *checkpoints*: a bounded ring of
state snapshots per slot, and ``truncate`` restores the snapshot taken at
the target token count instead of freeing a page suffix.

:class:`StateCache` is the host-side allocator for a device-side pool of
physical state slots (axis 1 of every state leaf in the paged cache).  It
mirrors the ``PagedKVCache`` contract — alloc / commit / truncate /
free_slot / defrag / pop_*_copies / refcount / stats — so the engine
drives both through the same tick choreography; hybrid (zamba2) slots hold
KV pages *and* a state slot, rolled back atomically by the engine's
``_truncate_slot``.

Physical slot ids:

* ``NULL_STATE`` (0) — pristine zero state, read-only: the gather target
  for slots that have not produced any state yet (first prefill chunk).
  Nothing may ever scatter into it.
* ``TRASH_STATE`` (1) — write sink: padded / inactive positions in a
  decode or verify tick scatter their garbage state here so the null slot
  stays zero.  Never read.
* ``2 ..`` — allocatable: one *current* id per active logical slot plus a
  snapshot ring of up to ``ring_depth`` checkpoints.

The cache never shares state between slots (a state is a lossy running
summary — there is no page boundary at which two histories coincide), so
refcounts are only ever 0 or 1; the accessor exists for contract parity
and leak checks.  Device data moves only through ``pop_state_copies()``
(truncate restores, defrag moves, explicit copy-snapshots), which the
engine drains into one jitted gather/scatter — the cache itself never
touches device memory.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

#: physical id of the pristine zero state (read-only)
NULL_STATE = 0
#: physical id of the write sink for padded positions (never read)
TRASH_STATE = 1
#: first allocatable physical id
_FIRST = 2


class OutOfStateSlots(Exception):
    """The physical state-slot pool is exhausted."""


class StateCache:
    """Host-side bookkeeping for a pool of physical recurrent-state slots.

    ``slots`` logical slots, each holding one *current* state plus a ring
    of at most ``ring_depth`` snapshots, so the pool never runs dry:
    ``num_slots = slots * (1 + ring_depth)`` allocatable ids (plus the two
    reserved ids — ``pool_slots`` is the device axis size).
    """

    def __init__(self, *, slots: int, ring_depth: int = 1):
        if slots < 1:
            raise ValueError("need at least one slot")
        if ring_depth < 1:
            raise ValueError("ring_depth must be >= 1")
        self.slots = slots
        self.ring_depth = ring_depth
        self.num_slots = slots * (1 + ring_depth)
        # pop() hands out low ids first
        self._free: List[int] = list(
            range(_FIRST + self.num_slots - 1, _FIRST - 1, -1))
        self._cur: List[int] = [NULL_STATE] * slots
        self._len: List[int] = [0] * slots
        #: per logical slot, ascending ``(token_count, physical_id)``
        self._ring: List[List[Tuple[int, int]]] = [[] for _ in range(slots)]
        self._ref: Dict[int, int] = {}
        self._pending: List[Tuple[int, int]] = []
        self.stats: Dict[str, int] = {
            "allocs": 0, "snapshots": 0, "restores": 0, "ring_evictions": 0,
        }

    # -- geometry ---------------------------------------------------------
    @property
    def pool_slots(self) -> int:
        """Device state-pool axis size (reserved ids included)."""
        return _FIRST + self.num_slots

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def used_slots(self) -> int:
        return self.num_slots - len(self._free)

    # -- per-slot accessors ----------------------------------------------
    def cur(self, slot: int) -> int:
        """Physical id of ``slot``'s current state (0 if unallocated)."""
        return self._cur[slot]

    def length(self, slot: int) -> int:
        """Committed token count reflected by the current state."""
        return self._len[slot]

    def read_id(self, slot: int) -> int:
        """Physical id a forward pass should gather ``slot``'s state from:
        the current state once any tokens are committed, else the pristine
        zero slot (a freshly-allocated physical slot holds stale data)."""
        return self._cur[slot] if self._len[slot] > 0 else NULL_STATE

    def refcount(self, sid: int) -> int:
        return self._ref.get(sid, 0)

    def snapshot_counts(self, slot: int) -> Tuple[int, ...]:
        """Token counts with a restorable checkpoint, ascending."""
        return tuple(c for c, _ in self._ring[slot])

    # -- allocation -------------------------------------------------------
    def _take(self) -> int:
        if not self._free:
            raise OutOfStateSlots(
                f"state pool exhausted ({self.num_slots} physical slots)")
        sid = self._free.pop()
        self._ref[sid] = 1
        return sid

    def _release(self, sid: int) -> None:
        self._ref[sid] -= 1
        assert self._ref[sid] == 0, "state slots are never shared"
        del self._ref[sid]
        self._free.append(sid)

    def alloc(self, slot: int) -> int:
        """Give ``slot`` a fresh current state (length 0).  The physical
        slot is *not* zeroed on device — reads before the first commit go
        to ``NULL_STATE`` instead (see :meth:`read_id`)."""
        if self._cur[slot] != NULL_STATE:
            raise ValueError(f"slot {slot} already has a state")
        sid = self._take()
        self._cur[slot] = sid
        self._len[slot] = 0
        self.stats["allocs"] += 1
        return sid

    def commit(self, slot: int, n_tokens: int) -> None:
        """The current state now reflects ``n_tokens`` committed tokens."""
        if self._cur[slot] == NULL_STATE:
            raise ValueError(f"slot {slot} has no state")
        self._len[slot] = n_tokens

    # -- snapshots --------------------------------------------------------
    def snapshot(self, slot: int, n_tokens: int = None, *,
                 copy: bool = True) -> int:
        """Checkpoint ``slot`` at ``n_tokens`` (default: current length).

        With ``copy=True`` a device copy current -> snapshot is queued (a
        plain checkpoint of state the slot already holds).  With
        ``copy=False`` the snapshot id is handed out *empty* for a forward
        pass to scatter into — the speculative verify tick allocates one
        per drafted position and writes the post-token state directly,
        so rejected tokens never touch the current state.

        The ring keeps at most ``ring_depth`` entries per slot; the oldest
        (lowest token count) is evicted first.  A snapshot at an existing
        count replaces it.
        """
        if self._cur[slot] == NULL_STATE:
            raise ValueError(f"slot {slot} has no state")
        if n_tokens is None:
            n_tokens = self._len[slot]
        # release before taking: a slot at full ring occupancy (the spec
        # engine's steady state at full acceptance) holds exactly its
        # 1 + ring_depth pool share, so the fresh id must come from an
        # eviction, not from headroom the pool does not have
        ring = self._ring[slot]
        for i, (c, old) in enumerate(ring):
            if c == n_tokens:
                self._release(old)
                del ring[i]
                break
        while len(ring) >= self.ring_depth:
            _, old = ring.pop(0)
            self._release(old)
            self.stats["ring_evictions"] += 1
        sid = self._take()
        if copy:
            self._pending.append((self._cur[slot], sid))
        ring.append((n_tokens, sid))
        ring.sort()
        self.stats["snapshots"] += 1
        return sid

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Roll ``slot`` back (or commit it forward) to ``n_tokens``.

        Unlike KV pages there is no suffix to drop: the state at
        ``n_tokens`` must exist as a ring checkpoint, and restoring queues
        a device copy snapshot -> current.  ``n_tokens == length`` is a
        no-op apart from dropping newer checkpoints (the verify tick's
        "nothing accepted" case).  Like ``PagedKVCache.truncate``,
        ``n_tokens`` may exceed the committed length when the target state
        was written ahead by a verify pass — truncate doubles as the
        commit of the accepted prefix.
        """
        if self._cur[slot] == NULL_STATE:
            raise ValueError(f"slot {slot} has no state")
        ring = self._ring[slot]
        hit = next((sid for c, sid in ring if c == n_tokens), None)
        if hit is None:
            if n_tokens == self._len[slot]:
                self._drop_after(slot, n_tokens)
                return
            raise ValueError(
                f"slot {slot}: no state checkpoint at {n_tokens} tokens "
                f"(ring holds {self.snapshot_counts(slot)}); recurrent "
                f"state cannot be truncated without a snapshot")
        self._pending.append((hit, self._cur[slot]))
        self.stats["restores"] += 1
        self._len[slot] = n_tokens
        self._drop_after(slot, n_tokens)

    def _drop_after(self, slot: int, n_tokens: int) -> None:
        ring = self._ring[slot]
        keep, drop = [], []
        for c, sid in ring:
            (keep if c <= n_tokens else drop).append((c, sid))
        for _, sid in drop:
            self._release(sid)
        self._ring[slot] = keep

    def free_slot(self, slot: int) -> None:
        """Release ``slot``'s current state and every checkpoint."""
        if self._cur[slot] != NULL_STATE:
            self._release(self._cur[slot])
            self._cur[slot] = NULL_STATE
        for _, sid in self._ring[slot]:
            self._release(sid)
        self._ring[slot] = []
        self._len[slot] = 0

    # -- device traffic ---------------------------------------------------
    def pop_state_copies(self) -> List[Tuple[int, int]]:
        """Drain queued device copies as ``(src_id, dst_id)`` pairs, in
        order.  The engine mirrors them into the device pool before the
        next forward pass reads any state."""
        out, self._pending = self._pending, []
        return out

    def defrag(self) -> List[Tuple[int, int]]:
        """Compact live physical slots to the low end of the pool; returns
        the ``(src, dst)`` moves (also queued on the pending list).  Safe
        in one pass: live ids are remapped in ascending order to ascending
        targets, so every destination is free before its source moves."""
        live = sorted(self._ref)
        mapping: Dict[int, int] = {}
        moves: List[Tuple[int, int]] = []
        for want, sid in enumerate(live, start=_FIRST):
            mapping[sid] = want
            if want != sid:
                moves.append((sid, want))
        if not moves:
            return []
        for slot in range(self.slots):
            if self._cur[slot] != NULL_STATE:
                self._cur[slot] = mapping[self._cur[slot]]
            self._ring[slot] = [(c, mapping[sid])
                                for c, sid in self._ring[slot]]
        self._ref = {mapping[sid]: n for sid, n in self._ref.items()}
        # earlier queued copies run first, at the pre-defrag layout — only
        # the moves themselves see the new ids
        self._pending.extend(moves)
        self._free = sorted(
            (sid for sid in range(_FIRST, _FIRST + self.num_slots)
             if sid not in self._ref), reverse=True)
        return moves
