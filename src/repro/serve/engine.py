"""Serving engines: paged continuous batching (production path) and the
contiguous slot engine (reference baseline).

:class:`PagedServeEngine` is the serve-side mirror of the paper's APR
residency story: KV lives in fixed-size reusable pages (``paged_cache``), a
FIFO scheduler streams prompts through token-budgeted *chunked prefill*
(``bundle.decode_paged`` with T = chunk, not the token-by-token decode
loop), decode attention touches only live pages, and a finished request's
pages flush back to the pool in one step.  Recurrent-state families
(ssm/mamba/hybrid) serve through the same engine: their fixed-size state
lives in a :class:`repro.serve.state_cache.StateCache`-managed slot pool
appended to the block table (read column + per-token write columns), and
hybrid (zamba2) slots hold KV pages *and* a state slot, rolled back
atomically by :meth:`PagedServeEngine._truncate_slot`.  :class:`ServeEngine`
keeps the seed slot engine — one contiguous ``slots x max_seq`` cache,
prefill through the decode path — as the numerics baseline the paged engine
is tested against (token-identical greedy outputs) and as the fallback for
families with no paged serving path at all (audio).

Both engines route kernel-config resolution through the tuned-config
cache; an explicit ``tune_cache`` argument is scoped to the engine
(:func:`repro.bench.config.scoped_cache` around warm-up and every
``step()``), so engines with different tuned profiles — different dtypes,
different hardware assumptions — coexist in one process.

Speculative decoding layers on top of the paged engine rather than living
here: :class:`repro.spec.SpeculativeServeEngine` subclasses
:class:`PagedServeEngine`, replacing the one-token decode tick with a
draft-and-verify step (T = spec_k + 1 through the same ``decode_paged``
contract) and rolling rejected tokens back via
:meth:`repro.serve.paged_cache.PagedKVCache.truncate`.  The spec fields on
:class:`EngineMetrics` below stay zero on the plain engines.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..bench.autotune import warm_cache
from ..bench.config import ConfigCache, scoped_cache
from ..models.registry import ModelBundle
from ..parallel.sharding import ParallelContext
from .paged_cache import OutOfPages, PagedKVCache
from .scheduler import (DECODING, DONE, PREFILLING, FifoScheduler, Request)
from .state_cache import TRASH_STATE, StateCache


@dataclasses.dataclass
class EngineMetrics:
    """Aggregate serving metrics, accumulated per tick by the engine."""
    ticks: int = 0
    requests_done: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    preemptions: int = 0
    prefill_time_s: float = 0.0   # device time inside prefill-chunk calls
    decode_time_s: float = 0.0    # device time inside decode-tick calls
    started_at: float = dataclasses.field(default_factory=time.perf_counter)
    ttfts: List[float] = dataclasses.field(default_factory=list)
    util_samples: List[float] = dataclasses.field(default_factory=list)
    # speculative decoding (repro.spec); all zero on the plain engine
    spec_steps: int = 0           # verify steps, counted per participating
                                  #   request (a batched tick adds one per
                                  #   DECODING slot it verified)
    draft_proposed: int = 0       # draft tokens proposed across all steps
    draft_accepted: int = 0       # ... accepted by the target AND emitted
    draft_time_s: float = 0.0     # time spent producing draft proposals
    # prefix cache (prefix_sharing=True); hit/cow stay zero without it
    prefix_hit_tokens: int = 0    # prompt tokens whose KV came from the
                                  #   prefix cache instead of prefill
    prefix_hit_requests: int = 0  # admissions that matched a cached prefix
    cow_copies: int = 0           # device page copies from COW splits
    prompt_pages_logical: int = 0  # sum over admissions of the pages each
                                   #   prompt would cost without sharing
    prompt_pages_unique: int = 0   # net new physical pages prefill actually
                                   #   consumed (fresh + COW - dedup)

    @property
    def elapsed(self) -> float:
        return time.perf_counter() - self.started_at

    @property
    def decode_tps(self) -> float:
        """Decode tokens per second of *decode device time* (phase-local,
        so prefill pressure and host scheduling don't dilute it)."""
        return self.decode_tokens / max(self.decode_time_s, 1e-9)

    @property
    def prefill_tps(self) -> float:
        """Prompt tokens per second of *prefill device time*."""
        return self.prefill_tokens / max(self.prefill_time_s, 1e-9)

    @property
    def mean_ttft(self) -> float:
        return float(np.mean(self.ttfts)) if self.ttfts else float("nan")

    @property
    def p50_ttft(self) -> float:
        return float(np.median(self.ttfts)) if self.ttfts else float("nan")

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the target model verified."""
        return self.draft_accepted / max(self.draft_proposed, 1)

    @property
    def tokens_per_step(self) -> float:
        """Tokens emitted per verify step (1.0 would match plain decode;
        the speculative ceiling is spec_k + 1)."""
        return self.decode_tokens / max(self.spec_steps, 1)

    @property
    def spec_decode_tps(self) -> float:
        """Decode tokens per second *including* draft time — the honest
        speculative throughput to compare against a plain engine's
        ``decode_tps`` (which has no draft phase)."""
        return self.decode_tokens / max(self.decode_time_s
                                        + self.draft_time_s, 1e-9)

    @property
    def effective_kv_multiplier(self) -> float:
        """Logical prompt pages served per physical page consumed — the
        effective-KV-capacity multiplier prefix sharing buys.  1.0 means
        no sharing benefit; N means the same pool admitted N tokens of
        prompt KV per token actually materialized."""
        return self.prompt_pages_logical / max(self.prompt_pages_unique, 1)

    @property
    def peak_page_utilization(self) -> float:
        return max(self.util_samples, default=0.0)

    @property
    def mean_page_utilization(self) -> float:
        return float(np.mean(self.util_samples)) if self.util_samples else 0.0

    def summary(self) -> Dict[str, float]:
        out = {
            "ticks": self.ticks,
            "requests_done": self.requests_done,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "preemptions": self.preemptions,
            "elapsed_s": round(self.elapsed, 4),
            "prefill_time_s": round(self.prefill_time_s, 4),
            "decode_time_s": round(self.decode_time_s, 4),
            "prefill_tps": round(self.prefill_tps, 2),
            "decode_tps": round(self.decode_tps, 2),
            "mean_ttft_s": round(self.mean_ttft, 4) if self.ttfts else None,
            "p50_ttft_s": round(self.p50_ttft, 4) if self.ttfts else None,
            "peak_page_utilization": round(self.peak_page_utilization, 4),
            "mean_page_utilization": round(self.mean_page_utilization, 4),
        }
        if self.spec_steps:  # speculative fields only when spec ran
            out.update({
                "spec_steps": self.spec_steps,
                "draft_proposed": self.draft_proposed,
                "draft_accepted": self.draft_accepted,
                "draft_time_s": round(self.draft_time_s, 4),
                "acceptance_rate": round(self.acceptance_rate, 4),
                "tokens_per_step": round(self.tokens_per_step, 4),
                "spec_decode_tps": round(self.spec_decode_tps, 2),
            })
        if self.prefix_hit_requests or self.cow_copies:
            out.update({
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "prefix_hit_requests": self.prefix_hit_requests,
                "cow_copies": self.cow_copies,
                "prompt_pages_logical": self.prompt_pages_logical,
                "prompt_pages_unique": self.prompt_pages_unique,
                "effective_kv_multiplier":
                    round(self.effective_kv_multiplier, 4),
            })
        return out


class PagedServeEngine:
    """Continuous batching over a paged KV cache with chunked prefill.

    Device state: per-layer KV page pools (``bundle.init_paged_cache``) and
    two jit'd entry points — a slot-batched decode tick (T=1) and a B=1
    prefill-chunk step (T=``prefill_chunk``) — both through
    ``bundle.decode_paged``, so prefill and decode share one cache contract.
    Host state: the page allocator (``PagedKVCache``) and the FIFO
    scheduler; see ``docs/serving.md`` for the request lifecycle and the
    scheduler invariants.

    ``kv_dtype="int8"`` stores the page pools as int8 + per-(page slot,
    head) fp32 scales (quantize-on-write, dequantize-after-gather; see
    ``docs/quantization.md``), roughly halving KV memory vs bf16; pass the
    model's int8-weight params (``bundle.quantize_params``) for the weight
    side of the same trade.

    ``prefix_sharing=True`` turns on the refcounted prefix cache: at admit
    the longest already-cached prefix of the prompt is attached read-only
    (its KV is reused, not recomputed — prefill resumes after it), pages
    completed by prefill are published for later requests, and the first
    divergent write COW-splits its shared boundary page
    (:meth:`_sync_page_copies` mirrors the split on the device pools).
    Greedy outputs are token-identical to a sharing-off engine: a cached
    page's KV is byte-identical to what prefill would have recomputed
    (same tokens, same positions, deterministic forward).  See
    ``docs/serving.md`` and ``benchmarks/bench_serve.py`` for the
    effective-KV-capacity multiplier this buys on shared-system-prompt
    traffic.

    Recurrent-state families (``supports_paged_state``) carry a
    :class:`repro.serve.state_cache.StateCache` next to the page allocator:
    every admitted request owns one physical state slot (plus a snapshot
    ring for rollback), the block table grows a state-read column and T
    per-token write columns, and the same ``decode_paged`` contract serves
    rwkv6 / mamba2 / zamba2 token-identically to the slot engine.  The KV
    allocator still ledgers every family's tokens (capacity, preemption,
    per-request caps); pure recurrent models simply never read the pages.
    ``state_dtype="int8"`` stores the large running-reduction leaves
    (wkv/ssm) int8 + per-head scales — lossy across steps, so not
    token-identity gated.  ``prefix_sharing`` is rejected for these
    families: a state is a lossy running summary, so a cached prefix cannot
    be attached mid-sequence.

    ``use_graph=True`` routes the chunked-prefill step *and* the T=1
    decode tick through the ``repro.graph`` compiler: the paged decode
    contract is traced unrolled at fixed shapes, epilogue/quant fusion
    passes run, and both steps execute through the fused graph executor
    (token-identical to the jit path, CI-gated by
    ``benchmarks/bench_graph.py``; see ``docs/graph.md``).  The hybrid
    family is rejected here: its f32 SSD update is FMA-contraction
    sensitive at cluster boundaries, so graph execution cannot guarantee
    token identity (see ``compile_decode_step``).  The fusion clustering
    is chosen by the ``repro.cost`` model (``graph_cost_model=False`` or
    ``$REPRO_COST_MODEL=off`` reverts to the fixed pipeline) and the
    chosen schedule persists in this engine's ``tune_cache`` next to the
    tuned kernel tiles; :meth:`graph_schedule_report` prints the audit.
    """

    def __init__(self, bundle: ModelBundle, params, pctx: ParallelContext,
                 *, slots: int = 4, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 max_pages_per_slot: Optional[int] = None,
                 prefill_chunk: int = 16,
                 prefill_budget: Optional[int] = None,
                 kv_dtype: str = "bfloat16",
                 state_dtype: str = "float32",
                 prefix_sharing: bool = False,
                 use_graph: bool = False,
                 graph_impl: Optional[str] = None,
                 graph_cost_model: Optional[bool] = None,
                 tune_cache: Optional[str] = None,
                 autotune_at_start: bool = False):
        if not bundle.supports_paged_serving:
            raise ValueError(
                f"{bundle.cfg.family!r} family has no paged KV cache or "
                "state pool; use the contiguous ServeEngine")
        if kv_dtype not in ("bfloat16", "float32", "int8"):
            raise ValueError(f"unsupported kv_dtype {kv_dtype!r}")
        if state_dtype not in ("float32", "int8"):
            raise ValueError(f"unsupported state_dtype {state_dtype!r}")
        if prefix_sharing and bundle.supports_paged_state:
            raise ValueError(
                "prefix_sharing=True is unsupported for recurrent-state "
                "families: a state slot is a lossy running summary of its "
                "whole history, so a cached prefix's KV pages cannot be "
                "attached mid-sequence (there is no state to resume from)")
        if use_graph and bundle.cfg.family == "hybrid":
            raise ValueError(
                "use_graph=True is unsupported for the hybrid family: "
                "cluster boundaries are compilation boundaries, and the "
                "interleaved f32 SSD update + bf16 attention is sensitive "
                "to cross-op FMA contraction — a 1-ulp f32 shift at a "
                "cluster cut can cross a bf16 rounding boundary and flip "
                "a greedy token, breaking the token-identity invariant; "
                "serve hybrids on the plain paged engine")
        # Tensor-parallel mode: a mesh with a >1 TP axis shards attention
        # heads / MLP blocks / KV page pools across its devices; everything
        # host-side (allocator, scheduler, prefix cache, block tables) is
        # unchanged — one engine drives N devices.  See repro.parallel.tp
        # and docs/parallel.md.
        self.tp_plan = None
        mesh = pctx.mesh
        if (mesh is not None and pctx.tp_axis in mesh.axis_names
                and mesh.shape[pctx.tp_axis] > 1):
            if bundle.supports_paged_state:
                raise ValueError(
                    "recurrent-state families have no TP plan: state pools "
                    "are per-sequence registers, not head-sharded tensors; "
                    "serve ssm/mamba/hybrid without a TP mesh")
            if use_graph:
                raise ValueError(
                    "use_graph=True is incompatible with a TP mesh: the "
                    "graph executor is a host-side op loop and cannot run "
                    "inside the manual shard_map region (use the jit "
                    "prefill path on meshes)")
            from ..parallel import tp as _tp
            self._tp = _tp
            self.tp_plan = _tp.plan_tp(bundle.cfg,
                                       int(mesh.shape[pctx.tp_axis]))
        self.bundle = bundle
        self.params = params
        self.pctx = pctx
        self.slots = slots
        self.page_size = page_size
        if num_pages is None:
            num_pages = slots * max(256 // page_size, 1)
        if max_pages_per_slot is None:
            # Bound the block-table width (and with it the logical span every
            # decode/prefill gather attends over) to a 256-token per-request
            # default rather than the whole pool — the attention cost of a
            # tick scales with slots x max_pages_per_slot x page_size.
            max_pages_per_slot = min(num_pages, max(256 // page_size, 1))
        self.kv = PagedKVCache(slots=slots, num_pages=num_pages,
                               page_size=page_size,
                               max_pages_per_slot=max_pages_per_slot,
                               enable_sharing=prefix_sharing)
        self.prefix_sharing = prefix_sharing
        self.sched = FifoScheduler(prefill_chunk=prefill_chunk,
                                   prefill_budget=prefill_budget)
        self.prefill_chunk = prefill_chunk
        self.kv_dtype = kv_dtype
        self.state_dtype = state_dtype
        self.use_graph = use_graph
        # Recurrent-state slot pool: one current state per engine slot plus
        # a snapshot ring (depth spec_k+1 on the speculative engine, whose
        # subclass sets ``spec_k`` before calling up) for truncate rollback.
        self.state: Optional[StateCache] = (
            StateCache(slots=slots, ring_depth=getattr(self, "spec_k", 0) + 1)
            if bundle.supports_paged_state else None)
        # Tuned-kernel plumbing: an explicit ``tune_cache`` is scoped to
        # THIS engine (warm-up + every step()); other engines and bare
        # kernel calls keep their own resolution.  See scoped_cache.
        self.tune_cache = (ConfigCache(tune_cache)
                          if tune_cache is not None else None)
        with scoped_cache(self.tune_cache):
            self.tuned_configs = warm_cache(
                self._decode_kernel_shapes(), sweep=autotune_at_start)
        self.cache = bundle.init_paged_cache(
            self.kv.pool_pages, page_size, kv_dtype=kv_dtype,
            state_slots=(self.state.pool_slots if self.state else 0),
            state_dtype=state_dtype)
        self.active: List[Optional[Request]] = [None] * slots
        self.last_tokens = np.zeros((slots,), np.int64)
        self.metrics = EngineMetrics()
        copy_fn = lambda c, s, d: jax.tree.map(
            lambda a: a.at[:, :, d].set(a[:, :, s]), c)
        if self.tp_plan is not None:
            # Shard the device state: params per logical axes (heads/ff/
            # vocab over the TP axis), KV pools over their kv-head axis.
            # One global cache keeps the page/block-table indexing shared;
            # each device physically holds only its heads' slice.
            pspecs = self._tp.tp_param_specs(params, bundle.logical_axes(),
                                             self.tp_plan, pctx.tp_axis)
            cspecs = self._tp.tp_cache_specs(self.cache, self.tp_plan,
                                             pctx.tp_axis)
            self.params = self._tp.shard_tree(params, mesh, pspecs)
            self.cache = self._tp.shard_tree(self.cache, mesh, cspecs)
            self._decode = jax.jit(self._tp.make_tp_decode_paged(
                bundle, pctx, self.tp_plan, pspecs, cspecs))
            # pin the copy output to the pool sharding so COW/defrag moves
            # never silently gather a pool onto every device
            from jax.sharding import NamedSharding
            cache_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), cspecs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            self._copy_page = jax.jit(copy_fn, out_shardings=cache_sh)
        else:
            self._decode = jax.jit(
                lambda p, c, t, l, n, bt: bundle.decode_paged(p, c, t, l, n, bt, pctx))
            if self.state is not None:
                # Key-aware device copies: a mixed cache holds KV page
                # pools (page axis 2) AND state pools (slot axis 1), so
                # page moves and state moves each touch only their leaves
                # (repro.models.paged_state).
                from ..models.paged_state import copy_kv_page, copy_state_slot
                self._copy_page = jax.jit(copy_kv_page)
                self._copy_state = jax.jit(copy_state_slot)
            else:
                # Page-granular device copy for COW splits and defrag
                # moves: every cache leaf — K/V pools and any int8 scale
                # pools — has the page axis at position 2 (n_sb, me,
                # pages, ...), so one tree.map moves a page across all
                # layers and pools at once.  src/dst are traced scalars:
                # one compilation serves every copy.
                self._copy_page = jax.jit(copy_fn)
        if use_graph:
            # Graph-compiled chunked prefill AND decode tick: each traced
            # once at the engine's fixed shapes — (B=1, T=chunk) and
            # (B=slots, T=1) — fused, executed cluster-at-a-time with a
            # compile cache (repro.graph.compiler).  ``graph_impl=None``
            # auto-selects: "pallas" on TPU (epilogue clusters dispatch to
            # the fused kernel variants), "xla" elsewhere.
            from ..graph.compiler import (compile_decode_step,
                                          compile_prefill_step)
            # Scope the compile under this engine's tune cache so the
            # cost model's whole-graph schedules persist next to the tuned
            # kernel tiles — a restarted engine replays its clustering from
            # the cache by graph signature instead of re-deriving it
            # (repro.cost.schedule).
            with scoped_cache(self.tune_cache):
                self._prefill = compile_prefill_step(
                    bundle, params, self.cache, chunk=prefill_chunk,
                    table_width=self._table_width(prefill_chunk), pctx=pctx,
                    impl=graph_impl, cost_model=graph_cost_model)
                self._decode_step = compile_decode_step(
                    bundle, params, self.cache, slots=slots,
                    table_width=self._table_width(1), pctx=pctx,
                    impl=graph_impl, cost_model=graph_cost_model)
        else:
            # same jit fn for all three entry points; shapes differ
            # (prefill: B=1 T=chunk; decode tick: B=slots T=1)
            self._prefill = self._decode
            self._decode_step = self._decode

    def _table_width(self, t: int) -> int:
        """Combined block-table width for a forward over T=``t`` positions:
        the KV page columns (always present — the allocator ledgers every
        family's tokens) plus, on state engines, one state-read column and
        ``t`` per-token state-write columns (repro.models.paged_state)."""
        width = self.kv.max_pages_per_slot
        if self.state is not None:
            width += 1 + t
        return width

    def _tables(self, rows, write_ids=None) -> np.ndarray:
        """Block-table rows for a forward call over engine slots ``rows``:
        the KV page table, extended on state engines with each row's state
        read id and the caller-built ``(len(rows), T)`` write-id columns
        (``TRASH_STATE`` for padded/inactive positions)."""
        kv_rows = self.kv.block_tables[list(rows)]
        if self.state is None:
            return kv_rows
        reads = np.array([[self.state.read_id(s)] for s in rows], np.int32)
        return np.concatenate(
            [kv_rows, reads, np.asarray(write_ids, np.int32)], axis=1)

    def _decode_kernel_shapes(self):
        """Kernel shapes the paged decode path exercises on real hardware:
        paged decode attention over the slot batch (attention families
        only) and the slot-batch GEMM.  An int8-KV engine tunes the
        ``_kvint8`` variant of the paged family — the key the int8
        gather-dequant kernel actually resolves.  On a TP mesh the
        per-shard (local) geometry is what each device runs."""
        cfg = (self.tp_plan.local_cfg if self.tp_plan is not None
               else self.bundle.cfg)
        shapes = []
        if not cfg.is_attention_free:
            attn_shape = {"b": self.slots, "hq": cfg.num_heads,
                          "hkv": cfg.num_kv_heads,
                          "d": cfg.resolved_head_dim,
                          "pages": self.kv.max_pages_per_slot,
                          "ps": self.page_size}
            if self.kv_dtype == "int8":
                attn_shape["kv_int8"] = 1
            shapes.append(("flash_decode_paged", attn_shape))
        shapes.append(("apr_matmul", {"m": self.slots, "k": cfg.d_model,
                                      "n": cfg.d_ff or cfg.d_inner}))
        return shapes

    def graph_schedule_report(self) -> str:
        """Human-readable cost-model schedule report for the graph-compiled
        steps (``launch.serve --explain``): one
        :meth:`~repro.cost.ScheduleDecision.report` block per compiled step.
        Empty when ``use_graph=False`` or the cost model was off."""
        blocks = []
        for label, step in (("prefill", self._prefill),
                            ("decode", self._decode_step)):
            ex = getattr(step, "executor", None)
            decision = getattr(ex, "schedule", None)
            if decision is not None:
                blocks.append(f"[{label}] {decision.report()}")
        return "\n".join(blocks)

    def kv_pool_bytes(self) -> int:
        """*Logical* bytes held by the device cache pools — KV pages,
        recurrent-state pools, and any int8 scale pools — the footprint
        ``kv_dtype="int8"`` / ``state_dtype="int8"`` shrink.
        On a TP mesh this is the global pool; see
        :meth:`kv_pool_bytes_per_device` for what one device holds."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in jax.tree.leaves(self.cache))

    def kv_pool_bytes_per_device(self) -> int:
        """Physical KV-pool bytes on the busiest device: ~global/N on a TP
        mesh with sharded KV heads (the BENCH_parallel gate), equal to
        :meth:`kv_pool_bytes` on one device or with replicated KV."""
        from ..parallel.tp import per_device_bytes
        return per_device_bytes(self.cache)

    def weight_bytes_per_device(self) -> int:
        """Physical parameter bytes on the busiest device."""
        from ..parallel.tp import per_device_bytes
        return per_device_bytes(self.params)

    # -- public API -------------------------------------------------------
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError(
                f"request {req.rid}: empty prompt (generation needs at "
                "least one conditioning token, e.g. a BOS id)")
        need = len(req.prompt) + req.max_new_tokens
        cap = min(self.kv.max_tokens_per_slot(),
                  self.kv.num_pages * self.page_size)
        if need > cap:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {need} tokens exceeds "
                f"per-request capacity {cap} (pages are exhausted even with "
                "every other request preempted)")
        self.sched.submit(req)

    def step(self) -> int:
        """One engine tick: admit, chunked prefill (token-budgeted), one
        batched decode for all DECODING slots.  Returns active requests.
        The whole tick runs under this engine's tuned-config scope, so a
        subclass tick phase (speculative verify) resolves through it too."""
        with scoped_cache(self.tune_cache):
            self._admit()
            self._prefill_tick()
            self._decode_tick()
        self.metrics.ticks += 1
        self.metrics.util_samples.append(self.kv.utilization())
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_ticks: int = 10_000) -> EngineMetrics:
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and not self.sched.waiting:
                break
        return self.metrics

    # -- tick phases ------------------------------------------------------
    def _active_requests(self) -> List[Request]:
        return [r for r in self.active if r is not None]

    def _admit(self) -> None:
        # Gate on available pages (free + lazily-evictable prefix cache) so
        # a freshly-preempted request is not bounced straight back into the
        # pool that just evicted it.
        if self.kv.available_pages == 0:
            return
        free = [i for i, r in enumerate(self.active) if r is None]
        for slot, req in self.sched.admit(free):
            self.active[slot] = req
            toks = req.prefill_tokens()
            self.metrics.prompt_pages_logical += self.kv.pages_for(len(toks))
            if self.prefix_sharing:
                # Attach the longest cached prefix; prefill resumes after
                # it (the matched tokens' KV is reused, not recomputed).
                matched = self.kv.match_prefix(slot, toks)
                if matched:
                    req.prefill_pos = matched
                    self.metrics.prefix_hit_tokens += matched
                    self.metrics.prefix_hit_requests += 1
            if self.state is not None:
                self.state.alloc(slot)
            self._on_admit(slot, req)

    def _on_admit(self, slot: int, req: Request) -> None:
        """Placement hook for subclasses (the speculative engine notifies
        its draft proposer here); the base engine needs nothing."""

    def _preempt(self, req: Request) -> None:
        self.kv.free_slot(req.slot)
        if self.state is not None:
            self.state.free_slot(req.slot)
        self.active[req.slot] = None
        self.sched.requeue_preempted(req)
        self.metrics.preemptions += 1

    def _ensure_pages(self, req: Request, n_tokens: int) -> bool:
        """Grow ``req``'s slot to hold ``n_tokens``, preempting the youngest
        active request (possibly ``req`` itself) until the pool covers it.
        Returns False if ``req`` was preempted or nothing could be freed."""
        while True:
            try:
                self.kv.allocate(req.slot, n_tokens)
                return True
            except OutOfPages:
                victim = self.sched.preemption_victim(self._active_requests())
                if victim is None:
                    return False
                self._preempt(victim)
                if victim is req:
                    return False

    def _sync_page_copies(self) -> None:
        """Mirror queued COW page splits onto the device pools.  Must run
        after any host-side ``allocate`` and before the next forward — the
        forward's writes land in the slot's *new* private page, which needs
        the shared page's prefix content under the write offset."""
        for src, dst in self.kv.pop_page_copies():
            self.cache = self._copy_page(self.cache, jnp.int32(src),
                                         jnp.int32(dst))
            self.metrics.cow_copies += 1

    def _sync_state_copies(self) -> None:
        """Mirror queued state-slot copies (truncate restores, snapshot
        materialisations, defrag moves) onto the device state pools, in
        queue order — StateCache sequences them so earlier copies always
        see the layout they were queued against."""
        if self.state is None:
            return
        for src, dst in self.state.pop_state_copies():
            self.cache = self._copy_state(self.cache, jnp.int32(src),
                                          jnp.int32(dst))

    def _truncate_slot(self, slot: int, n_tokens: int) -> None:
        """Roll one engine slot back (or commit it forward) to ``n_tokens``
        atomically across both residency domains: the KV page suffix is
        dropped AND the paired recurrent state is restored from its ring
        checkpoint before any later forward can read either."""
        self.kv.truncate(slot, n_tokens)
        if self.state is not None:
            self.state.truncate(slot, n_tokens)
            self._sync_state_copies()

    def defrag(self) -> int:
        """Compact the page pool — and on state engines the state-slot
        pool — host tables + device pools in lockstep, preserving prefix
        sharing; returns the total number of device moves."""
        moves = self.kv.defrag()
        for src, dst in moves:
            self.cache = self._copy_page(self.cache, jnp.int32(src),
                                         jnp.int32(dst))
        n = len(moves)
        if self.state is not None:
            n += len(self.state.defrag())
            self._sync_state_copies()
        return n

    def _net_unique_pages(self) -> int:
        """Physical prompt pages consumed so far, net of sharing: fresh
        allocations plus COW splits, minus pages retired by retro-dedup."""
        s = self.kv.stats
        return s["fresh_pages"] + s["cow_splits"] - s["dedup_reclaimed"]

    def _prefill_tick(self) -> None:
        prefilling = [r for r in self._active_requests()
                      if r.state == PREFILLING]
        unique0 = self._net_unique_pages()
        for req, n in self.sched.prefill_plan(prefilling):
            if self.active[req.slot] is not req:
                continue  # preempted earlier this tick by a sibling's alloc
            toks_all = req.prefill_tokens()
            if not self._ensure_pages(req, req.prefill_pos + n):
                continue
            self._sync_page_copies()
            chunk = toks_all[req.prefill_pos:req.prefill_pos + n]
            padded = chunk + [0] * (self.prefill_chunk - n)
            if self.state is not None:
                # only the chunk's last real token needs its state kept —
                # the forward carries state across tokens in registers, so
                # intermediate (and padded) positions write to the sink
                write_ids = np.full((1, self.prefill_chunk), TRASH_STATE,
                                    np.int32)
                write_ids[0, n - 1] = self.state.cur(req.slot)
            else:
                write_ids = None
            t0 = time.perf_counter()
            logits, self.cache = self._prefill(
                self.params, self.cache,
                jnp.asarray([padded], jnp.int32),
                jnp.asarray([req.prefill_pos], jnp.int32),
                jnp.asarray([n], jnp.int32),
                jnp.asarray(self._tables([req.slot], write_ids)))
            jax.block_until_ready(logits)
            self.metrics.prefill_time_s += time.perf_counter() - t0
            req.prefill_pos += n
            self.kv.commit(req.slot, req.prefill_pos)
            if self.state is not None:
                self.state.commit(req.slot, req.prefill_pos)
            if self.prefix_sharing:
                # publish completed pages so siblings (and later waves)
                # can share them; identical pages prefix-filled in parallel
                # retro-dedup onto one physical copy here
                self.kv.register_prefix(req.slot, toks_all)
            self.metrics.prefill_tokens += n
            if req.prefill_pos == len(toks_all):
                # prompt (+ recompute suffix) fully cached: the last real
                # row of this chunk's logits is the next-token distribution
                nxt = int(jnp.argmax(logits[0, n - 1]))
                if not req.first_token_at:
                    req.first_token_at = time.perf_counter()
                    self.metrics.ttfts.append(req.ttft)
                req.output.append(nxt)
                self.last_tokens[req.slot] = nxt
                req.state = DECODING
                self._maybe_finish(req, nxt)
        self.metrics.prompt_pages_unique += (self._net_unique_pages()
                                             - unique0)

    def _decode_tick(self) -> None:
        # oldest first, so page pressure evicts the youngest (LIFO) and the
        # head of the FIFO line always makes progress
        decoding = sorted(
            (r for r in self._active_requests() if r.state == DECODING),
            key=lambda r: r.admit_seq)
        for req in decoding:
            self._ensure_pages(req, self.kv.length(req.slot) + 1)
        self._sync_page_copies()
        decoding = [r for r in self._active_requests() if r.state == DECODING]
        if not decoding:
            return
        lengths = np.array([self.kv.length(s) for s in range(self.slots)],
                           np.int32)
        counts = np.zeros((self.slots,), np.int32)
        for r in decoding:
            counts[r.slot] = 1
        if self.state is not None:
            write_ids = np.full((self.slots, 1), TRASH_STATE, np.int32)
            for r in decoding:
                write_ids[r.slot, 0] = self.state.cur(r.slot)
        else:
            write_ids = None
        t0 = time.perf_counter()
        logits, self.cache = self._decode_step(
            self.params, self.cache,
            jnp.asarray(self.last_tokens[:, None], jnp.int32),
            jnp.asarray(lengths), jnp.asarray(counts),
            jnp.asarray(self._tables(range(self.slots), write_ids)))
        jax.block_until_ready(logits)
        self.metrics.decode_time_s += time.perf_counter() - t0
        next_tokens = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for req in decoding:
            self.kv.commit(req.slot, self.kv.length(req.slot) + 1)
            if self.state is not None:
                self.state.commit(req.slot, self.kv.length(req.slot))
            tok = int(next_tokens[req.slot])
            req.output.append(tok)
            self.last_tokens[req.slot] = tok
            self.metrics.decode_tokens += 1
            self._maybe_finish(req, tok)

    def _maybe_finish(self, req: Request, tok: int) -> None:
        if (req.eos_id is not None and tok == req.eos_id) or \
           len(req.output) >= req.max_new_tokens:
            self._finish(req)

    def _finish(self, req: Request) -> None:
        # allocator-level rfsmac.s: the request's accumulated KV working set
        # (and its state slot + checkpoints) flushes back to the pool in
        # one step
        self.kv.free_slot(req.slot)
        if self.state is not None:
            self.state.free_slot(req.slot)
        self.active[req.slot] = None
        req.state = DONE
        req.done = True
        req.finished_at = time.perf_counter()
        self.metrics.requests_done += 1


class ServeEngine:
    """Contiguous slot engine (seed baseline): one ``slots x max_seq`` KV
    cache, prefill-on-admit *through the decode path* token by token.

    Kept as (a) the numerics reference the paged engine must match
    token-for-token, and (b) the serving path for model families whose
    decode state is not a growing KV sequence (ssm/hybrid/audio).  For
    dense/moe/vlm traffic use :class:`PagedServeEngine`.
    """

    def __init__(self, bundle: ModelBundle, params, pctx: ParallelContext,
                 *, slots: int = 4, max_seq: int = 256,
                 tune_cache: Optional[str] = None,
                 autotune_at_start: bool = False):
        self.bundle = bundle
        self.params = params
        self.pctx = pctx
        self.slots = slots
        self.max_seq = max_seq
        # Tuned-kernel plumbing (repro.bench): an explicit ``tune_cache``
        # is scoped to THIS engine — warm-up here plus every step() — via
        # :func:`repro.bench.config.scoped_cache`, so two engines with
        # different tuned profiles (e.g. different dtypes) coexist; see
        # tests/test_autotune.py::test_two_engine_tune_caches_coexist.
        # ``autotune_at_start=True`` additionally sweeps any shape missing
        # from the cache (slow; meant for a one-off warm-up run).
        self.tune_cache = (ConfigCache(tune_cache)
                          if tune_cache is not None else None)
        with scoped_cache(self.tune_cache):
            self.tuned_configs = warm_cache(
                self._decode_kernel_shapes(), sweep=autotune_at_start)
        self.cache = bundle.init_cache(slots, max_seq)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.pending: "queue.Queue[Request]" = queue.Queue()
        self._decode = jax.jit(
            lambda p, c, t, l: bundle.decode_step(p, c, t, l, pctx)
        )
        self.last_tokens = jnp.zeros((slots, 1), jnp.int32)

    def _decode_kernel_shapes(self):
        """Kernel shapes this engine's decode path exercises: batched decode
        attention over the full slot batch, and the slot-batch x d_ff GEMM."""
        cfg = self.bundle.cfg
        return [
            ("flash_decode", {"b": self.slots, "hq": cfg.num_heads,
                              "hkv": cfg.num_kv_heads,
                              "d": cfg.resolved_head_dim,
                              "s": self.max_seq}),
            ("apr_matmul", {"m": self.slots, "k": cfg.d_model,
                            "n": cfg.d_ff}),
        ]

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(
                f"request {req.rid}: empty prompt (generation needs at "
                "least one conditioning token, e.g. a BOS id)")
        self.pending.put(req)

    def _admit(self):
        stateful = self.bundle.cfg.family in ("ssm", "mamba", "hybrid")
        for slot in range(self.slots):
            if self.active[slot] is not None or self.pending.empty():
                continue
            req = self.pending.get()
            # prefill by decoding the prompt token-by-token into this slot
            # (keeps cache layouts identical; PagedServeEngine runs chunked
            # prefill over the paged cache contract instead).  Reset the
            # slot's length first: a reused slot must not attend over the
            # previous request's KV (stale entries beyond the new length are
            # masked, and get overwritten as the new request grows).
            if stateful:
                # Recurrent state is a running summary, not masked by
                # lengths: (a) a reused slot must start from the zero
                # state, and (b) the full-batch prompt decode below
                # advances EVERY row's recurrence, so the other slots'
                # rows are pinned across the loop (batch rows are
                # independent in decode, so restoring them once at the
                # end is exact).  Every state leaf has batch axis 1.
                keep = self.cache
                self.cache = jax.tree.map(
                    lambda a: a.at[:, slot].set(
                        jnp.zeros_like(a[:, slot])), self.cache)
            lengths = self.lengths.at[slot].set(0)
            for tok in req.prompt:
                toks = self.last_tokens.at[slot, 0].set(tok)
                logits, self.cache = self._decode(
                    self.params, self.cache, toks, lengths)
                lengths = lengths.at[slot].add(1)
            if stateful:
                self.cache = jax.tree.map(
                    lambda k, n: k.at[:, slot].set(n[:, slot]),
                    keep, self.cache)
            self.lengths = lengths
            nxt = int(jnp.argmax(logits[slot, -1]))
            if not req.first_token_at:
                req.first_token_at = time.perf_counter()
            req.output.append(nxt)
            self.last_tokens = self.last_tokens.at[slot, 0].set(nxt)
            self.active[slot] = req

    def step(self) -> int:
        """One engine tick: admit new requests, one decode for all active
        slots.  Returns number of active requests."""
        with scoped_cache(self.tune_cache):
            return self._step_inner()

    def _step_inner(self) -> int:
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_tokens, self.lengths)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1)
        new_last = self.last_tokens
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.lengths = self.lengths.at[slot].add(1)
            tok = int(next_tokens[slot])
            req.output.append(tok)
            new_last = new_last.at[slot, 0].set(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
               len(req.output) >= req.max_new_tokens or \
               int(self.lengths[slot]) >= self.max_seq - 1:
                req.done = True
                req.finished_at = time.perf_counter()
                self.active[slot] = None
        self.last_tokens = new_last
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and self.pending.empty():
                return
