"""Batched serving engine: slot-based continuous batching over a fixed
decode batch, prefill-on-admit, per-slot lengths — the serve-side driver
behind examples/serve_lm.py and the decode shape cells.

The decode hot loop is one jit'd ``decode_step`` over the whole slot batch;
admission runs prefill for the new request and scatters its KV into the
batch cache (host-side orchestration, device-side compute).
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..bench.autotune import warm_cache
from ..bench.config import ConfigCache, set_default_cache
from ..configs.base import ModelConfig
from ..models.registry import ModelBundle
from ..parallel.sharding import ParallelContext


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, bundle: ModelBundle, params, pctx: ParallelContext,
                 *, slots: int = 4, max_seq: int = 256,
                 tune_cache: Optional[str] = None,
                 autotune_at_start: bool = False):
        self.bundle = bundle
        self.params = params
        self.pctx = pctx
        self.slots = slots
        self.max_seq = max_seq
        # Tuned-kernel plumbing (repro.bench): point the PROCESS-WIDE config
        # cache at the given file (this redirects config resolution for every
        # kernel call in the process, not just this engine — last engine
        # constructed with an explicit ``tune_cache`` wins), then resolve the
        # block configs for this engine's decode-shape kernels up front so
        # the first jit trace of decode_step already uses tuned tiles.
        # ``autotune_at_start=True`` additionally sweeps any shape missing
        # from the cache (slow; meant for a one-off warm-up run, not for
        # every engine start).
        if tune_cache is not None:
            set_default_cache(ConfigCache(tune_cache))
        self.tuned_configs = warm_cache(
            self._decode_kernel_shapes(), sweep=autotune_at_start)
        self.cache = bundle.init_cache(slots, max_seq)
        self.lengths = jnp.zeros((slots,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.pending: "queue.Queue[Request]" = queue.Queue()
        self._decode = jax.jit(
            lambda p, c, t, l: bundle.decode_step(p, c, t, l, pctx)
        )
        self.last_tokens = jnp.zeros((slots, 1), jnp.int32)

    def _decode_kernel_shapes(self):
        """Kernel shapes this engine's decode path exercises: batched decode
        attention over the full slot batch, and the slot-batch x d_ff GEMM."""
        cfg = self.bundle.cfg
        return [
            ("flash_decode", {"b": self.slots, "hq": cfg.num_heads,
                              "hkv": cfg.num_kv_heads,
                              "d": cfg.resolved_head_dim,
                              "s": self.max_seq}),
            ("apr_matmul", {"m": self.slots, "k": cfg.d_model,
                            "n": cfg.d_ff}),
        ]

    def submit(self, req: Request):
        self.pending.put(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None or self.pending.empty():
                continue
            req = self.pending.get()
            # prefill by decoding the prompt token-by-token into this slot
            # (keeps cache layouts identical; a production engine runs the
            # chunked prefill kernel and scatters — same cache contract).
            lengths = self.lengths
            for tok in req.prompt:
                toks = self.last_tokens.at[slot, 0].set(tok)
                logits, self.cache = self._decode(
                    self.params, self.cache, toks, lengths)
                lengths = lengths.at[slot].add(1)
            self.lengths = lengths
            nxt = int(jnp.argmax(logits[slot, -1]))
            req.output.append(nxt)
            self.last_tokens = self.last_tokens.at[slot, 0].set(nxt)
            self.active[slot] = req

    def step(self) -> int:
        """One engine tick: admit new requests, one decode for all active
        slots.  Returns number of active requests."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        logits, self.cache = self._decode(
            self.params, self.cache, self.last_tokens, self.lengths)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1)
        new_last = self.last_tokens
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.lengths = self.lengths.at[slot].add(1)
            tok = int(next_tokens[slot])
            req.output.append(tok)
            new_last = new_last.at[slot, 0].set(tok)
            limit = len(req.prompt) + req.max_new_tokens
            if (req.eos_id is not None and tok == req.eos_id) or \
               len(req.output) >= req.max_new_tokens or \
               int(self.lengths[slot]) >= self.max_seq - 1:
                req.done = True
                self.active[slot] = None
        self.last_tokens = new_last
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and self.pending.empty():
                return
