from .engine import EngineMetrics, PagedServeEngine, ServeEngine  # noqa: F401
from .paged_cache import OutOfPages, PagedKVCache  # noqa: F401
from .scheduler import FifoScheduler, Request  # noqa: F401
