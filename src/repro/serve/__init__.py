from .engine import EngineMetrics, PagedServeEngine, ServeEngine  # noqa: F401
from .paged_cache import OutOfPages, PagedKVCache  # noqa: F401
from .scheduler import (SLO_THROUGHPUT, SLO_TTFT,  # noqa: F401
                        FifoScheduler, Request)
from .server import AsyncServeFrontend, TokenStream  # noqa: F401
from .state_cache import (NULL_STATE, TRASH_STATE,  # noqa: F401
                          OutOfStateSlots, StateCache)
