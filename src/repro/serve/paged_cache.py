"""Paged KV cache: fixed-size pages, per-slot block tables, alloc/free/defrag.

The serving analogue of the paper's APR residency story: the APR keeps a
running reduction resident near the ALU so the memory system sees one write
per output element; a paged KV cache keeps the *decode working set* resident
in fixed-size, reusable pages so decode attention touches only live pages —
no slot ever holds ``max_seq`` worth of zeros it will never fill.  Freeing a
request's pages on completion is the allocator-level ``rfsmac.s``: the
accumulated state is flushed (sampled tokens already emitted) and the
storage returns to the pool in one step.

This module is the *host-side* allocator: pure python/numpy bookkeeping
(free list, block tables, per-slot lengths).  The device-side page pools —
``(n_sb, me, num_pages, page_size, hkv, dh)`` arrays — are owned by the
engine (`repro.serve.engine.PagedServeEngine`) and by the model's paged
decode path (`repro.models.lm.lm_decode_paged`); the allocator only decides
*which* page indices they use.

Layout invariants
-----------------
* Page ``0`` is the reserved **null page**: never allocated, used as the
  scatter target for padded prefill positions and idle slots, and as the
  block-table filler for unallocated entries.  Garbage written there is
  never read back (attention masks by length before any null-page position
  becomes visible).
* ``block_tables[slot, i]`` holds the physical page backing logical tokens
  ``[i * page_size, (i+1) * page_size)`` of that slot.  The same logical ->
  physical mapping is shared by every layer (each layer has its own storage
  at the same page index), so one int32 table drives the whole model.
* A slot owning ``n`` tokens owns exactly ``ceil(n / page_size)`` pages.
* **int8 storage** (``kv_dtype="int8"`` on the engine / model cache): the
  device pools hold int8 payloads plus fp32 scale pools of shape
  ``(..., num_pages + 1, page_size, hkv)`` — one symmetric scale per (page
  slot, kv head), written together with its payload so a slot is always
  self-consistent and rewrites stay idempotent.  Nothing here changes: the
  allocator tracks *pages*, not bytes, and the same block tables drive the
  int8 pools and their scale pools.  See ``docs/quantization.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

NULL_PAGE = 0

_KV_ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1}


def kv_token_bytes(hkv: int, head_dim: int, kv_dtype: str = "bfloat16") -> int:
    """Analytic KV-cache bytes one token costs per layer tensor (K or V):
    payload at ``kv_dtype`` width plus, for int8, the per-(token, head)
    fp32 scale.  Used by the quantization benchmark's bandwidth model."""
    payload = hkv * head_dim * _KV_ITEMSIZE[kv_dtype]
    scales = hkv * 4 if kv_dtype == "int8" else 0
    return payload + scales


class OutOfPages(Exception):
    """Raised by ``allocate`` when the pool cannot cover a reservation."""


@dataclasses.dataclass
class PageTableView:
    """Immutable snapshot handed to device code / tests."""
    block_tables: np.ndarray      # (slots, max_pages_per_slot) int32
    lengths: np.ndarray           # (slots,) int32 tokens stored per slot


class PagedKVCache:
    """Fixed-size-page allocator with per-slot block tables.

    ``num_pages`` counts *usable* pages; one extra null page is always
    appended at index 0, so device pools must be sized ``num_pages + 1``
    (see :attr:`pool_pages`).
    """

    def __init__(self, *, slots: int, num_pages: int, page_size: int,
                 max_pages_per_slot: Optional[int] = None):
        if page_size <= 0 or num_pages <= 0 or slots <= 0:
            raise ValueError("slots, num_pages, page_size must be positive")
        self.slots = slots
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot or num_pages
        # physical ids 1..num_pages are allocatable; 0 is the null page
        self._free: List[int] = list(range(num_pages, 0, -1))  # pop() -> 1 first
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._lengths = np.zeros((slots,), np.int32)
        self.block_tables = np.zeros((slots, self.max_pages_per_slot), np.int32)

    # -- capacity queries -------------------------------------------------
    @property
    def pool_pages(self) -> int:
        """Physical pages device pools must allocate (incl. null page)."""
        return self.num_pages + 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    def max_tokens_per_slot(self) -> int:
        return self.max_pages_per_slot * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_grow(self, slot: int, n_tokens: int) -> bool:
        """Could ``slot`` hold ``n_tokens`` total without preempting anyone?"""
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_slot:
            return False
        return need - len(self._owned[slot]) <= len(self._free)

    # -- alloc / free -----------------------------------------------------
    def allocate(self, slot: int, n_tokens: int) -> List[int]:
        """Grow ``slot`` so it can store ``n_tokens`` tokens total.

        Returns the newly assigned page ids (possibly empty).  Raises
        :class:`OutOfPages` without side effects if the pool cannot cover
        the growth, so callers can preempt and retry.
        """
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_slot:
            raise OutOfPages(
                f"slot {slot}: {n_tokens} tokens needs {need} pages "
                f"> max_pages_per_slot={self.max_pages_per_slot}")
        grow = need - len(self._owned[slot])
        if grow <= 0:
            return []
        if grow > len(self._free):
            raise OutOfPages(
                f"slot {slot}: need {grow} pages, {len(self._free)} free")
        new = [self._free.pop() for _ in range(grow)]
        base = len(self._owned[slot])
        self._owned[slot].extend(new)
        self.block_tables[slot, base:base + grow] = new
        return new

    def commit(self, slot: int, n_tokens: int) -> None:
        """Record that ``slot`` now stores ``n_tokens`` tokens (post-write)."""
        assert self.pages_for(n_tokens) <= len(self._owned[slot]), \
            (slot, n_tokens, len(self._owned[slot]))
        self._lengths[slot] = n_tokens

    def truncate(self, slot: int, n_tokens: int) -> List[int]:
        """Roll ``slot`` back so it stores exactly ``n_tokens`` tokens,
        freeing every owned page past ``ceil(n_tokens / page_size)``.

        This is the rollback primitive speculative decoding needs
        (``repro.spec``): a verify step writes K+1 candidate tokens into the
        slot's pages, then the rejected suffix is discarded by truncating to
        the accepted length.  ``n_tokens`` is bounded by the slot's currently
        *allocated* capacity, not its committed length — a verify step
        allocates and writes before it knows how much survives, so truncate
        doubles as the commit of the accepted prefix.

        Stale KV left in the kept partial page (offsets past ``n_tokens``)
        is never read: attention masks by length, and the offsets are
        overwritten by the next append.  Freed pages return to the pool and
        may be re-rented immediately (their stale contents are masked by the
        new owner's length the same way).  Returns the freed page ids.
        """
        if n_tokens < 0:
            raise ValueError(f"slot {slot}: cannot truncate to {n_tokens}")
        keep = self.pages_for(n_tokens)
        owned = self._owned[slot]
        if keep > len(owned):
            raise ValueError(
                f"slot {slot}: truncate to {n_tokens} tokens needs {keep} "
                f"pages but only {len(owned)} are allocated")
        freed = owned[keep:]
        self._owned[slot] = owned[:keep]
        self._free.extend(reversed(freed))
        self.block_tables[slot, keep:] = NULL_PAGE
        self._lengths[slot] = n_tokens
        return freed

    def free_slot(self, slot: int) -> int:
        """Return all of ``slot``'s pages to the pool; returns count freed."""
        pages = self._owned[slot]
        n = len(pages)
        self._free.extend(reversed(pages))
        self._owned[slot] = []
        self._lengths[slot] = 0
        self.block_tables[slot, :] = NULL_PAGE
        return n

    def length(self, slot: int) -> int:
        return int(self._lengths[slot])

    def owned_pages(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._owned[slot])

    def view(self) -> PageTableView:
        return PageTableView(block_tables=self.block_tables.copy(),
                             lengths=self._lengths.copy())

    # -- defrag -----------------------------------------------------------
    def defrag(self) -> List[Tuple[int, int]]:
        """Compact live pages onto the lowest physical ids.

        Returns ``[(src, dst), ...]`` moves for the engine to mirror on the
        device pools (``pool = pool.at[..., dst].set(pool[..., src])``).
        After compaction the live pages occupy ids ``1..used_pages``, so a
        long-running engine can shrink its device pools by slicing off the
        tail.  Moves are ordered so applying them sequentially is safe
        (every dst is drawn from the free set before its src is released).
        """
        live = sorted(p for owned in self._owned for p in owned)
        mapping: Dict[int, int] = {}
        moves: List[Tuple[int, int]] = []
        for want, src in enumerate(live, start=1):
            if src != want:
                mapping[src] = want
                moves.append((src, want))
        if not moves:
            return []
        for slot in range(self.slots):
            self._owned[slot] = [mapping.get(p, p) for p in self._owned[slot]]
            n = len(self._owned[slot])
            self.block_tables[slot, :n] = self._owned[slot]
        n_live = len(live)
        self._free = list(range(self.num_pages, n_live, -1))
        return moves
