"""Paged KV cache: fixed-size pages, per-slot block tables, alloc/free/defrag,
and refcounted **prefix sharing** with copy-on-write.

The serving analogue of the paper's APR residency story: the APR keeps a
running reduction resident near the ALU so the memory system sees one write
per output element; a paged KV cache keeps the *decode working set* resident
in fixed-size, reusable pages so decode attention touches only live pages —
no slot ever holds ``max_seq`` worth of zeros it will never fill.  Freeing a
request's pages on completion is the allocator-level ``rfsmac.s``: the
accumulated state is flushed (sampled tokens already emitted) and the
storage returns to the pool in one step.

Prefix sharing pushes the same residency argument *across requests*: two
requests whose prompts share a prefix would materialize byte-identical KV
pages (KV content is a deterministic function of the token prefix under
greedy serving), so with ``enable_sharing=True`` the allocator dedupes them
— identical prefixes resolve to the *same* physical pages, refcounted, and
a slot only gets a private copy when it is about to **write** into a shared
page (copy-on-write).  Under shared-system-prompt traffic this multiplies
effective KV capacity the way the paper's APR multiplies effective memory
bandwidth: the hot state is kept once and rented to every consumer.

This module is the *host-side* allocator: pure python/numpy bookkeeping
(free list, block tables, refcounts, the prefix index).  The device-side
page pools — ``(n_sb, me, num_pages, page_size, hkv, dh)`` arrays — are
owned by the engine (`repro.serve.engine.PagedServeEngine`) and by the
model's paged decode path (`repro.models.lm.lm_decode_paged`); the
allocator only decides *which* page indices they use.  The one device
consequence of sharing is the COW split: the allocator queues ``(src, dst)``
page copies that the engine must mirror on every pool
(:meth:`PagedKVCache.pop_page_copies`) before the next forward.

Layout invariants
-----------------
* Page ``0`` is the reserved **null page**: never allocated, used as the
  scatter target for padded prefill positions and idle slots, and as the
  block-table filler for unallocated entries.  Garbage written there is
  never read back (attention masks by length before any null-page position
  becomes visible).
* ``block_tables[slot, i]`` holds the physical page backing logical tokens
  ``[i * page_size, (i+1) * page_size)`` of that slot.  The same logical ->
  physical mapping is shared by every layer (each layer has its own storage
  at the same page index), so one int32 table drives the whole model.
* A slot storing ``n`` tokens references exactly ``ceil(n / page_size)``
  pages; with sharing, several slots may reference the *same* physical page
  (its refcount counts the referencing slots) but a slot's reference run is
  always prefix-closed: if a slot holds page ``i`` it holds pages
  ``0..i-1`` too, so a shared page can never outlive its shared parent.
* **Shared pages are read-only.**  Every write path goes through
  :meth:`allocate`, which COW-splits the page containing the write boundary
  when its refcount exceeds one (and unregisters it from the prefix index
  when it does not, since its content is about to diverge).  ``truncate``
  and ``free_slot`` only *drop references* — a page returns to the free
  list exactly when its refcount hits zero, **unless** it is published in
  the prefix index, in which case it parks in an LRU *evictable* pool
  instead: its KV content stays valid and matchable after every referent
  finished (the cache survives between request waves), and the page is
  lazily evicted — unregistered and recycled — only when an allocation
  finds the free list empty.  Prefix-closure makes lazy eviction safe: a
  parked page's registered descendants are necessarily parked too (a live
  child would imply a live parent), so evicting a page evicts its whole
  subtree and no trie entry can ever dangle under a recycled page id.
* **int8 storage** (``kv_dtype="int8"`` on the engine / model cache): the
  device pools hold int8 payloads plus fp32 scale pools of shape
  ``(..., num_pages + 1, page_size, hkv)`` — one symmetric scale per (page
  slot, kv head), written together with its payload so a slot is always
  self-consistent and rewrites stay idempotent.  Nothing here changes: the
  allocator tracks *pages*, not bytes; the same block tables drive the int8
  pools and their scale pools, and a COW/defrag page move applies to
  payload and scale pools alike (the page axis is shared).  See
  ``docs/quantization.md``.

Prefix index
------------
Registered pages form a trie keyed by content: a page is registered under
``(parent_page, tokens)`` where ``parent_page`` is the physical page backing
the preceding ``page_size`` tokens (``NULL_PAGE`` for the first page) and
``tokens`` is the exact token tuple the page stores.  Because a registered
page's id *is* its trie node, lookup is exact — no hash collisions can ever
splice two different prefixes together.  ``match_prefix`` walks the trie
page by page and finishes with a **partial-page** match: the best
common-prefix child of the last matched node is attached shared, and the
first divergent append COW-splits it.  Entries are evicted when their page's
refcount reaches zero (content is about to be recycled) or when its owner
writes into it while unshared (content is about to diverge).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

NULL_PAGE = 0

_KV_ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1}


def kv_token_bytes(hkv: int, head_dim: int, kv_dtype: str = "bfloat16") -> int:
    """Analytic KV-cache bytes one token costs per layer tensor (K or V):
    payload at ``kv_dtype`` width plus, for int8, the per-(token, head)
    fp32 scale.  Used by the quantization benchmark's bandwidth model."""
    payload = hkv * head_dim * _KV_ITEMSIZE[kv_dtype]
    scales = hkv * 4 if kv_dtype == "int8" else 0
    return payload + scales


class OutOfPages(Exception):
    """Raised by ``allocate`` when the pool cannot cover a reservation."""


@dataclasses.dataclass
class PageTableView:
    """Immutable snapshot handed to device code / tests."""
    block_tables: np.ndarray      # (slots, max_pages_per_slot) int32
    lengths: np.ndarray           # (slots,) int32 tokens stored per slot


#: trie key: (parent physical page, exact token tuple the page stores)
_TrieKey = Tuple[int, Tuple[int, ...]]


class PagedKVCache:
    """Fixed-size-page allocator with per-slot block tables and (optional)
    refcounted prefix sharing.

    ``num_pages`` counts *usable* pages; one extra null page is always
    appended at index 0, so device pools must be sized ``num_pages + 1``
    (see :attr:`pool_pages`).

    Refcounting is always on (``truncate`` / ``free_slot`` drop references
    and only recycle a page at refcount zero); ``enable_sharing=True``
    additionally activates the prefix index so :meth:`match_prefix` /
    :meth:`register_prefix` can create refcounts above one.  With sharing
    off every refcount stays at one and behavior is identical to the
    pre-sharing allocator.
    """

    def __init__(self, *, slots: int, num_pages: int, page_size: int,
                 max_pages_per_slot: Optional[int] = None,
                 enable_sharing: bool = False):
        if page_size <= 0 or num_pages <= 0 or slots <= 0:
            raise ValueError("slots, num_pages, page_size must be positive")
        self.slots = slots
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot or num_pages
        self.enable_sharing = enable_sharing
        # physical ids 1..num_pages are allocatable; 0 is the null page
        self._free: List[int] = list(range(num_pages, 0, -1))  # pop() -> 1 first
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._lengths = np.zeros((slots,), np.int32)
        self.block_tables = np.zeros((slots, self.max_pages_per_slot), np.int32)
        # page refcounts: number of slots referencing each physical page
        self._ref = np.zeros((num_pages + 1,), np.int32)
        # prefix index (trie over page contents; see module docstring)
        self._index: Dict[_TrieKey, int] = {}
        self._children: Dict[int, Set[int]] = {}
        self._page_meta: Dict[int, _TrieKey] = {}
        # pages of a slot already offered to register_prefix (avoids
        # rehashing the whole prefix every chunk)
        self._next_reg: List[int] = [0] * slots
        # registered pages whose refcount hit zero, kept matchable until
        # memory pressure evicts them; dict = insertion-ordered, oldest
        # first (suffix-first release order makes a chain's deepest page
        # oldest, so LRU eviction trims subtrees leaf-first)
        self._evictable: Dict[int, None] = {}
        # COW page copies the engine must mirror on the device pools before
        # the next forward (drained via pop_page_copies, FIFO-safe)
        self._pending_copies: List[Tuple[int, int]] = []
        #: cumulative sharing counters (never reset; consumers take deltas):
        #: fresh_pages = pages drawn from the free list, shared_attached =
        #: references added by match_prefix, cow_splits = COW page copies,
        #: dedup_reclaimed = private pages retired by retro-dedup in
        #: register_prefix (a page found byte-identical to an already-
        #: published one).  fresh_pages - dedup_reclaimed is the *unique*
        #: page cost of the traffic served so far.
        self.stats: Dict[str, int] = {"fresh_pages": 0, "shared_attached": 0,
                                      "cow_splits": 0, "dedup_reclaimed": 0,
                                      "evictions": 0}

    # -- capacity queries -------------------------------------------------
    @property
    def pool_pages(self) -> int:
        """Physical pages device pools must allocate (incl. null page)."""
        return self.num_pages + 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Parked prefix-cache pages: registered, refcount zero, reclaimed
        lazily under pressure.  Always zero with sharing disabled."""
        return len(self._evictable)

    @property
    def available_pages(self) -> int:
        """Pages an allocation can draw on: free plus lazily evictable."""
        return len(self._free) + len(self._evictable)

    @property
    def used_pages(self) -> int:
        """Unique physical pages referenced by at least one slot (a shared
        page counts once; parked prefix-cache pages don't count)."""
        return self.num_pages - self.available_pages

    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    def max_tokens_per_slot(self) -> int:
        return self.max_pages_per_slot * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def refcount(self, page: int) -> int:
        """Number of slots currently referencing ``page`` (0 = free)."""
        return int(self._ref[page])

    def _cow_pages_needed(self, slot: int, n_tokens: int) -> int:
        """Extra pages a grow-to-``n_tokens`` needs for COW splits: writing
        starts at the committed length, so only the page containing that
        boundary can be both owned and shared (later owned pages are always
        private over-allocations, earlier ones are not written)."""
        length = int(self._lengths[slot])
        if n_tokens <= length or length % self.page_size == 0:
            return 0
        boundary = length // self.page_size
        owned = self._owned[slot]
        if boundary < len(owned) and self._ref[owned[boundary]] > 1:
            return 1
        return 0

    def can_grow(self, slot: int, n_tokens: int) -> bool:
        """Could ``slot`` hold ``n_tokens`` total without preempting anyone?
        Accounts for any COW split the first write would force; parked
        prefix-cache pages count as reclaimable."""
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_slot:
            return False
        grow = max(need - len(self._owned[slot]), 0)
        return (grow + self._cow_pages_needed(slot, n_tokens)
                <= self.available_pages)

    # -- alloc / free -----------------------------------------------------
    def _take_free(self) -> int:
        """Pop a free page, lazily evicting the oldest parked prefix-cache
        page (and, via prefix-closure, its parked subtree) when the free
        list is empty.  Callers must have checked ``available_pages``."""
        if not self._free:
            victim = next(iter(self._evictable))
            self._drop_subtree(victim)
            self.stats["evictions"] += 1
        return self._free.pop()

    def _drop_subtree(self, page: int) -> None:
        """Unregister ``page`` and every registered descendant — their trie
        entries continue a prefix that is about to diverge or be recycled,
        so leaving any behind would let a future match splice stale KV onto
        a new context (page ids are reused; a dangling child under a reused
        id aliases the new registration).  Parked descendants return to the
        free list; live ones (possible only when dropping a *diverging*
        page, whose still-running referents share a now-unpublished prefix)
        keep serving their slots read-only but stop being matchable."""
        for child in list(self._children.get(page, ())):
            self._drop_subtree(child)
        self._unregister(page)
        if page in self._evictable:
            del self._evictable[page]
            self._free.append(page)

    def allocate(self, slot: int, n_tokens: int) -> List[int]:
        """Grow ``slot`` so it can store ``n_tokens`` tokens total, and make
        the write range exclusively owned.

        Returns the newly assigned page ids (possibly empty).  Raises
        :class:`OutOfPages` without side effects if the pool cannot cover
        the growth, so callers can preempt and retry.

        Callers invoke this exactly when they are about to *write* tokens
        ``[length, n_tokens)``, so this is also the copy-on-write point: if
        the page containing the committed-length boundary is shared, it is
        split — a fresh page replaces it in this slot's table, the copy is
        queued for the engine (:meth:`pop_page_copies`), and the original
        keeps serving its other referents read-only.  If that boundary page
        is unshared but published in the prefix index, it is unregistered
        instead (its content is about to diverge from the registered
        prefix).
        """
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_slot:
            raise OutOfPages(
                f"slot {slot}: {n_tokens} tokens needs {need} pages "
                f"> max_pages_per_slot={self.max_pages_per_slot}")
        owned = self._owned[slot]
        grow = max(need - len(owned), 0)
        cow = self._cow_pages_needed(slot, n_tokens)
        if grow + cow > self.available_pages:
            raise OutOfPages(
                f"slot {slot}: need {grow + cow} pages "
                f"({grow} growth + {cow} COW), {self.available_pages} "
                "available")
        length = int(self._lengths[slot])
        if n_tokens > length and length % self.page_size != 0:
            boundary = length // self.page_size
            if boundary < len(owned):
                src = owned[boundary]
                if self._ref[src] > 1:
                    dst = self._take_free()
                    self._ref[src] -= 1
                    self._ref[dst] = 1
                    owned[boundary] = dst
                    self.block_tables[slot, boundary] = dst
                    self._pending_copies.append((src, dst))
                    self.stats["cow_splits"] += 1
                elif src in self._page_meta:
                    # unshared but published: content is about to diverge,
                    # so the page — and every registered continuation of
                    # the prefix it anchored — leaves the index
                    self._drop_subtree(src)
        if grow == 0:
            return []
        new = [self._take_free() for _ in range(grow)]
        for p in new:
            self._ref[p] = 1
        base = len(owned)
        owned.extend(new)
        self.block_tables[slot, base:base + grow] = new
        self.stats["fresh_pages"] += grow
        return new

    def commit(self, slot: int, n_tokens: int) -> None:
        """Record that ``slot`` now stores ``n_tokens`` tokens (post-write)."""
        assert self.pages_for(n_tokens) <= len(self._owned[slot]), \
            (slot, n_tokens, len(self._owned[slot]))
        self._lengths[slot] = n_tokens

    def pop_page_copies(self) -> List[Tuple[int, int]]:
        """Drain queued COW page copies as ``[(src, dst), ...]`` for the
        engine to mirror on every device pool
        (``pool = pool.at[:, :, dst].set(pool[:, :, src])``) **before the
        next forward**.  Applying them in order is safe: a src is only ever
        recycled as a later copy's dst, never overwritten in between
        (device pages are written only by forwards, which happen after the
        drain)."""
        moves, self._pending_copies = self._pending_copies, []
        return moves

    def _release(self, page: int) -> bool:
        """Drop one reference to ``page``.  At refcount zero the page is
        recycled — or, if it is published in the prefix index, *parked* in
        the evictable pool so its content stays matchable until memory
        pressure reclaims it.  Returns True when the page left live use
        (freed or parked)."""
        self._ref[page] -= 1
        assert self._ref[page] >= 0, f"page {page}: negative refcount"
        if self._ref[page] > 0:
            return False
        if page in self._page_meta:
            self._evictable[page] = None
        else:
            self._free.append(page)
        return True

    def truncate(self, slot: int, n_tokens: int) -> List[int]:
        """Roll ``slot`` back so it stores exactly ``n_tokens`` tokens,
        dropping its reference to every page past ``ceil(n / page_size)``.

        This is the rollback primitive speculative decoding needs
        (``repro.spec``): a verify step writes K+1 candidate tokens into the
        slot's pages, then the rejected suffix is discarded by truncating to
        the accepted length.  ``n_tokens`` is bounded by the slot's currently
        *allocated* capacity, not its committed length — a verify step
        allocates and writes before it knows how much survives, so truncate
        doubles as the commit of the accepted prefix.

        Refcount semantics: dropped pages leave live use only when this
        slot held the last reference — a page still backing another slot's
        prefix survives untouched (rollback never mutates shared state; the
        *write* that follows a rollback into a still-shared kept page is
        what triggers the COW split, inside :meth:`allocate`).  References
        are dropped suffix-first so a shared child is always released before
        its parent.  Returns the page ids whose refcount hit zero — freed
        to the pool, or (if published in the prefix index) parked in the
        evictable prefix cache.

        Stale KV left in the kept partial page (offsets past ``n_tokens``)
        is never read: attention masks by length, and the offsets are
        overwritten by the next append (COW-splitting first if the page is
        shared).  Freed pages may be re-rented immediately (their stale
        contents are masked by the new owner's length the same way).
        """
        if n_tokens < 0:
            raise ValueError(f"slot {slot}: cannot truncate to {n_tokens}")
        keep = self.pages_for(n_tokens)
        owned = self._owned[slot]
        if keep > len(owned):
            raise ValueError(
                f"slot {slot}: truncate to {n_tokens} tokens needs {keep} "
                f"pages but only {len(owned)} are allocated")
        dropped = owned[keep:]
        self._owned[slot] = owned[:keep]
        freed = [p for p in reversed(dropped) if self._release(p)]
        self.block_tables[slot, keep:] = NULL_PAGE
        self._lengths[slot] = n_tokens
        self._next_reg[slot] = min(self._next_reg[slot],
                                   n_tokens // self.page_size)
        return freed

    def free_slot(self, slot: int) -> int:
        """Drop all of ``slot``'s page references; returns how many pages
        left live use — returned to the pool or parked in the prefix cache
        (shared pages survive with their other referents)."""
        pages = self._owned[slot]
        n = sum(self._release(p) for p in reversed(pages))
        self._owned[slot] = []
        self._lengths[slot] = 0
        self._next_reg[slot] = 0
        self.block_tables[slot, :] = NULL_PAGE
        return n

    def length(self, slot: int) -> int:
        return int(self._lengths[slot])

    def owned_pages(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._owned[slot])

    def view(self) -> PageTableView:
        return PageTableView(block_tables=self.block_tables.copy(),
                             lengths=self._lengths.copy())

    # -- prefix sharing ---------------------------------------------------
    def _attach(self, page: int) -> None:
        """Take a reference on a registered page, un-parking it if it was
        sitting in the evictable prefix cache."""
        if self._ref[page] == 0:
            del self._evictable[page]
        self._ref[page] += 1

    def _register(self, page: int, key: _TrieKey) -> None:
        self._index[key] = page
        self._children.setdefault(key[0], set()).add(page)
        self._page_meta[page] = key

    def _unregister(self, page: int) -> None:
        key = self._page_meta.pop(page)
        del self._index[key]
        kids = self._children.get(key[0])
        if kids is not None:
            kids.discard(page)
            if not kids:
                del self._children[key[0]]

    @property
    def registered_pages(self) -> int:
        """Pages currently published in the prefix index (test hook)."""
        return len(self._page_meta)

    def match_prefix(self, slot: int, tokens: List[int]) -> int:
        """Attach the longest already-cached prefix of ``tokens`` to the
        empty ``slot`` and return how many tokens it covers.

        Walks the prefix trie a full page at a time, then finishes with the
        best *partial* match among the last node's children (a shared page
        whose content starts with the remaining tokens) — the attached
        partial page is shared read-only and the first divergent append
        COW-splits it.  The match is capped at ``len(tokens) - 1``: at least
        one prompt token must run through prefill so the engine gets
        next-token logits (the KV of matched tokens is reused, their logits
        were never kept).

        The slot's committed length is set to the matched token count —
        callers resume prefill from there.  Returns 0 with sharing disabled.
        """
        if not self.enable_sharing:
            return 0
        assert not self._owned[slot] and self._lengths[slot] == 0, \
            f"match_prefix: slot {slot} is not empty"
        limit = min(len(tokens) - 1, self.max_tokens_per_slot())
        ps = self.page_size
        attached: List[int] = []
        parent = NULL_PAGE
        while (len(attached) + 1) * ps <= limit:
            base = len(attached) * ps
            page = self._index.get((parent, tuple(tokens[base:base + ps])))
            if page is None:
                break
            attached.append(page)
            parent = page
        matched = len(attached) * ps
        remaining = limit - matched
        if remaining > 0:
            best, best_r = None, 0
            want = tokens[matched:matched + remaining]
            for q in self._children.get(parent, ()):
                have = self._page_meta[q][1]
                r = 0
                for a, b in zip(have, want):
                    if a != b:
                        break
                    r += 1
                if r > best_r:
                    best, best_r = q, r
            if best is not None:
                attached.append(best)
                matched += best_r
        for i, p in enumerate(attached):
            self._attach(p)
            self._owned[slot].append(p)
            self.block_tables[slot, i] = p
        self._lengths[slot] = matched
        # full attached pages are already published; the engine's
        # register_prefix calls start after them (a partially-matched tail
        # page belongs to its original publisher, and this slot's divergent
        # copy of it re-registers — or retro-dedups — once complete)
        self._next_reg[slot] = matched // ps
        self.stats["shared_attached"] += len(attached)
        return matched

    def register_prefix(self, slot: int, tokens: List[int]) -> None:
        """Publish ``slot``'s fully-written pages in the prefix index so
        later requests can share them.  ``tokens`` is the slot's full token
        history; only pages completely covered by the committed length are
        published (a partial page's content is still growing).

        Idempotent and incremental: pages already offered are skipped.  If
        an identical page is already published by another slot
        (simultaneous admissions compute the same prefix independently),
        this slot's private copy is retired and its reference is repointed
        at the canonical page (**retro-dedup**) — contents are byte-
        identical by construction, so no device copy is needed.
        """
        if not self.enable_sharing:
            return
        ps = self.page_size
        owned = self._owned[slot]
        full = min(int(self._lengths[slot]) // ps, len(tokens) // ps,
                   len(owned))
        for i in range(self._next_reg[slot], full):
            page_toks = tuple(tokens[i * ps:(i + 1) * ps])
            parent = owned[i - 1] if i else NULL_PAGE
            key = (parent, page_toks)
            cur = self._index.get(key)
            if cur is None:
                if page_toks and owned[i] not in self._page_meta:
                    self._register(owned[i], key)
            elif cur != owned[i] and self._ref[owned[i]] == 1:
                # retro-dedup: identical content already published; retire
                # the private copy and share the canonical page
                private = owned[i]
                self._attach(cur)
                owned[i] = cur
                self.block_tables[slot, i] = cur
                self._release(private)
                self.stats["dedup_reclaimed"] += 1
        self._next_reg[slot] = full

    # -- defrag -----------------------------------------------------------
    def defrag(self) -> List[Tuple[int, int]]:
        """Compact live pages — slot-owned or parked in the prefix cache —
        onto the lowest physical ids, preserving sharing (a page referenced
        by several slots moves once and every referent's table is rewritten
        to the new id; the prefix trie and the evictable pool are remapped
        with it, so cached prefixes stay matchable across compaction).

        Returns ``[(src, dst), ...]`` moves for the engine to mirror on the
        device pools (``pool = pool.at[..., dst].set(pool[..., src])``).
        After compaction the live pages occupy ids ``1..used + cached``, so
        a long-running engine can shrink its device pools by slicing off
        the tail.  Moves are ordered so applying them sequentially is safe
        (every dst is drawn from the free set before its src is released).
        Queued-but-undrained COW copies are remapped to the new ids.
        """
        live = sorted({p for owned in self._owned for p in owned}
                      | set(self._evictable))
        mapping: Dict[int, int] = {}
        moves: List[Tuple[int, int]] = []
        for want, src in enumerate(live, start=1):
            if src != want:
                mapping[src] = want
                moves.append((src, want))
        if not moves:
            return []
        for slot in range(self.slots):
            self._owned[slot] = [mapping.get(p, p) for p in self._owned[slot]]
            n = len(self._owned[slot])
            self.block_tables[slot, :n] = self._owned[slot]
        new_ref = np.zeros_like(self._ref)
        for p in live:
            new_ref[mapping.get(p, p)] = self._ref[p]
        self._ref = new_ref
        # remap the prefix trie: both node ids (pages) and parent links
        remap = lambda p: mapping.get(p, p)  # noqa: E731
        self._index = {(remap(parent), toks): remap(page)
                       for (parent, toks), page in self._index.items()}
        self._children = {remap(parent): {remap(q) for q in kids}
                          for parent, kids in self._children.items()}
        self._page_meta = {remap(page): (remap(parent), toks)
                           for page, (parent, toks) in self._page_meta.items()}
        self._pending_copies = [(remap(s), remap(d))
                                for s, d in self._pending_copies]
        self._evictable = {remap(p): None for p in self._evictable}
        n_live = len(live)
        self._free = list(range(self.num_pages, n_live, -1))
        return moves
