"""Mamba-2 (SSD) blocks — chunked matmul formulation.

The chunk loop is a *python-unrolled* state-passing loop (not lax.scan) so
the dry-run's ``cost_analysis`` counts every chunk's FLOPs (XLA counts a
scan body once); chunk count is static (seq/chunk).  All decay exponents
are differences of a non-increasing cumulative sum, so every ``exp`` is
<= 1 — numerically safe in bf16/fp32.

On real TPU the per-chunk inner compute maps onto kernels/mamba2 (state as
APR in VMEM); the jnp path here is the distributable oracle the dry-run
lowers.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import ParamBuilder, Params, rms_norm

CONV_K = 4


def ssm_params(pb: ParamBuilder, prefix: str, cfg: ModelConfig, layers: Optional[int]):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    lead = () if layers is None else (layers,)
    llog = () if layers is None else ("layers",)
    pb.param(f"{prefix}.w_in", lead + (d, 2 * di + 2 * n + h), llog + ("embed", "ssm_inner"))
    pb.param(f"{prefix}.conv", lead + (CONV_K, di + 2 * n), llog + (None, "ssm_inner"), scale=0.5)
    pb.param(f"{prefix}.a_log", lead + (h,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.d_skip", lead + (h,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.dt_bias", lead + (h,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.norm", lead + (di,), llog + ("ssm_inner",), scale=0.0)
    pb.param(f"{prefix}.w_out", lead + (di, d), llog + ("ssm_inner", "embed"))


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel CONV_K.  x: (B,T,C); w: (K,C)."""
    pads = [x]
    for k in range(1, CONV_K):
        pads.append(jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]])
    out = sum(pads[k] * w[CONV_K - 1 - k] for k in range(CONV_K))
    return out


def _segsum_exp(s: jax.Array) -> jax.Array:
    """exp(s_i - s_j) masked to j <= i.  s: (B,H,C) non-increasing-safe."""
    c = s.shape[-1]
    diff = s[..., :, None] - s[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def _ssd_one_chunk(xc, bc, cc, dtc, a, d_skip, hstate):
    """One SSD chunk: (B,C,...) fp32 inputs + (B,H,P,N) state -> (y, state)."""
    g = a[None, None, :] * dtc                       # (B,C,H) <= 0
    s = jnp.cumsum(g, axis=1)                        # non-increasing
    sh = s.transpose(0, 2, 1)                        # (B,H,C)

    scores_nb = jnp.einsum("bin,bjn->bij", cc, bc)   # shared over heads
    m = _segsum_exp(sh) * scores_nb[:, None]         # (B,H,C,C)
    dtx = dtc[..., None] * xc                        # (B,C,H,P)
    y_intra = jnp.einsum("bhij,bjhp->bihp", m, dtx)

    decay_in = jnp.exp(sh).transpose(0, 2, 1)        # (B,C,H)
    y_inter = jnp.einsum("bhpn,bin,bih->bihp", hstate, cc, decay_in)

    decay_to_end = jnp.exp(sh[..., -1:] - sh).transpose(0, 2, 1)  # (B,C,H)
    upd = jnp.einsum("bih,bin,bihp->bhpn", decay_to_end * dtc, bc, xc)
    hstate = jnp.exp(sh[..., -1])[..., None, None] * hstate + upd

    y = y_intra + y_inter + d_skip[None, None, :, None] * xc
    return y, hstate


def ssd_chunked(
    x: jax.Array,    # (B,T,H,P)  post-conv, activated
    b: jax.Array,    # (B,T,N)
    c: jax.Array,    # (B,T,N)
    dt: jax.Array,   # (B,T,H)    positive
    a: jax.Array,    # (H,)       negative
    d_skip: jax.Array,  # (H,)
    *,
    chunk: int,
    h_init: Optional[jax.Array] = None,  # (B,H,P,N)
    return_state: bool = False,
    chunk_scan: bool = False,
):
    """``chunk_scan=False``: python-unrolled chunk loop (FLOPs fully visible
    to cost_analysis — used by the depth-extrapolation compiles).
    ``chunk_scan=True``: lax.scan over chunks (compact HLO for the full-depth
    memory-proof compile and for real training)."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    nchunks = -(-t // chunk)
    pad = nchunks * chunk - t
    if pad:
        x, b, c, dt = (jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
                       for v in (x, b, c, dt))

    hstate = h_init if h_init is not None else jnp.zeros((bsz, h, p, n), jnp.float32)
    af = a.astype(jnp.float32)
    df = d_skip.astype(jnp.float32)

    if chunk_scan and nchunks > 1:
        def to_chunks(v):
            return v.reshape(bsz, nchunks, chunk, *v.shape[2:]) \
                    .swapaxes(0, 1).astype(jnp.float32)

        def body(hs, xs):
            xc, bc, cc, dtc = xs
            y, hs = _ssd_one_chunk(xc, bc, cc, dtc, af, df, hs)
            return hs, y

        hstate, ys = jax.lax.scan(
            body, hstate, (to_chunks(x), to_chunks(b), to_chunks(c), to_chunks(dt)))
        out = ys.swapaxes(0, 1).reshape(bsz, nchunks * chunk, h, p)[:, :t]
    else:
        ys = []
        for ci in range(nchunks):
            sl = slice(ci * chunk, (ci + 1) * chunk)
            y, hstate = _ssd_one_chunk(
                x[:, sl].astype(jnp.float32), b[:, sl].astype(jnp.float32),
                c[:, sl].astype(jnp.float32), dt[:, sl].astype(jnp.float32),
                af, df, hstate)
            ys.append(y)
        out = jnp.concatenate(ys, axis=1)[:, :t]
    if return_state:
        return out, hstate
    return out


def mamba2_mixer(
    p: Params, prefix: str, cfg: ModelConfig, x: jax.Array, *, chunk: int = 256,
    chunk_scan: Optional[bool] = None,
) -> jax.Array:
    """Full Mamba-2 block body (train/prefill): x: (B,T,d) -> (B,T,d)."""
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("btd,de->bte", x, p[f"{prefix}.w_in"])
    z, xc, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p[f"{prefix}.conv"]))
    xc, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}.dt_bias"])
    a = -jnp.exp(p[f"{prefix}.a_log"].astype(jnp.float32))
    bsz, t = x.shape[:2]
    if chunk_scan is None:
        # follow the layer-scan mode: compact HLO when layers are scanned
        # (real training / memory-proof), unrolled for cost extrapolation
        chunk_scan = cfg.scan_layers
    y = ssd_chunked(
        xc.reshape(bsz, t, h, ph), bmat, cmat, dt, a,
        p[f"{prefix}.d_skip"].astype(jnp.float32), chunk=chunk,
        chunk_scan=chunk_scan,
    )
    y = y.reshape(bsz, t, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p[f"{prefix}.norm"] + 1.0, cfg.norm_eps)
    return jnp.einsum("bte,ed->btd", y, p[f"{prefix}.w_out"])


# ---------------------------------------------------------------------------
# Single-token decode with carried (conv_state, ssm_state).
# ---------------------------------------------------------------------------


def mamba2_decode(
    p: Params, prefix: str, cfg: ModelConfig,
    x: jax.Array,                 # (B, 1, d)
    conv_state: jax.Array,        # (B, CONV_K-1, di+2N)
    ssm_state: jax.Array,         # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    bsz = x.shape[0]
    zxbcdt = jnp.einsum("btd,de->bte", x, p[f"{prefix}.w_in"])[:, 0]
    z, xc, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)       # (B, di+2N)
    window = jnp.concatenate([conv_state, conv_in[:, None]], axis=1)  # (B,K,ch)
    w = p[f"{prefix}.conv"]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))
    new_conv_state = window[:, 1:]
    xc, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}.dt_bias"])  # (B,H)
    a = -jnp.exp(p[f"{prefix}.a_log"].astype(jnp.float32))
    xh = xc.reshape(bsz, h, ph).astype(jnp.float32)
    decay = jnp.exp(a[None] * dt)                                # (B,H)
    upd = dt[..., None, None] * (xh[..., None] * bmat[:, None, None, :].astype(jnp.float32))
    ssm_state = decay[..., None, None] * ssm_state + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, cmat.astype(jnp.float32))
    y = y + p[f"{prefix}.d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, di).astype(x.dtype) * jax.nn.silu(z)[:, None]
    y = rms_norm(y, p[f"{prefix}.norm"] + 1.0, cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p[f"{prefix}.w_out"])
    return out, new_conv_state, ssm_state
