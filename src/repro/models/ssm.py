"""Mamba-2 (SSD) blocks — chunked matmul formulation.

The chunk loop is a *python-unrolled* state-passing loop (not lax.scan) so
the dry-run's ``cost_analysis`` counts every chunk's FLOPs (XLA counts a
scan body once); chunk count is static (seq/chunk).  All decay exponents
are differences of a non-increasing cumulative sum, so every ``exp`` is
<= 1 — numerically safe in bf16/fp32.

On real TPU the per-chunk inner compute maps onto kernels/mamba2 (state as
APR in VMEM); the jnp path here is the distributable oracle the dry-run
lowers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import ParallelContext
from .layers import ParamBuilder, Params, mask_vocab_logits, rms_norm
from .paged_state import gather_state, scatter_state, split_state_tables

CONV_K = 4


def ssm_params(pb: ParamBuilder, prefix: str, cfg: ModelConfig, layers: Optional[int]):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    lead = () if layers is None else (layers,)
    llog = () if layers is None else ("layers",)
    pb.param(f"{prefix}.w_in", lead + (d, 2 * di + 2 * n + h), llog + ("embed", "ssm_inner"))
    pb.param(f"{prefix}.conv", lead + (CONV_K, di + 2 * n), llog + (None, "ssm_inner"), scale=0.5)
    pb.param(f"{prefix}.a_log", lead + (h,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.d_skip", lead + (h,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.dt_bias", lead + (h,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.norm", lead + (di,), llog + ("ssm_inner",), scale=0.0)
    pb.param(f"{prefix}.w_out", lead + (di, d), llog + ("ssm_inner", "embed"))


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, kernel CONV_K.  x: (B,T,C); w: (K,C)."""
    pads = [x]
    for k in range(1, CONV_K):
        pads.append(jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]])
    out = sum(pads[k] * w[CONV_K - 1 - k] for k in range(CONV_K))
    return out


def _segsum_exp(s: jax.Array) -> jax.Array:
    """exp(s_i - s_j) masked to j <= i.  s: (B,H,C) non-increasing-safe."""
    c = s.shape[-1]
    diff = s[..., :, None] - s[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def _ssd_one_chunk(xc, bc, cc, dtc, a, d_skip, hstate):
    """One SSD chunk: (B,C,...) fp32 inputs + (B,H,P,N) state -> (y, state)."""
    g = a[None, None, :] * dtc                       # (B,C,H) <= 0
    s = jnp.cumsum(g, axis=1)                        # non-increasing
    sh = s.transpose(0, 2, 1)                        # (B,H,C)

    scores_nb = jnp.einsum("bin,bjn->bij", cc, bc)   # shared over heads
    m = _segsum_exp(sh) * scores_nb[:, None]         # (B,H,C,C)
    dtx = dtc[..., None] * xc                        # (B,C,H,P)
    y_intra = jnp.einsum("bhij,bjhp->bihp", m, dtx)

    decay_in = jnp.exp(sh).transpose(0, 2, 1)        # (B,C,H)
    y_inter = jnp.einsum("bhpn,bin,bih->bihp", hstate, cc, decay_in)

    decay_to_end = jnp.exp(sh[..., -1:] - sh).transpose(0, 2, 1)  # (B,C,H)
    upd = jnp.einsum("bih,bin,bihp->bhpn", decay_to_end * dtc, bc, xc)
    hstate = jnp.exp(sh[..., -1])[..., None, None] * hstate + upd

    y = y_intra + y_inter + d_skip[None, None, :, None] * xc
    return y, hstate


def ssd_chunked(
    x: jax.Array,    # (B,T,H,P)  post-conv, activated
    b: jax.Array,    # (B,T,N)
    c: jax.Array,    # (B,T,N)
    dt: jax.Array,   # (B,T,H)    positive
    a: jax.Array,    # (H,)       negative
    d_skip: jax.Array,  # (H,)
    *,
    chunk: int,
    h_init: Optional[jax.Array] = None,  # (B,H,P,N)
    return_state: bool = False,
    chunk_scan: bool = False,
):
    """``chunk_scan=False``: python-unrolled chunk loop (FLOPs fully visible
    to cost_analysis — used by the depth-extrapolation compiles).
    ``chunk_scan=True``: lax.scan over chunks (compact HLO for the full-depth
    memory-proof compile and for real training)."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    nchunks = -(-t // chunk)
    pad = nchunks * chunk - t
    if pad:
        x, b, c, dt = (jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
                       for v in (x, b, c, dt))

    hstate = h_init if h_init is not None else jnp.zeros((bsz, h, p, n), jnp.float32)
    af = a.astype(jnp.float32)
    df = d_skip.astype(jnp.float32)

    if chunk_scan and nchunks > 1:
        def to_chunks(v):
            return v.reshape(bsz, nchunks, chunk, *v.shape[2:]) \
                    .swapaxes(0, 1).astype(jnp.float32)

        def body(hs, xs):
            xc, bc, cc, dtc = xs
            y, hs = _ssd_one_chunk(xc, bc, cc, dtc, af, df, hs)
            return hs, y

        hstate, ys = jax.lax.scan(
            body, hstate, (to_chunks(x), to_chunks(b), to_chunks(c), to_chunks(dt)))
        out = ys.swapaxes(0, 1).reshape(bsz, nchunks * chunk, h, p)[:, :t]
    else:
        ys = []
        for ci in range(nchunks):
            sl = slice(ci * chunk, (ci + 1) * chunk)
            y, hstate = _ssd_one_chunk(
                x[:, sl].astype(jnp.float32), b[:, sl].astype(jnp.float32),
                c[:, sl].astype(jnp.float32), dt[:, sl].astype(jnp.float32),
                af, df, hstate)
            ys.append(y)
        out = jnp.concatenate(ys, axis=1)[:, :t]
    if return_state:
        return out, hstate
    return out


def mamba2_mixer(
    p: Params, prefix: str, cfg: ModelConfig, x: jax.Array, *, chunk: int = 256,
    chunk_scan: Optional[bool] = None, return_state: bool = False,
):
    """Full Mamba-2 block body (train/prefill): x: (B,T,d) -> (B,T,d).
    ``return_state=True`` additionally yields the serving carry — the last
    CONV_K-1 conv-input rows and the final SSD state — so chunked prefill
    can hand off to O(1) decode."""
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("btd,de->bte", x, p[f"{prefix}.w_in"])
    z, xc, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p[f"{prefix}.conv"]))
    xc, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}.dt_bias"])
    a = -jnp.exp(p[f"{prefix}.a_log"].astype(jnp.float32))
    bsz, t = x.shape[:2]
    if chunk_scan is None:
        # follow the layer-scan mode: compact HLO when layers are scanned
        # (real training / memory-proof), unrolled for cost extrapolation
        chunk_scan = cfg.scan_layers
    y = ssd_chunked(
        xc.reshape(bsz, t, h, ph), bmat, cmat, dt, a,
        p[f"{prefix}.d_skip"].astype(jnp.float32), chunk=chunk,
        chunk_scan=chunk_scan, return_state=return_state,
    )
    if return_state:
        y, hstate = y
        window = jnp.concatenate(
            [jnp.zeros((bsz, CONV_K - 1, conv_in.shape[-1]), jnp.float32),
             conv_in.astype(jnp.float32)], axis=1)
        conv_state = window[:, window.shape[1] - (CONV_K - 1):]
    y = y.reshape(bsz, t, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p[f"{prefix}.norm"] + 1.0, cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p[f"{prefix}.w_out"])
    if return_state:
        return out, conv_state, hstate
    return out


# ---------------------------------------------------------------------------
# Single-token decode with carried (conv_state, ssm_state).
# ---------------------------------------------------------------------------


def mamba2_decode(
    p: Params, prefix: str, cfg: ModelConfig,
    x: jax.Array,                 # (B, 1, d)
    conv_state: jax.Array,        # (B, CONV_K-1, di+2N)
    ssm_state: jax.Array,         # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    bsz = x.shape[0]
    zxbcdt = jnp.einsum("btd,de->bte", x, p[f"{prefix}.w_in"])[:, 0]
    z, xc, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)       # (B, di+2N)
    window = jnp.concatenate([conv_state, conv_in[:, None]], axis=1)  # (B,K,ch)
    w = p[f"{prefix}.conv"]
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))
    new_conv_state = window[:, 1:]
    xc, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p[f"{prefix}.dt_bias"])  # (B,H)
    a = -jnp.exp(p[f"{prefix}.a_log"].astype(jnp.float32))
    xh = xc.reshape(bsz, h, ph).astype(jnp.float32)
    decay = jnp.exp(a[None] * dt)                                # (B,H)
    upd = dt[..., None, None] * (xh[..., None] * bmat[:, None, None, :].astype(jnp.float32))
    ssm_state = decay[..., None, None] * ssm_state + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, cmat.astype(jnp.float32))
    y = y + p[f"{prefix}.d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, 1, di).astype(x.dtype) * jax.nn.silu(z)[:, None]
    y = rms_norm(y, p[f"{prefix}.norm"] + 1.0, cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p[f"{prefix}.w_out"])
    return out, new_conv_state, ssm_state

# ---------------------------------------------------------------------------
# Mamba-2 language model (the pure-recurrent `mamba` family): a stack of
# pre-norm mixer blocks with residuals — no attention, no FFN.
# ---------------------------------------------------------------------------


def build_lm_params(cfg: ModelConfig) -> ParamBuilder:
    pb = ParamBuilder(dtype=jnp.bfloat16)
    d = cfg.d_model
    pb.param("embed", (cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02)
    ssm_params(pb, "blk.ssm", cfg, cfg.num_layers)
    pb.param("blk.ln", (cfg.num_layers, d), ("layers", None), scale=0.0)
    pb.param("final_norm", (d,), (None,), scale=0.0)
    pb.param("lm_head", (d, cfg.padded_vocab), ("embed", "vocab"))
    return pb


def _lm_blk(params: Params):
    return {k[len("blk."):]: v for k, v in params.items()
            if k.startswith("blk.")}


def _lm_head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    return mask_vocab_logits(
        jnp.einsum("btd,dv->btv", x, params["lm_head"]), cfg.vocab_size)


def mamba_forward(params: Params, cfg: ModelConfig, pctx: ParallelContext,
                  tokens: jax.Array, *, scan_layers: bool = True,
                  chunk: int = 256) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    blk = _lm_blk(params)

    def layer(xx, lp):
        h = rms_norm(xx, lp["ln"] + 1.0, cfg.norm_eps)
        return xx + mamba2_mixer(lp, "ssm", cfg, h, chunk=chunk,
                                 chunk_scan=scan_layers)

    run = layer
    if cfg.remat:
        run = jax.checkpoint(layer,
                             policy=jax.checkpoint_policies.nothing_saveable)
    if scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (run(c, lp), None), x, blk)
    else:
        for i in range(cfg.num_layers):
            x = run(x, jax.tree.map(lambda a: a[i], blk))
    return _lm_head(params, cfg, x)


def init_lm_state_abstract(cfg: ModelConfig, batch: int):
    ch = cfg.d_inner + 2 * cfg.ssm_state
    L, h, p, n = (cfg.num_layers, cfg.ssm_heads, cfg.ssm_head_dim,
                  cfg.ssm_state)
    return {
        "conv": jax.ShapeDtypeStruct((L, batch, CONV_K - 1, ch), jnp.float32),
        "ssm": jax.ShapeDtypeStruct((L, batch, h, p, n), jnp.float32),
    }


def init_lm_state(cfg: ModelConfig, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_lm_state_abstract(cfg, batch))


def mamba_decode_step(
    params: Params, cfg: ModelConfig, pctx: ParallelContext,
    state: Dict[str, jax.Array], tokens: jax.Array, lengths=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """tokens: (B, 1).  O(1) in context length, like rwkv_decode_step."""
    x = jnp.take(params["embed"], tokens, axis=0)
    blk = _lm_blk(params)

    def body(carry, xs):
        x = carry
        lp, conv, ssm = xs
        h = rms_norm(x, lp["ln"] + 1.0, cfg.norm_eps)
        out, conv, ssm = mamba2_decode(lp, "ssm", cfg, h, conv, ssm)
        return x + out, (conv, ssm)

    xs_tree = (blk, state["conv"], state["ssm"])
    if cfg.scan_layers:
        x, (conv, ssm) = jax.lax.scan(body, x, xs_tree)
    else:  # unrolled (cost-extrapolation dry-run compiles)
        ys = []
        for i in range(cfg.num_layers):
            x, y = body(x, jax.tree.map(lambda a: a[i], xs_tree))
            ys.append(y)
        conv = jnp.stack([y[0] for y in ys])
        ssm = jnp.stack([y[1] for y in ys])
    return _lm_head(params, cfg, x), {"conv": conv, "ssm": ssm}


def mamba_prefill(
    params: Params, cfg: ModelConfig, pctx: ParallelContext,
    tokens: jax.Array, *, scan_layers: bool = True, chunk: int = 256,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked prefill returning last-position logits + the decode carry."""
    x = jnp.take(params["embed"], tokens, axis=0)
    blk = _lm_blk(params)

    def body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln"] + 1.0, cfg.norm_eps)
        out, conv, ssm = mamba2_mixer(lp, "ssm", cfg, h, chunk=chunk,
                                      chunk_scan=scan_layers,
                                      return_state=True)
        return x + out, (conv, ssm)

    if scan_layers:
        x, (conv, ssm) = jax.lax.scan(body, x, blk)
    else:
        ys = []
        for i in range(cfg.num_layers):
            x, y = body(x, jax.tree.map(lambda a: a[i], blk))
            ys.append(y)
        conv = jnp.stack([y[0] for y in ys])
        ssm = jnp.stack([y[1] for y in ys])
    return _lm_head(params, cfg, x[:, -1:]), {"conv": conv, "ssm": ssm}


# ---------------------------------------------------------------------------
# Paged serving: state pools behind the StateCache contract.
# ---------------------------------------------------------------------------


def init_paged_state_abstract(cfg: ModelConfig, state_slots: int,
                              state_dtype: str = "float32"):
    """State pools, physical state slot at axis 1.  ``state_dtype="int8"``
    stores the SSD state int8 with per-(layer, slot, head) scales; the
    conv window stays fp32 (tiny, and re-quantizing a sliding window every
    token would compound)."""
    ch = cfg.d_inner + 2 * cfg.ssm_state
    L, S = cfg.num_layers, state_slots
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    pools = {
        "conv": jax.ShapeDtypeStruct((L, S, CONV_K - 1, ch), jnp.float32),
    }
    if state_dtype == "int8":
        pools["ssm"] = jax.ShapeDtypeStruct((L, S, h, p, n), jnp.int8)
        pools["ssm_scale"] = jax.ShapeDtypeStruct((L, S, h), jnp.float32)
    else:
        pools["ssm"] = jax.ShapeDtypeStruct((L, S, h, p, n), jnp.float32)
    return pools


def init_paged_state(cfg: ModelConfig, state_slots: int,
                     state_dtype: str = "float32"):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_paged_state_abstract(cfg, state_slots,
                                                  state_dtype))


def mamba_decode_paged(params: Params, cfg: ModelConfig, cache,
                       tokens: jax.Array, lengths: jax.Array,
                       new_counts: jax.Array, block_tables: jax.Array,
                       pctx: ParallelContext):
    """Paged decode/prefill chunk: same per-token recurrence as the slot
    engine (bit-identical greedy outputs), state gathered/scattered via the
    combined block table's read/write columns."""
    _, read, writes = split_state_tables(block_tables, tokens.shape[1])
    state = gather_state(cache, read)
    outs = []
    for t in range(tokens.shape[1]):
        logits, state = mamba_decode_step(params, cfg, pctx, state,
                                          tokens[:, t:t + 1])
        cache = scatter_state(cache, state, writes[:, t])
        outs.append(logits)
    return jnp.concatenate(outs, axis=1), cache
