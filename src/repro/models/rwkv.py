"""RWKV-6 (Finch) blocks — chunked matmul formulation with per-channel
data-dependent decay.

Numerics: every exponential is ``exp(cumW_i - cumW_j)`` with ``cumW`` a
non-increasing cumulative sum of ``log w`` (w in (0,1)), evaluated only for
``j <= i`` — all exponents <= 0, so no overflow for any decay value (the
k~ = k*exp(-W) trick of other chunked formulations is deliberately avoided).
The chunk loop is python-unrolled for cost_analysis fidelity (see ssm.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import ParamBuilder, Params, rms_norm

W_LORA = 32


def _k(prefix: str, name: str) -> str:
    return f"{prefix}.{name}" if prefix else name


def rwkv_params(pb: ParamBuilder, prefix: str, cfg: ModelConfig, layers: Optional[int]):
    d, ff = cfg.d_model, cfg.d_ff
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    lead = () if layers is None else (layers,)
    llog = () if layers is None else ("layers",)
    # time mixing
    for name in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
        pb.param(f"{prefix}.{name}", lead + (d,), llog + (None,), scale=0.0)
    for name in ("wr", "wk", "wv", "wg", "wo"):
        pb.param(f"{prefix}.{name}", lead + (d, d), llog + ("embed", "heads"))
    pb.param(f"{prefix}.w0", lead + (d,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.wA", lead + (d, W_LORA), llog + ("embed", None))
    pb.param(f"{prefix}.wB", lead + (W_LORA, d), llog + (None, "heads"))
    pb.param(f"{prefix}.u", lead + (h, dh), llog + (None, None), scale=0.1)
    pb.param(f"{prefix}.ln_x", lead + (d,), llog + (None,), scale=0.0)
    # channel mixing
    pb.param(f"{prefix}.mu_ck", lead + (d,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.mu_cr", lead + (d,), llog + (None,), scale=0.0)
    pb.param(f"{prefix}.ck", lead + (d, ff), llog + ("embed", "ff"))
    pb.param(f"{prefix}.cv", lead + (ff, d), llog + ("ff", "embed"))
    pb.param(f"{prefix}.cr", lead + (d, d), llog + ("embed", "heads"))


def _token_shift(x: jax.Array, last: Optional[jax.Array] = None) -> jax.Array:
    """Previous-token embedding; ``last`` carries across decode steps."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, : x.shape[1]]
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _mix(x, shifted, mu):
    return x + (shifted - x) * mu


def wkv_chunked(
    r: jax.Array,  # (B,T,H,D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # (B,T,H,D) log decay, <= 0
    u: jax.Array,     # (H,D)
    *,
    chunk: int,
    s_init: Optional[jax.Array] = None,   # (B,H,D,D) fp32
    return_state: bool = False,
    chunk_scan: bool = False,
):
    bsz, t, h, d = r.shape
    nchunks = -(-t // chunk)
    pad = nchunks * chunk - t
    if pad:
        r, k, v = (jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))) for x in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))

    state = s_init if s_init is not None else jnp.zeros((bsz, h, d, d), jnp.float32)
    uf = u.astype(jnp.float32)

    def one_chunk(rc, kc, vc, lw, state):
        cum = jnp.cumsum(lw, axis=1)                   # (B,C,H,D) non-increasing
        cum_prev = cum - lw                            # cumW_{i-1}
        # pairwise decays exp(cumW_{i-1} - cumW_j), j < i  (exponent <= 0)
        diff = cum_prev[:, :, None] - cum[:, None, :]  # (B,C,C,H,D)
        c = rc.shape[1]
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)[None, :, :, None, None]
        decay = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)

        scores = jnp.einsum("bihd,bijhd,bjhd->bhij", rc, decay, kc)
        y = jnp.einsum("bhij,bjhv->bihv", scores, vc)
        # diagonal (current token) bonus term
        coef = jnp.einsum("bihd,hd,bihd->bih", rc, uf, kc)
        y = y + coef[..., None] * vc
        # carry-in from previous chunks
        rin = rc * jnp.exp(cum_prev)
        y = y + jnp.einsum("bihd,bhdv->bihv", rin, state)

        # state update to end of chunk
        decay_end = jnp.exp(cum[:, -1:] - cum)         # (B,C,H,D) <= 1
        state = (
            jnp.exp(cum[:, -1])[..., None] * state   # (B,H,Dk,1) decay on k-dim
            + jnp.einsum("bihd,bihv->bhdv", kc * decay_end, vc)
        )
        return y, state

    if chunk_scan and nchunks > 1:
        def to_chunks(x):
            return x.reshape(bsz, nchunks, chunk, h, d).swapaxes(0, 1) \
                    .astype(jnp.float32)

        def body(st, xs):
            rc, kc, vc, lw = xs
            y, st = one_chunk(rc, kc, vc, lw, st)
            return st, y

        state, ys = jax.lax.scan(
            body, state, (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(logw)))
        out = ys.swapaxes(0, 1).reshape(bsz, nchunks * chunk, h, d)[:, :t]
    else:
        ys = []
        for ci in range(nchunks):  # python-unrolled (cost_analysis fidelity)
            sl = slice(ci * chunk, (ci + 1) * chunk)
            y, state = one_chunk(
                r[:, sl].astype(jnp.float32), k[:, sl].astype(jnp.float32),
                v[:, sl].astype(jnp.float32), logw[:, sl].astype(jnp.float32),
                state)
            ys.append(y)
        out = jnp.concatenate(ys, axis=1)[:, :t]
    if return_state:
        return out, state
    return out


def _decay_logw(p: Params, prefix: str, xw: jax.Array) -> jax.Array:
    """Data-dependent decay: w = exp(-exp(w0 + lora(xw))), returns log w."""
    lora = jnp.einsum("btd,dr->btr", xw, p[_k(prefix, "wA")])
    lora = jnp.einsum("btr,rd->btd", jnp.tanh(lora), p[_k(prefix, "wB")])
    return -jnp.exp(
        jnp.clip(p[_k(prefix, "w0")].astype(jnp.float32) + lora.astype(jnp.float32), -8.0, 4.0)
    )


def rwkv6_time_mix(
    p: Params, prefix: str, cfg: ModelConfig, x: jax.Array,
    *, chunk: int = 64,
    last_x: Optional[jax.Array] = None,
    s_init: Optional[jax.Array] = None,
    return_state: bool = False,
    pctx=None,
):
    bsz, t, d = x.shape
    h, dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    sx = _token_shift(x, last_x)
    xr = _mix(x, sx, p[_k(prefix, "mu_r")])
    xk = _mix(x, sx, p[_k(prefix, "mu_k")])
    xv = _mix(x, sx, p[_k(prefix, "mu_v")])
    xw = _mix(x, sx, p[_k(prefix, "mu_w")])
    xg = _mix(x, sx, p[_k(prefix, "mu_g")])

    r = jnp.einsum("btd,de->bte", xr, p[_k(prefix, "wr")]).reshape(bsz, t, h, dh)
    k = jnp.einsum("btd,de->bte", xk, p[_k(prefix, "wk")]).reshape(bsz, t, h, dh)
    v = jnp.einsum("btd,de->bte", xv, p[_k(prefix, "wv")]).reshape(bsz, t, h, dh)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p[_k(prefix, "wg")]))
    logw = _decay_logw(p, prefix, xw).reshape(bsz, t, h, dh)
    u = p[_k(prefix, "u")]

    # §Perf optimisation (optional): pad the head axis with inert zero heads
    # (k=0 -> no state update; r=0 -> no output; logw=0 -> w=1, stable) to a
    # TP multiple and pin the WKV computation head-sharded — removes the
    # per-op all-gathers GSPMD otherwise inserts because 40 % 16 != 0.
    # Parameters are untouched: pure compute-layout change.
    hp = h
    if cfg.rwkv_pad_heads_to:
        hp = -(-h // cfg.rwkv_pad_heads_to) * cfg.rwkv_pad_heads_to
        pad = ((0, 0), (0, 0), (0, hp - h), (0, 0))
        r, k, v, logw = (jnp.pad(a, pad) for a in (r, k, v, logw))
        u = jnp.pad(u, ((0, hp - h), (0, 0)))
        if pctx is not None and pctx.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = P(tuple(pctx.dp_axes), None, pctx.tp_axis, None)
            con = lambda a: jax.lax.with_sharding_constraint(
                a, NamedSharding(pctx.mesh, spec))
            r, k, v, logw = con(r), con(k), con(v), con(logw)
        if s_init is not None:
            s_init = jnp.pad(s_init, ((0, 0), (0, hp - h), (0, 0), (0, 0)))

    res = wkv_chunked(r, k, v, logw, u, chunk=chunk,
                      s_init=s_init, return_state=return_state,
                      chunk_scan=cfg.scan_layers and t > chunk)
    y, state = res if return_state else (res, None)
    if hp != h:
        y = y[:, :, :h]
        if state is not None:
            state = state[:, :h]
    y = y.reshape(bsz, t, d).astype(x.dtype)
    y = rms_norm(y, p[_k(prefix, "ln_x")] + 1.0, cfg.norm_eps) * g
    out = jnp.einsum("bte,ed->btd", y, p[_k(prefix, "wo")])
    if return_state:
        return out, x[:, -1], state
    return out


def rwkv6_channel_mix(p: Params, prefix: str, cfg: ModelConfig, x: jax.Array,
                      last_x: Optional[jax.Array] = None,
                      return_last: bool = False):
    sx = _token_shift(x, last_x)
    xk = _mix(x, sx, p[_k(prefix, "mu_ck")])
    xr = _mix(x, sx, p[_k(prefix, "mu_cr")])
    kk = jnp.einsum("btd,df->btf", xk, p[_k(prefix, "ck")])
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("btf,fd->btd", kk, p[_k(prefix, "cv")])
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p[_k(prefix, "cr")]))
    out = rr * vv
    if return_last:
        return out, x[:, -1]
    return out
