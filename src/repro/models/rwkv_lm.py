"""RWKV-6 language model (the assigned attention-free `ssm`-family arch)."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import ParallelContext
from .layers import ParamBuilder, Params, mask_vocab_logits, rms_norm
from .paged_state import gather_state, scatter_state, split_state_tables
from .rwkv import (rwkv6_channel_mix, rwkv6_time_mix, rwkv_params,
                   wkv_chunked, _decay_logw, _mix, _token_shift)


def build_params(cfg: ModelConfig) -> ParamBuilder:
    pb = ParamBuilder(dtype=jnp.bfloat16)
    d = cfg.d_model
    pb.param("embed", (cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02)
    rwkv_params(pb, "blk", cfg, cfg.num_layers)
    pb.param("blk.ln1", (cfg.num_layers, d), ("layers", None), scale=0.0)
    pb.param("blk.ln2", (cfg.num_layers, d), ("layers", None), scale=0.0)
    pb.param("final_norm", (d,), (None,), scale=0.0)
    pb.param("lm_head", (d, cfg.padded_vocab), ("embed", "vocab"))
    return pb


def _layer(cfg: ModelConfig, x, lp, chunk: int, pctx=None):
    h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
    x = x + rwkv6_time_mix(lp, "", cfg, h, chunk=chunk, pctx=pctx)
    h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
    x = x + rwkv6_channel_mix(lp, "", cfg, h)
    return x


def rwkv_forward(params: Params, cfg: ModelConfig, pctx: ParallelContext,
                 tokens: jax.Array, *, scan_layers: bool = True,
                 chunk: int = 64) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    blk = {k[len("blk."):]: v for k, v in params.items() if k.startswith("blk.")}
    if cfg.remat:
        run = jax.checkpoint(
            lambda xx, lp: _layer(cfg, xx, lp, chunk, pctx),
            policy=jax.checkpoint_policies.nothing_saveable,
        )
    else:
        run = lambda xx, lp: _layer(cfg, xx, lp, chunk, pctx)
    if scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (run(c, lp), None), x, blk)
    else:
        for i in range(cfg.num_layers):
            x = run(x, jax.tree.map(lambda a: a[i], blk))
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    return mask_vocab_logits(jnp.einsum("btd,dv->btv", x, params["lm_head"]), cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving: state-passing prefill + O(1) decode.
# ---------------------------------------------------------------------------


def init_state_abstract(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    h, dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    L = cfg.num_layers
    return {
        "tmix_x": jax.ShapeDtypeStruct((L, batch, d), jnp.bfloat16),
        "cmix_x": jax.ShapeDtypeStruct((L, batch, d), jnp.bfloat16),
        "wkv": jax.ShapeDtypeStruct((L, batch, h, dh, dh), jnp.float32),
    }


def init_state(cfg: ModelConfig, batch: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_state_abstract(cfg, batch))


def rwkv_decode_step(
    params: Params, cfg: ModelConfig, pctx: ParallelContext,
    state: Dict[str, jax.Array], tokens: jax.Array, lengths=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """tokens: (B, 1).  Attention-free: decode cost independent of context
    length (the long_500k cell exercises exactly this)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    blk = {k[len("blk."):]: v for k, v in params.items() if k.startswith("blk.")}

    def body(carry, xs):
        x = carry
        lp, tmx, cmx, wkv = xs
        h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
        out, new_tmx, new_wkv = rwkv6_time_mix(
            lp, "", cfg, h, chunk=1, last_x=tmx, s_init=wkv, return_state=True
        )
        x = x + out
        h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
        out, new_cmx = rwkv6_channel_mix(lp, "", cfg, h, last_x=cmx, return_last=True)
        x = x + out
        return x, (new_tmx.astype(jnp.bfloat16), new_cmx.astype(jnp.bfloat16), new_wkv)

    xs_tree = (blk, state["tmix_x"], state["cmix_x"], state["wkv"])
    if cfg.scan_layers:
        x, (tmix_x, cmix_x, wkv) = jax.lax.scan(body, x, xs_tree)
    else:  # unrolled (cost-extrapolation dry-run compiles)
        ys = []
        for i in range(cfg.num_layers):
            x, y = body(x, jax.tree.map(lambda a: a[i], xs_tree))
            ys.append(y)
        tmix_x = jnp.stack([y[0] for y in ys])
        cmix_x = jnp.stack([y[1] for y in ys])
        wkv = jnp.stack([y[2] for y in ys])
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = mask_vocab_logits(jnp.einsum("btd,dv->btv", x, params["lm_head"]), cfg.vocab_size)
    return logits, {"tmix_x": tmix_x, "cmix_x": cmix_x, "wkv": wkv}


def rwkv_prefill(
    params: Params, cfg: ModelConfig, pctx: ParallelContext,
    tokens: jax.Array, *, scan_layers: bool = True, chunk: int = 64,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = jnp.take(params["embed"], tokens, axis=0)
    blk = {k[len("blk."):]: v for k, v in params.items() if k.startswith("blk.")}

    def body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
        out, tmx, wkv = rwkv6_time_mix(lp, "", cfg, h, chunk=chunk, return_state=True, pctx=pctx)
        x = x + out
        h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
        out, cmx = rwkv6_channel_mix(lp, "", cfg, h, return_last=True)
        x = x + out
        return x, (tmx.astype(jnp.bfloat16), cmx.astype(jnp.bfloat16), wkv)

    if scan_layers:
        x, (tmix_x, cmix_x, wkv) = jax.lax.scan(body, x, blk)
    else:
        outs = []
        for i in range(cfg.num_layers):
            x, o = body(x, jax.tree.map(lambda a: a[i], blk))
            outs.append(o)
        tmix_x = jnp.stack([o[0] for o in outs])
        cmix_x = jnp.stack([o[1] for o in outs])
        wkv = jnp.stack([o[2] for o in outs])
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = mask_vocab_logits(jnp.einsum("btd,dv->btv", x[:, -1:], params["lm_head"]), cfg.vocab_size)
    return logits, {"tmix_x": tmix_x, "cmix_x": cmix_x, "wkv": wkv}

# ---------------------------------------------------------------------------
# Paged serving: state pools behind the StateCache contract.
# ---------------------------------------------------------------------------


def init_paged_state_abstract(cfg: ModelConfig, state_slots: int,
                              state_dtype: str = "float32"):
    """State pools with the physical state slot as axis 1 (the engine's
    copy convention; ``repro.serve.state_cache``).  ``state_dtype="int8"``
    stores the wkv matrices int8 with per-(layer, slot, head) scales."""
    d = cfg.d_model
    h, dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    L, S = cfg.num_layers, state_slots
    pools = {
        "tmix_x": jax.ShapeDtypeStruct((L, S, d), jnp.bfloat16),
        "cmix_x": jax.ShapeDtypeStruct((L, S, d), jnp.bfloat16),
    }
    if state_dtype == "int8":
        pools["wkv"] = jax.ShapeDtypeStruct((L, S, h, dh, dh), jnp.int8)
        pools["wkv_scale"] = jax.ShapeDtypeStruct((L, S, h), jnp.float32)
    else:
        pools["wkv"] = jax.ShapeDtypeStruct((L, S, h, dh, dh), jnp.float32)
    return pools


def init_paged_state(cfg: ModelConfig, state_slots: int,
                     state_dtype: str = "float32"):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_paged_state_abstract(cfg, state_slots,
                                                  state_dtype))


def rwkv_decode_paged(params: Params, cfg: ModelConfig, cache,
                      tokens: jax.Array, lengths: jax.Array,
                      new_counts: jax.Array, block_tables: jax.Array,
                      pctx: ParallelContext):
    """Paged decode/prefill chunk: gather state at the read column, run the
    *same* per-token recurrence as the slot engine (so greedy outputs are
    bit-identical), scatter the post-token state to each write column.
    Padded positions write to the trash slot; their logits rows are
    discarded by the caller."""
    _, read, writes = split_state_tables(block_tables, tokens.shape[1])
    state = gather_state(cache, read)
    outs = []
    for t in range(tokens.shape[1]):
        logits, state = rwkv_decode_step(params, cfg, pctx, state,
                                         tokens[:, t:t + 1])
        cache = scatter_state(cache, state, writes[:, t])
        outs.append(logits)
    return jnp.concatenate(outs, axis=1), cache
