"""Model bundle: family -> (params, logical axes, forward/prefill/decode).

Every entry point takes a ``ParallelContext`` so the identical code runs on
one CPU device (smoke tests / examples) and on the 512-chip production mesh
(dry-run / launcher).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import ParallelContext
from . import encdec, hybrid, lm, rwkv_lm, ssm
from .layers import ParamBuilder

#: Families whose decode state is a growing KV sequence served by the
#: repro.models.lm path — the ones that page their cache (and hence the
#: ones speculative decoding can target with a *model* draft).  Single
#: source of truth for the dispatch sites and capability checks below.
_LM_FAMILIES = ("dense", "moe", "vlm")

#: Families whose decode state is a fixed-size recurrent register file
#: served through the paged *state* cache (repro.serve.state_cache):
#: rwkv6 (ssm), pure mamba2 (mamba), and zamba2 (hybrid — attention KV
#: pages plus mamba state slots in the same cache).
_STATE_FAMILIES = ("ssm", "mamba", "hybrid")


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    builder: ParamBuilder

    def init_params(self, key) -> Dict[str, jax.Array]:
        return self.builder.build(key)

    def abstract_params(self):
        return self.builder.abstract()

    def logical_axes(self):
        return self.builder.logical_axes()

    # ---- entry points --------------------------------------------------
    def forward(self, params, batch: Dict[str, Any], pctx: ParallelContext,
                *, scan_layers: bool | None = None) -> jax.Array:
        cfg = self.cfg
        if scan_layers is None:
            scan_layers = cfg.scan_layers
        if cfg.family in ("dense", "moe"):
            return lm.lm_forward(params, cfg, pctx, batch["tokens"],
                                 scan_layers=scan_layers)
        if cfg.family == "vlm":
            return lm.lm_forward(params, cfg, pctx, batch["tokens"],
                                 prefix_embeds=batch["vision_embeds"],
                                 scan_layers=scan_layers)
        if cfg.family == "audio":
            return encdec.encdec_forward(params, cfg, pctx, batch["tokens"],
                                         batch["frames"], scan_layers=scan_layers)
        if cfg.family == "ssm":
            return rwkv_lm.rwkv_forward(params, cfg, pctx, batch["tokens"],
                                        scan_layers=scan_layers)
        if cfg.family == "mamba":
            return ssm.mamba_forward(params, cfg, pctx, batch["tokens"],
                                     scan_layers=scan_layers)
        if cfg.family == "hybrid":
            return hybrid.hybrid_forward(params, cfg, pctx, batch["tokens"],
                                         scan_layers=scan_layers)
        raise ValueError(cfg.family)

    def prefill(self, params, batch: Dict[str, Any], pctx: ParallelContext,
                *, max_seq: Optional[int] = None, scan_layers: bool | None = None):
        cfg = self.cfg
        if scan_layers is None:
            scan_layers = cfg.scan_layers
        if cfg.family in ("dense", "moe"):
            return lm.lm_prefill(params, cfg, pctx, batch["tokens"],
                                 max_seq=max_seq, scan_layers=scan_layers)
        if cfg.family == "vlm":
            return lm.lm_prefill(params, cfg, pctx, batch["tokens"],
                                 max_seq=max_seq,
                                 prefix_embeds=batch["vision_embeds"],
                                 scan_layers=scan_layers)
        if cfg.family == "audio":
            return encdec.encdec_prefill(params, cfg, pctx, batch["tokens"],
                                         batch["frames"],
                                         max_seq or batch["tokens"].shape[1],
                                         scan_layers=scan_layers)
        if cfg.family == "ssm":
            return rwkv_lm.rwkv_prefill(params, cfg, pctx, batch["tokens"],
                                        scan_layers=scan_layers)
        if cfg.family == "mamba":
            return ssm.mamba_prefill(params, cfg, pctx, batch["tokens"],
                                     scan_layers=scan_layers)
        if cfg.family == "hybrid":
            # hybrid prefill = forward + state build; decode-path states are
            # produced by running decode over the prompt in serving; for the
            # prefill shape cell we lower the forward (cost-equivalent).
            logits = hybrid.hybrid_forward(params, cfg, pctx, batch["tokens"],
                                           scan_layers=scan_layers)
            return logits[:, -1:], None
        raise ValueError(cfg.family)

    def decode_step(self, params, cache, tokens, lengths, pctx: ParallelContext):
        cfg = self.cfg
        if cfg.family in _LM_FAMILIES:
            return lm.lm_decode_step(params, cfg, pctx, cache, tokens, lengths)
        if cfg.family == "audio":
            return encdec.encdec_decode_step(params, cfg, pctx, cache, tokens, lengths)
        if cfg.family == "ssm":
            return rwkv_lm.rwkv_decode_step(params, cfg, pctx, cache, tokens, lengths)
        if cfg.family == "mamba":
            return ssm.mamba_decode_step(params, cfg, pctx, cache, tokens, lengths)
        if cfg.family == "hybrid":
            return hybrid.hybrid_decode_step(params, cfg, pctx, cache, tokens, lengths)
        raise ValueError(cfg.family)

    # ---- multi-precision (repro.quant) ---------------------------------
    # Int8 weight variants are supported for every family whose weight
    # einsums route through the dequant-aware helpers in models/layers.py
    # (dense/moe/vlm/audio).  The recurrent-state families (ssm/hybrid)
    # keep bespoke mixer einsums and are out of scope for now — see
    # docs/quantization.md.

    @property
    def supports_int8_weights(self) -> bool:
        return self.cfg.family in ("dense", "moe", "vlm", "audio")

    def quantize_params(self, params):
        """Int8-weight variant of ``params`` (symmetric per-output-channel;
        embeddings / norms / MoE router stay full precision).  The returned
        dict is a drop-in replacement for every entry point above."""
        if not self.supports_int8_weights:
            raise ValueError(
                f"{self.cfg.family!r} family has no int8-weight path; see "
                "docs/quantization.md for scope")
        from ..quant import quantize_params
        return quantize_params(params)

    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        if cfg.family in _LM_FAMILIES:
            return lm.init_cache(cfg, batch, max_seq)
        if cfg.family == "audio":
            return encdec.init_cache(cfg, batch, max_seq)
        if cfg.family == "ssm":
            return rwkv_lm.init_state(cfg, batch)
        if cfg.family == "mamba":
            return ssm.init_lm_state(cfg, batch)
        if cfg.family == "hybrid":
            return hybrid.init_state(cfg, batch, max_seq)
        raise ValueError(cfg.family)

    # ---- paged serving contract ---------------------------------------
    # Families whose decode state is a growing KV sequence page it through
    # PagedKVCache; the recurrent-state families page their fixed-size
    # state through the StateCache (hybrid uses both).  Audio (enc-dec
    # cross-attention cache) stays on the contiguous slot engine.

    @property
    def supports_paged_kv(self) -> bool:
        return self.cfg.family in _LM_FAMILIES

    @property
    def supports_paged_state(self) -> bool:
        return self.cfg.family in _STATE_FAMILIES

    @property
    def supports_paged_serving(self) -> bool:
        return self.supports_paged_kv or self.supports_paged_state

    def init_paged_cache(self, pool_pages: int, page_size: int,
                         kv_dtype: str = "bfloat16", *, state_slots: int = 0,
                         state_dtype: str = "float32"):
        """Paged cache pools.  For the KV families: shared page pools of
        shape (n_sb, me, pool_pages, page_size, Hkv, Dh) per tensor, where
        ``pool_pages`` includes the reserved null page 0 (see
        repro.serve.paged_cache.PagedKVCache.pool_pages) and
        ``kv_dtype="int8"`` adds per-(page slot, head) fp32 scale pools
        (docs/quantization.md).  For the recurrent-state families: state
        pools with the physical state slot at axis 1, ``state_slots`` =
        StateCache.pool_slots (reserved null/trash ids included) and
        ``state_dtype="int8"`` storing the large running-state leaves int8
        (repro.models.paged_state).  Hybrid caches hold both kinds."""
        cfg = self.cfg
        if cfg.family in _LM_FAMILIES:
            return lm.init_paged_cache(cfg, pool_pages, page_size,
                                       kv_dtype=kv_dtype)
        if cfg.family == "ssm":
            return rwkv_lm.init_paged_state(cfg, state_slots, state_dtype)
        if cfg.family == "mamba":
            return ssm.init_paged_state(cfg, state_slots, state_dtype)
        if cfg.family == "hybrid":
            return hybrid.init_paged_cache(cfg, pool_pages, page_size,
                                           kv_dtype, state_slots,
                                           state_dtype)
        raise ValueError(
            f"{cfg.family!r} family has no paged KV cache or state pool; "
            "use init_cache / the contiguous slot engine")

    def decode_paged(self, params, cache, tokens, lengths, new_counts,
                     block_tables, pctx: ParallelContext):
        """Multi-token paged decode/prefill step: tokens (B, T); T=1 is
        the decode tick, T=chunk is chunked prefill.  For the recurrent
        families ``block_tables`` is the engine's *combined* table — KV
        page columns, then one state read column, then T state write
        columns (repro.models.paged_state.split_state_tables)."""
        cfg = self.cfg
        if cfg.family in _LM_FAMILIES:
            return lm.lm_decode_paged(params, cfg, pctx, cache, tokens,
                                      lengths, new_counts, block_tables)
        if cfg.family == "ssm":
            return rwkv_lm.rwkv_decode_paged(params, cfg, cache, tokens,
                                             lengths, new_counts,
                                             block_tables, pctx)
        if cfg.family == "mamba":
            return ssm.mamba_decode_paged(params, cfg, cache, tokens,
                                          lengths, new_counts, block_tables,
                                          pctx)
        if cfg.family == "hybrid":
            return hybrid.hybrid_decode_paged(params, cfg, cache, tokens,
                                              lengths, new_counts,
                                              block_tables, pctx)
        raise ValueError(
            f"{cfg.family!r} family has no paged decode path")


def check_draft_pair(target: ModelConfig, draft: ModelConfig) -> None:
    """Validate a (target, draft) speculative-decoding pairing.

    Greedy verification compares the draft's proposed token *ids* against
    the target's argmax, so the two models must share one tokenizer — the
    config-level proxy is an identical ``vocab_size`` (a draft with a
    different vocabulary would propose ids that mean different strings,
    silently destroying acceptance).  Both sides must also speak the paged
    decode contract: the target verifies through ``decode_paged`` and a
    model-backed draft keeps its own paged cache in lockstep (rollback via
    ``PagedKVCache.truncate``).
    """
    if draft.vocab_size != target.vocab_size:
        raise ValueError(
            f"draft {draft.name!r} (vocab {draft.vocab_size}) does not share "
            f"target {target.name!r}'s tokenizer (vocab {target.vocab_size}); "
            "speculative verification compares token ids, so the pair must "
            "use one vocabulary")
    for role, cfg in (("target", target), ("draft", draft)):
        if cfg.family not in _LM_FAMILIES:
            raise ValueError(
                f"{role} {cfg.name!r} ({cfg.family!r} family) has no paged "
                "KV cache; speculative decoding runs on the paged engine")


def build_draft_model(target: ModelConfig, draft: ModelConfig) -> ModelBundle:
    """Build the draft-side :class:`ModelBundle` for speculative decoding,
    after :func:`check_draft_pair` validates the pairing."""
    check_draft_pair(target, draft)
    return build_model(draft)


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family in _LM_FAMILIES:
        builder = lm.build_params(cfg)
    elif cfg.family == "audio":
        builder = encdec.build_params(cfg)
    elif cfg.family == "ssm":
        builder = rwkv_lm.build_params(cfg)
    elif cfg.family == "mamba":
        builder = ssm.build_lm_params(cfg)
    elif cfg.family == "hybrid":
        builder = hybrid.build_params(cfg)
    else:
        raise ValueError(cfg.family)
    return ModelBundle(cfg=cfg, builder=builder)
