"""Mixture-of-Experts layer with expert parallelism.

Production dispatch path (DeepSpeed-MoE / MaxText style):

* experts are sharded over the ``data`` mesh axis (EP group = one pod's DP
  slice; experts replicate across pods so MoE all-to-alls never cross the
  slow pod links — gradients do, once per step);
* within each expert the FFN is tensor-sharded over ``model`` with an
  explicit psum on the down projection (``tp_einsum`` under ``manual_tp``
  — the region is manual over *every* mesh axis, because partial-manual
  shard_map does not compile on the image's jax; see
  docs/known_failures.md);
* routing is local, capacity-bounded (drops), dispatch/return via
  ``lax.all_to_all`` on the ``data`` axis.

The same math runs without a mesh (single-device smoke path) by skipping
the all-to-alls — ``ep_degree=1``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel import compat
from . import layers
from .layers import ParamBuilder, Params


def moe_params(pb: ParamBuilder, prefix: str, cfg: ModelConfig, layers: Optional[int]):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    lead = () if layers is None else (layers,)
    llog = () if layers is None else ("layers",)
    pb.param(f"{prefix}.router", lead + (d, e), llog + ("embed", None))
    pb.param(f"{prefix}.w_gate", lead + (e, d, ff), llog + ("experts", "embed", "ff"))
    pb.param(f"{prefix}.w_up", lead + (e, d, ff), llog + ("experts", "embed", "ff"))
    pb.param(f"{prefix}.w_down", lead + (e, ff, d), llog + ("experts", "ff", "embed"))
    if cfg.dense_residual_ff:
        fr = cfg.dense_residual_ff
        pb.param(f"{prefix}.res_gate", lead + (d, fr), llog + ("embed", "ff"))
        pb.param(f"{prefix}.res_up", lead + (d, fr), llog + ("embed", "ff"))
        pb.param(f"{prefix}.res_down", lead + (fr, d), llog + ("ff", "embed"))


def _capacity(tokens_local: int, cfg: ModelConfig, ep: int) -> int:
    per_expert = tokens_local * cfg.experts_per_token / max(cfg.num_experts, 1)
    return max(1, int(per_expert * cfg.moe_capacity_factor + 0.999))


def _route_and_dispatch(x, router_w, cfg: ModelConfig, capacity: int):
    """Local routing: returns (gathered (E, C, d), combine metadata)."""
    t, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = jnp.einsum("td,de->te", x, router_w).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(gates, k)                  # (t, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    flat_sel = sel.reshape(-1)                              # (t*k,)
    # position of each dispatch within its expert's queue
    order = jnp.argsort(flat_sel, stable=True)
    counts = jnp.bincount(flat_sel, length=e)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(t * k) - starts[flat_sel[order]]
    rank = jnp.zeros(t * k, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    keep = rank < capacity                                  # dropped beyond C
    slot = flat_sel * capacity + jnp.where(keep, rank, 0)   # (t*k,)
    token_id = jnp.repeat(jnp.arange(t), k)

    # scatter tokens into the (E*C, d) dispatch buffer; dropped dispatches
    # land in a trash row that is sliced off.
    buf = jnp.zeros((e * capacity + 1, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, e * capacity)].add(
        jnp.where(keep[:, None], x[token_id], 0).astype(x.dtype)
    )
    buf = buf[: e * capacity]
    meta = (token_id, slot, keep, weights.reshape(-1).astype(x.dtype))
    return buf.reshape(e, capacity, d), meta


def _combine(expert_out, meta, t: int):
    """Weighted scatter-add of expert outputs back to token order."""
    e, c, d = expert_out.shape
    token_id, slot, keep, w = meta
    vals = expert_out.reshape(e * c, d)[jnp.where(keep, slot, 0)]
    vals = jnp.where(keep[:, None], vals, 0) * w[:, None]
    return jnp.zeros((t, d), expert_out.dtype).at[token_id].add(vals)


def _expert_ffn(p: Params, prefix: str, xs: jax.Array, cfg=None) -> jax.Array:
    """xs: (E_local, C_total, d) -> same; per-expert SwiGLU."""
    from .layers import materialize_weight, tp_einsum
    g = jnp.einsum("ecd,edf->ecf", xs,
                   materialize_weight(p[f"{prefix}.w_gate"], xs.dtype))
    u = jnp.einsum("ecd,edf->ecf", xs,
                   materialize_weight(p[f"{prefix}.w_up"], xs.dtype))
    return tp_einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p[f"{prefix}.w_down"], cfg)


def moe_ffn_local(p: Params, prefix: str, cfg: ModelConfig, x: jax.Array,
                  ep_axis: Optional[str] = None) -> jax.Array:
    """MoE FFN over local tokens x: (T_local, d).

    With ``ep_axis`` set (inside shard_map), expert weights arrive sliced to
    E_local = E/ep on axis 0 and tokens are exchanged with two all-to-alls.
    """
    t = x.shape[0]
    ep = compat.axis_size(ep_axis) if ep_axis else 1
    cap = _capacity(t, cfg, ep)
    dispatched, meta = _route_and_dispatch(x, p[f"{prefix}.router"], cfg, cap)

    if ep_axis:
        # (E, C, d) -> (E_local, ep*C, d): each shard keeps its own experts'
        # queues from every peer.
        dispatched = jax.lax.all_to_all(
            dispatched, ep_axis, split_axis=0, concat_axis=1, tiled=True
        )
        # named so remat_policy="save_coll" avoids re-running the all-to-all
        # during backward recompute (§Perf iteration, arctic cell)
        dispatched = jax.ad_checkpoint.checkpoint_name(dispatched, "moe_a2a")
    out = _expert_ffn(p, prefix, dispatched, cfg)
    if ep_axis:
        out = jax.lax.all_to_all(out, ep_axis, split_axis=1, concat_axis=0, tiled=True)
        out = jax.ad_checkpoint.checkpoint_name(out, "moe_a2a")
    return _combine(out, meta, t)


def moe_block(p: Params, prefix: str, cfg: ModelConfig, x: jax.Array,
              pctx=None) -> jax.Array:
    """x: (B, T, d).  Runs the EP path under a fully-manual shard_map when a
    mesh with a >1 EP axis is provided, else the single-shard path (same
    math).  Fully manual means every mesh axis is explicit here: experts
    shard over the EP axis, each expert's ff dim shards over the TP axis
    (when divisible) with :func:`~.layers.tp_einsum` psumming the down
    projection under :func:`~.layers.manual_tp`, and any remaining axes
    (pods) see replicated weights and tokens — nothing is left for GSPMD,
    which is what lets this compile on jax without partial-manual support
    (docs/known_failures.md)."""
    b, t, d = x.shape
    flat = x.reshape(b * t, d)

    # res_* (arctic's parallel dense MLP) runs outside the region, below
    moe_keys = [k for k in p if k.startswith(prefix + ".") and ".res_" not in k]
    sub = {k: p[k] for k in moe_keys}

    mesh = pctx.mesh if pctx is not None else None
    if (mesh is not None and pctx.ep_axis in mesh.axis_names
            and mesh.shape[pctx.ep_axis] > 1):
        P = jax.sharding.PartitionSpec
        ep_axis = pctx.ep_axis
        tp_axis = pctx.tp_axis if pctx.tp_axis in mesh.axis_names else None
        shard_ff = (tp_axis is not None and mesh.shape[tp_axis] > 1
                    and cfg.d_ff % mesh.shape[tp_axis] == 0)
        ff_ax = tp_axis if shard_ff else None

        def spec_for(k):
            if ".router" in k:
                return P()                      # replicated everywhere
            if ".w_down" in k:
                return P(ep_axis, ff_ax, None)  # (E, ff, d)
            return P(ep_axis, None, ff_ax)      # w_gate/w_up (E, d, ff)

        tp_deg = mesh.shape[tp_axis] if shard_ff else 1

        def body(sp, xl):
            with layers.manual_tp(ff_ax, tp_deg):
                return moe_ffn_local(sp, prefix, cfg, xl, ep_axis=ep_axis)

        out = compat.shard_map(
            body,
            mesh=mesh,
            in_specs=({k: spec_for(k) for k in sub}, P(tuple(pctx.dp_axes))),
            out_specs=P(tuple(pctx.dp_axes)),
            check_vma=False,
        )(sub, flat)
    else:
        out = moe_ffn_local(sub, prefix, cfg, flat, ep_axis=None)

    out = out.reshape(b, t, d)
    if cfg.dense_residual_ff:
        from .layers import swiglu
        out = out + swiglu(x, p[f"{prefix}.res_gate"], p[f"{prefix}.res_up"],
                           p[f"{prefix}.res_down"], cfg)
    return out
