"""Device-side paged recurrent-state pools.

Shared by the rwkv6 / mamba2 / zamba2 paged decode paths: every state leaf
in a paged cache is a pool with the *physical state slot* as axis 1
(``(layers, pool_slots, ...)``), indexed by the read/write columns the
engine appends to the block table (``repro.serve.state_cache``).  The
helpers here gather a batch's state out of the pools, scatter post-token
state back in, and split the combined block table the engine builds:

    [ KV page table (width P) | state read col (1) | write cols (T) ]

The model derives the split purely from shapes (``P = width - 1 - T``), so
the same jitted ``decode_paged`` signature serves attention, recurrent,
and hybrid families.

int8 state storage (``state_dtype="int8"``): the large running-reduction
leaves (``wkv``, ``ssm``) are stored int8 with a per-(layer, slot, head)
symmetric scale, quantized on scatter and dequantized on gather — the APR
analogue of SPEED's multi-precision lanes.  The small leaves (conv window,
token-shift rows) stay in their native dtype; unlike int8 *KV*, int8
*state* is lossy across steps (the state is re-quantized every token), so
it trades accuracy for a ~4x pool-byte cut and is not token-identity
gated.
"""
from __future__ import annotations

import jax.numpy as jnp

#: cache keys that are state pools (slot axis 1); everything else in a
#: paged cache is a KV page pool (page axis 2) — the engine's copy
#: choreography dispatches on this split
STATE_POOL_KEYS = frozenset({
    "tmix_x", "cmix_x", "wkv", "wkv_scale", "conv", "ssm", "ssm_scale",
})

#: state leaves eligible for int8 storage (scale key = f"{key}_scale")
INT8_STATE_KEYS = ("wkv", "ssm")


def split_state_tables(block_tables, t: int):
    """``(kv_tables, read_ids, write_ids)`` from a combined table whose
    last ``1 + t`` columns are the state read column and ``t`` per-token
    write columns.  ``kv_tables`` is empty-width for pure recurrent
    families (the engine still ledgers their tokens through the KV block
    table, but the model never looks at pages)."""
    kv_w = block_tables.shape[1] - 1 - t
    return (block_tables[:, :kv_w], block_tables[:, kv_w],
            block_tables[:, kv_w + 1:])


def _quantize(v):
    """Symmetric int8 over the trailing two axes; scale has their shape
    dropped (per layer/row/head)."""
    amax = jnp.max(jnp.abs(v), axis=(-2, -1))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(v / scale[..., None, None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def gather_state(cache, ids):
    """Gather per-sequence state ``{key: (layers, B, ...)}`` from the
    pools at physical slot ``ids (B,)``, dequantizing int8 leaves."""
    state = {}
    for k, pool in cache.items():
        if k not in STATE_POOL_KEYS or k.endswith("_scale"):
            continue
        if k in INT8_STATE_KEYS and f"{k}_scale" in cache:
            scale = cache[f"{k}_scale"][:, ids]
            state[k] = pool[:, ids].astype(jnp.float32) \
                * scale[..., None, None]
        else:
            state[k] = pool[:, ids]
    return state

def scatter_state(cache, state, ids):
    """Scatter post-token state back into the pools at slot ``ids (B,)``
    (quantizing int8 leaves), returning the updated cache.  Padded rows
    target ``TRASH_STATE``; duplicate trash writes race benignly (the sink
    is never read)."""
    new = dict(cache)
    for k, v in state.items():
        if k in INT8_STATE_KEYS and f"{k}_scale" in cache:
            q, scale = _quantize(v)
            new[k] = cache[k].at[:, ids].set(q)
            new[f"{k}_scale"] = cache[f"{k}_scale"].at[:, ids].set(scale)
        else:
            new[k] = cache[k].at[:, ids].set(v.astype(cache[k].dtype))
    return new


def copy_state_slot(cache, src: int, dst: int):
    """Copy one physical state slot across every state leaf (KV page
    pools untouched) — the engine's mirror for ``pop_state_copies``."""
    return {k: (a.at[:, dst].set(a[:, src]) if k in STATE_POOL_KEYS else a)
            for k, a in cache.items()}


def copy_kv_page(cache, src: int, dst: int):
    """Copy one physical KV page across every page-pool leaf (state pools
    untouched; page axis is 2 on every KV leaf)."""
    return {k: (a if k in STATE_POOL_KEYS else a.at[:, :, dst].set(a[:, :, src]))
            for k, a in cache.items()}
