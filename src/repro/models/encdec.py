"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, F, d) and the encoder transformer runs on
them directly.  Decoder: causal self-attention + cross-attention into the
encoder output.  Whisper uses absolute positions; we keep RoPE off
(apply_rope=False) and add learned positional embeddings.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import ParallelContext
from .layers import (ParamBuilder, Params, attention, attention_decode,
                     attn_params, mask_vocab_logits, materialize_weight,
                     project_qkv, gqa_scores_attend, rms_norm)


def gelu_mlp_params(pb: ParamBuilder, prefix: str, cfg: ModelConfig, layers: int):
    d, ff = cfg.d_model, cfg.d_ff
    pb.param(f"{prefix}.w1", (layers, d, ff), ("layers", "embed", "ff"))
    pb.param(f"{prefix}.w2", (layers, ff, d), ("layers", "ff", "embed"))


def gelu_mlp(lp: Params, prefix: str, x: jax.Array) -> jax.Array:
    w1 = materialize_weight(lp[f"{prefix}.w1"], x.dtype)
    w2 = materialize_weight(lp[f"{prefix}.w2"], x.dtype)
    h = jax.nn.gelu(jnp.einsum("btd,df->btf", x, w1))
    return jnp.einsum("btf,fd->btd", h, w2)


def build_params(cfg: ModelConfig) -> ParamBuilder:
    pb = ParamBuilder(dtype=jnp.bfloat16)
    d = cfg.d_model
    le, ld = cfg.encoder_layers, cfg.num_layers
    pb.param("embed", (cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02)
    pb.param("pos_dec", (32768, d), (None, "embed"), scale=0.02)
    pb.param("pos_enc", (cfg.encoder_frames, d), (None, "embed"), scale=0.02)
    # encoder
    attn_params(pb, "enc.attn", cfg, le)
    gelu_mlp_params(pb, "enc.mlp", cfg, le)
    pb.param("enc.ln1", (le, d), ("layers", None), scale=0.0)
    pb.param("enc.ln2", (le, d), ("layers", None), scale=0.0)
    pb.param("enc_final", (d,), (None,), scale=0.0)
    # decoder
    attn_params(pb, "dec.self", cfg, ld)
    attn_params(pb, "dec.cross", cfg, ld)
    gelu_mlp_params(pb, "dec.mlp", cfg, ld)
    pb.param("dec.ln1", (ld, d), ("layers", None), scale=0.0)
    pb.param("dec.ln2", (ld, d), ("layers", None), scale=0.0)
    pb.param("dec.ln3", (ld, d), ("layers", None), scale=0.0)
    pb.param("final_norm", (d,), (None,), scale=0.0)
    pb.param("lm_head", (d, cfg.padded_vocab), ("embed", "vocab"))
    return pb


def _grp(params: Params, prefix: str) -> Params:
    return {k[len(prefix):]: v for k, v in params.items() if k.startswith(prefix)}


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           *, scan_layers: bool = True) -> jax.Array:
    """frames: (B, F, d) stub embeddings -> encoder output (B, F, d)."""
    f = frames.shape[1]
    x = frames.astype(jnp.bfloat16) + params["pos_enc"][None, :f]
    enc = _grp(params, "enc.")

    def body(x, lp):
        h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
        x = x + attention(lp, "attn", cfg, h, causal=False, apply_rope=False)
        h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
        return x + gelu_mlp(lp, "mlp", h)

    run = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else body
    if scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (run(c, lp), None), x, enc)
    else:
        for i in range(cfg.encoder_layers):
            x = run(x, jax.tree.map(lambda a: a[i], enc))
    return rms_norm(x, params["enc_final"] + 1.0, cfg.norm_eps)


def encdec_forward(params: Params, cfg: ModelConfig, pctx: ParallelContext,
                   tokens: jax.Array, frames: jax.Array,
                   *, scan_layers: bool = True) -> jax.Array:
    """Teacher-forced training forward: logits over decoder positions."""
    enc_out = encode(params, cfg, frames, scan_layers=scan_layers)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_dec"][None, :s]
    dec = _grp(params, "dec.")

    def body(x, lp):
        h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
        x = x + attention(lp, "self", cfg, h, causal=True, apply_rope=False)
        h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
        q, _, _ = project_qkv(lp, "cross", cfg, h, None, apply_rope=False)
        kc = jnp.einsum("bfd,dk->bfk", enc_out, materialize_weight(lp["cross.wk"], x.dtype))
        vc = jnp.einsum("bfd,dk->bfk", enc_out, materialize_weight(lp["cross.wv"], x.dtype))
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        kc = kc.reshape(*kc.shape[:2], hkv, dh)
        vc = vc.reshape(*vc.shape[:2], hkv, dh)
        o = gqa_scores_attend(q, kc, vc, None)
        x = x + jnp.einsum("btk,kd->btd", o, materialize_weight(lp["cross.wo"], x.dtype))
        h = rms_norm(x, lp["ln3"] + 1.0, cfg.norm_eps)
        return x + gelu_mlp(lp, "mlp", h)

    run = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if cfg.remat else body
    if scan_layers:
        x, _ = jax.lax.scan(lambda c, lp: (run(c, lp), None), x, dec)
    else:
        for i in range(cfg.num_layers):
            x = run(x, jax.tree.map(lambda a: a[i], dec))
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    return mask_vocab_logits(jnp.einsum("btd,dv->btv", x, materialize_weight(params["lm_head"], x.dtype)), cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving: cross-KV computed once at prefill; self-KV cache grows.
# ---------------------------------------------------------------------------


def init_cache_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    ld, hkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    f = cfg.encoder_frames
    return {
        "self_k": jax.ShapeDtypeStruct((ld, batch, max_seq, hkv, dh), jnp.bfloat16),
        "self_v": jax.ShapeDtypeStruct((ld, batch, max_seq, hkv, dh), jnp.bfloat16),
        "cross_k": jax.ShapeDtypeStruct((ld, batch, f, hkv, dh), jnp.bfloat16),
        "cross_v": jax.ShapeDtypeStruct((ld, batch, f, hkv, dh), jnp.bfloat16),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_abstract(cfg, batch, max_seq))


def encdec_prefill(params: Params, cfg: ModelConfig, pctx: ParallelContext,
                   tokens: jax.Array, frames: jax.Array, max_seq: int,
                   *, scan_layers: bool = True):
    """Encode audio + run the decoder prompt, building both caches."""
    enc_out = encode(params, cfg, frames, scan_layers=scan_layers)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_dec"][None, :s]
    dec = _grp(params, "dec.")
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim

    def body(x, lp):
        h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
        q, k, v = project_qkv(lp, "self", cfg, h, None, apply_rope=False)
        mask = jnp.tril(jnp.ones((s, s), bool))[None, None, None]
        o = gqa_scores_attend(q, k, v, mask)
        x = x + jnp.einsum("btk,kd->btd", o, materialize_weight(lp["self.wo"], x.dtype))
        h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
        qc, _, _ = project_qkv(lp, "cross", cfg, h, None, apply_rope=False)
        kc = jnp.einsum("bfd,dk->bfk", enc_out, materialize_weight(lp["cross.wk"], x.dtype)).reshape(b, -1, hkv, dh)
        vc = jnp.einsum("bfd,dk->bfk", enc_out, materialize_weight(lp["cross.wv"], x.dtype)).reshape(b, -1, hkv, dh)
        o = gqa_scores_attend(qc, kc, vc, None)
        x = x + jnp.einsum("btk,kd->btd", o, materialize_weight(lp["cross.wo"], x.dtype))
        h = rms_norm(x, lp["ln3"] + 1.0, cfg.norm_eps)
        x = x + gelu_mlp(lp, "mlp", h)
        pad = max_seq - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16)
        return x, (k, v, kc.astype(jnp.bfloat16), vc.astype(jnp.bfloat16))

    if scan_layers:
        x, (sk, sv, ck, cv) = jax.lax.scan(body, x, dec)
    else:
        ys = []
        for i in range(cfg.num_layers):
            x, y = body(x, jax.tree.map(lambda a: a[i], dec))
            ys.append(y)
        sk, sv, ck, cv = (jnp.stack([y[j] for y in ys]) for j in range(4))
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = mask_vocab_logits(jnp.einsum("btd,dv->btv", x[:, -1:], materialize_weight(params["lm_head"], x.dtype)), cfg.vocab_size)
    return logits, {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}


def encdec_decode_step(params: Params, cfg: ModelConfig, pctx: ParallelContext,
                       cache, tokens: jax.Array, lengths: jax.Array):
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + params["pos_dec"][lengths][:, None]
    dec = _grp(params, "dec.")

    def body(carry, xs):
        x = carry
        lp, sk, sv, ck, cv = xs
        h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
        o, sk, sv = attention_decode(lp, "self", cfg, h, sk, sv, lengths,
                                     apply_rope=False)
        x = x + o
        h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
        q, _, _ = project_qkv(lp, "cross", cfg, h, None, apply_rope=False)
        o = gqa_scores_attend(q, ck, cv, None)
        x = x + jnp.einsum("btk,kd->btd", o, materialize_weight(lp["cross.wo"], x.dtype))
        h = rms_norm(x, lp["ln3"] + 1.0, cfg.norm_eps)
        x = x + gelu_mlp(lp, "mlp", h)
        return x, (sk, sv)

    xs_tree = (dec, cache["self_k"], cache["self_v"],
               cache["cross_k"], cache["cross_v"])
    if cfg.scan_layers:
        x, (sk, sv) = jax.lax.scan(body, x, xs_tree)
    else:  # unrolled (cost-extrapolation dry-run compiles)
        ys = []
        for i in range(cfg.num_layers):
            x, y = body(x, jax.tree.map(lambda a: a[i], xs_tree))
            ys.append(y)
        sk = jnp.stack([y[0] for y in ys])
        sv = jnp.stack([y[1] for y in ys])
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = mask_vocab_logits(jnp.einsum("btd,dv->btv", x, materialize_weight(params["lm_head"], x.dtype)), cfg.vocab_size)
    return logits, {"self_k": sk, "self_v": sv,
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
