from .registry import ModelBundle, build_model  # noqa: F401
