from .registry import (ModelBundle, build_draft_model,  # noqa: F401
                       build_model, check_draft_pair)
