"""Shared transformer building blocks (functional, framework-free).

Parameters are plain dicts of jax.Arrays; every creation site registers a
logical-axis tuple alongside the shape so `repro.parallel.sharding` can map
the whole tree to PartitionSpecs without name guessing.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..quant.quantize import QuantizedTensor, quantize_channelwise

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Manual tensor-parallel region marker.
#
# Fully-manual shard_map regions (the only kind jax 0.4.x compiles — see
# repro/parallel/compat.py and docs/known_failures.md) must place their own
# collectives: GSPMD never sees the region, so nobody inserts the all-reduce
# that completes a contraction whose reduced dim is sharded.  The region
# body wraps its model call in :func:`manual_tp`, and :func:`tp_einsum`
# (the one designated reduction site) psums over the named axis.  Because
# the context is entered inside the traced body, it is active on every
# (re)trace regardless of when jit decides to compile.
# ---------------------------------------------------------------------------

_TP_AXIS_STACK: list = [(None, 1)]


@contextlib.contextmanager
def manual_tp(axis: Optional[str], degree: int = 1):
    """Mark the enclosed trace as a manual-TP region: every
    :func:`tp_einsum` contraction all-reduces its partial sums over the
    ``degree``-sized mesh axis ``axis``.  The caller guarantees that *all*
    tp_einsum contraction dims in scope are actually sharded over ``axis``
    (see repro.parallel.tp).  ``manual_tp(None)`` masks any enclosing
    region (a fresh shard_map body with nothing sharded inside)."""
    _TP_AXIS_STACK.append((axis, degree) if axis is not None else (None, 1))
    try:
        yield
    finally:
        _TP_AXIS_STACK.pop()


def current_tp_axis() -> Optional[str]:
    """The active manual-TP mesh axis, or None outside any region."""
    return _TP_AXIS_STACK[-1][0]


def materialize_weight(w: Any, dtype) -> jax.Array:
    """Weight entries in a params dict are plain arrays or int8
    :class:`~repro.quant.QuantizedTensor`s (`repro.quant.quantize_params`);
    every einsum site goes through here so both load transparently.  XLA
    fuses the dequant multiply into the consumer, so the weight crosses HBM
    at 1 byte/element — the bandwidth win `docs/quantization.md` measures.
    """
    if isinstance(w, QuantizedTensor):
        return w.dequantize(dtype)
    return w


# ---------------------------------------------------------------------------
# Parameter builder: params pytree + parallel logical-axes pytree.
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Collects (init, logical_axes) pairs; materialises with a key or
    abstractly (ShapeDtypeStruct) for the dry-run."""

    def __init__(self, dtype=jnp.bfloat16):
        self.dtype = dtype
        self._defs: Dict[str, Tuple[Tuple[int, ...], Tuple[Optional[str], ...], float]] = {}

    def param(self, name, shape, logical, scale=None):
        assert name not in self._defs, name
        assert len(shape) == len(logical), (name, shape, logical)
        if scale is None:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = fan_in ** -0.5
        self._defs[name] = (tuple(shape), tuple(logical), float(scale))
        return name

    def build(self, key) -> Params:
        out = {}
        names = sorted(self._defs)
        keys = jax.random.split(key, max(len(names), 1))
        for k, name in zip(keys, names):
            shape, _, scale = self._defs[name]
            if scale == 0.0:
                out[name] = jnp.zeros(shape, self.dtype)
            else:
                out[name] = (jax.random.normal(k, shape, jnp.float32) * scale).astype(self.dtype)
        return out

    def abstract(self) -> Params:
        return {
            name: jax.ShapeDtypeStruct(shape, self.dtype)
            for name, (shape, _, _) in self._defs.items()
        }

    def logical_axes(self) -> Dict[str, Tuple[Optional[str], ...]]:
        return {name: logical for name, (_, logical, _) in self._defs.items()}


# ---------------------------------------------------------------------------
# Primitive layers.
# ---------------------------------------------------------------------------


def mask_vocab_logits(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Neutralise padded vocab slots (see ModelConfig.padded_vocab)."""
    if logits.shape[-1] == vocab_size:
        return logits
    live = jnp.arange(logits.shape[-1]) < vocab_size
    return jnp.where(live, logits, jnp.asarray(-1e30, logits.dtype))


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope(
    x: jax.Array,            # (..., T, H, Dh)
    positions: jax.Array,    # (..., T)
    theta: float,
    fraction: float = 1.0,
) -> jax.Array:
    """Rotary embedding over the first ``fraction`` of the head dim
    (chatglm3's 2d-RoPE rotates half the dimensions)."""
    dh = x.shape[-1]
    rot = int(dh * fraction) // 2 * 2
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions: (..., T) -> angles (..., T, 1, half), broadcast over heads
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = xr[..., :half], xr[..., half:]
    xr = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([xr.astype(x.dtype), xp], axis=-1)


#: canonical split-K granularity for :func:`tp_einsum` — the contraction
#: dim is always cut into (up to) this many equal slices, 1 device or N
TP_CHUNKS = 4


@jax.custom_jvp
def _dtype_barrier(x: jax.Array, w: jax.Array):
    """optimization_barrier with a pass-through differentiation rule.

    ``jax.lax.optimization_barrier`` has no JVP registered (through
    jax 0.4.x), so using it bare would break every training path that
    differentiates through :func:`tp_einsum`.  The barrier only needs to
    pin the *forward* values at their storage dtype; tangents flow
    through untouched."""
    return jax.lax.optimization_barrier((x, w))


@_dtype_barrier.defjvp
def _dtype_barrier_jvp(primals, tangents):
    return _dtype_barrier(*primals), tuple(tangents)


def _tp_contract_axes(spec: str) -> Tuple[int, int]:
    """Axis of the (single) contracted letter in each einsum operand."""
    ins, out = spec.split("->")
    a, b = ins.split(",")
    shared = [c for c in a if c in b and c not in out]
    assert len(shared) == 1, spec
    return a.index(shared[0]), b.index(shared[0])


def _tp_chunks(k: int) -> int:
    """Canonical chunk count for a global contraction length ``k``."""
    for c in (TP_CHUNKS, 2):
        if k % c == 0:
            return c
    return 1


def _tree_sum(parts):
    """Balanced-binary-tree sum — the one canonical association order."""
    while len(parts) > 1:
        parts = [parts[i] + parts[i + 1] if i + 1 < len(parts) else parts[i]
                 for i in range(0, len(parts), 2)]
    return parts[0]


def tp_einsum(spec: str, x: jax.Array, w: jax.Array, cfg=None) -> jax.Array:
    """Einsum whose contraction dim is TP-sharded (partial sums cross the
    ``model`` axis).  With cfg.bf16_reduce the partials are bf16 so the
    all-reduce moves half the bytes (§Perf iteration 1); default keeps f32
    partials (paper-faithful baseline).

    The arithmetic is *canonical split-K*: the global contraction dim is
    cut into :data:`TP_CHUNKS` equal slices, each slice reduced by its own
    wide-accumulator gemm, and the per-slice partials combined by one
    balanced binary tree, rounding to ``x.dtype`` once at the end.  A
    1-device trace and a :func:`manual_tp` region at any degree dividing
    TP_CHUNKS execute the *same* gemm shapes in the *same* association
    order (the region all_gathers its slice partials in global order
    instead of summing a longer local contraction), so mesh outputs are
    bit-identical to 1-device outputs — the property the engine-identity
    suite and BENCH_parallel gate on.  Degrees not dividing TP_CHUNKS
    still reduce correctly (one gemm per shard, tree over N partials) but
    only match 1-device up to float associativity.

    The optimization_barrier pins both operands at their storage dtype:
    without it XLA's excess-precision pass may strip an upstream
    ``f32 -> bf16 -> f32`` round-trip (feeding the gemm *unrounded* f32
    activations) in one program but not the other, which breaks the
    bit-identity the canonical chunking otherwise guarantees."""
    w = materialize_weight(w, x.dtype)
    x, w = _dtype_barrier(x, w)
    axis, degree = _TP_AXIS_STACK[-1]
    acc = (jnp.bfloat16 if cfg is not None and getattr(cfg, "bf16_reduce", False)
           else jnp.float32)
    xk, wk = _tp_contract_axes(spec)
    k_local = x.shape[xk]
    c_global = _tp_chunks(k_local * degree)
    c_local = c_global // degree if c_global % degree == 0 else 1
    step = k_local // c_local
    parts = [
        jnp.einsum(spec,
                   jax.lax.slice_in_dim(x, i * step, (i + 1) * step, axis=xk),
                   jax.lax.slice_in_dim(w, i * step, (i + 1) * step, axis=wk),
                   preferred_element_type=acc)
        for i in range(c_local)
    ]
    if axis is not None:
        # (c_local, ...) local partials -> (degree*c_local, ...) global
        # partials, in global slice order (shard i holds slices
        # [i*c_local, (i+1)*c_local) of the contraction dim)
        gathered = jax.lax.all_gather(jnp.stack(parts), axis, axis=0,
                                      tiled=True)
        parts = [gathered[i] for i in range(degree * c_local)]
    return _tree_sum(parts).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
           cfg=None) -> jax.Array:
    g = jnp.einsum("btd,df->btf", x, materialize_weight(w_gate, x.dtype))
    u = jnp.einsum("btd,df->btf", x, materialize_weight(w_up, x.dtype))
    return tp_einsum("btf,fd->btd", jax.nn.silu(g) * u, w_down, cfg)


# ---------------------------------------------------------------------------
# GQA attention (full / causal / cached decode).
# ---------------------------------------------------------------------------


def attn_params(pb: ParamBuilder, prefix: str, cfg: ModelConfig, layers: Optional[int]):
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    lead = () if layers is None else (layers,)
    llog = () if layers is None else ("layers",)
    pb.param(f"{prefix}.wq", lead + (d, h * dh), llog + ("embed", "heads"))
    pb.param(f"{prefix}.wk", lead + (d, hkv * dh), llog + ("embed", "kv_heads"))
    pb.param(f"{prefix}.wv", lead + (d, hkv * dh), llog + ("embed", "kv_heads"))
    pb.param(f"{prefix}.wo", lead + (h * dh, d), llog + ("heads", "embed"))


def project_qkv(p: Params, prefix: str, cfg: ModelConfig, x: jax.Array,
                positions: Optional[jax.Array], apply_rope: bool = True):
    b, t, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    wq = materialize_weight(p[f"{prefix}.wq"], x.dtype)
    wk = materialize_weight(p[f"{prefix}.wk"], x.dtype)
    wv = materialize_weight(p[f"{prefix}.wv"], x.dtype)
    q = jnp.einsum("btd,dk->btk", x, wq).reshape(b, t, h, dh)
    k = jnp.einsum("btd,dk->btk", x, wk).reshape(b, t, hkv, dh)
    v = jnp.einsum("btd,dk->btk", x, wv).reshape(b, t, hkv, dh)
    if apply_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def gqa_scores_attend(q, k, v, mask) -> jax.Array:
    """q: (B,T,H,Dh); k/v: (B,S,Hkv,Dh); mask broadcastable to (B,H,T,S)."""
    b, t, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, t, hkv, g, dh)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k).astype(jnp.float32)
    scores = scores * (dh ** -0.5)
    if mask is not None:  # mask broadcastable to (B, Hkv, G, T, S)
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgts,bshd->bthgd", probs, v)
    return out.reshape(b, t, h * dh)


def attention(
    p: Params,
    prefix: str,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
    apply_rope: bool = True,
) -> jax.Array:
    """Self (or cross, via kv_override) attention for train/prefill."""
    b, t, _ = x.shape
    q, k, v = project_qkv(p, prefix, cfg, x, positions, apply_rope)
    if kv_override is not None:
        k, v = kv_override
    mask = None
    if causal and kv_override is None:
        mask = jnp.tril(jnp.ones((t, t), bool))[None, None, None]
    out = gqa_scores_attend(q, k, v, mask)
    return tp_einsum("btk,kd->btd", out, p[f"{prefix}.wo"], cfg)


def attention_decode(
    p: Params,
    prefix: str,
    cfg: ModelConfig,
    x: jax.Array,              # (B, 1, d)
    k_cache: jax.Array,        # (B, S, Hkv, Dh) — may be seq-sharded
    v_cache: jax.Array,
    lengths: jax.Array,        # (B,) tokens already in cache
    *,
    apply_rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention against (and update of) the KV cache.

    The softmax runs over the cache's sequence axis; when that axis is
    sharded (decode_32k / long_500k shard it over "model"), GSPMD lowers the
    max/sum reductions to all-reduces — the distributed form of the APR
    online-softmax accumulator (see kernels/flash_decode for the TPU-kernel
    form the serving path uses on real hardware).
    """
    b = x.shape[0]
    s = k_cache.shape[1]
    q, k_new, v_new = project_qkv(p, prefix, cfg, x, lengths[:, None], apply_rope)
    idx = lengths  # scatter position per sequence
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, idx].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, idx].set(v_new[:, 0].astype(v_cache.dtype))
    mask = (jnp.arange(s)[None] <= lengths[:, None])[:, None, None, None, :]
    out = gqa_scores_attend(q, k_cache, v_cache, mask)
    return tp_einsum("btk,kd->btd", out, p[f"{prefix}.wo"], cfg), k_cache, v_cache


def attention_decode_paged(
    p: Params,
    prefix: str,
    cfg: ModelConfig,
    x: jax.Array,              # (B, T, d) — T new tokens per slot
    k_pages: jax.Array,        # (P_pool, page_size, Hkv, Dh) shared pool
    v_pages: jax.Array,
    lengths: jax.Array,        # (B,) tokens already in the cache per slot
    new_counts: jax.Array,     # (B,) real new tokens this call (<= T)
    block_tables: jax.Array,   # (B, P_max) physical page per logical page
    *,
    k_scales: Optional[jax.Array] = None,  # (P_pool, page_size, Hkv) fp32
    v_scales: Optional[jax.Array] = None,  # when the page pools are int8
    apply_rope: bool = True,
):
    """Multi-token attention against (and update of) a *paged* KV cache.

    One function covers both serve paths: ``T == 1`` is the decode step,
    ``T == chunk`` is one chunked-prefill step.  Token ``i`` of slot ``b``
    sits at logical position ``lengths[b] + i`` and is scattered to physical
    page ``block_tables[b, pos // page_size]``, offset ``pos % page_size``.
    Positions ``i >= new_counts[b]`` are padding (short final prefill chunk,
    or an idle slot with ``new_counts == 0``): their writes are routed to
    the reserved null page 0 so they can never corrupt a live page, and
    their query rows return garbage the caller must ignore.

    With ``k_scales``/``v_scales`` the page pools are **int8**: each new
    (token, head) vector is quantized symmetrically over the head dim at
    write time and its fp32 scale scattered to the matching page slot, so a
    page slot is always self-consistent (no requantization of old entries,
    writes stay idempotent); the gather dequantizes before the softmax.
    Returns ``(out, k_pages, v_pages)`` — plus the updated scale pools when
    quantized.
    """
    b, t, _ = x.shape
    ps = k_pages.shape[1]
    positions = lengths[:, None] + jnp.arange(t)[None]         # (B, T)
    q, k_new, v_new = project_qkv(p, prefix, cfg, x, positions, apply_rope)
    write = jnp.arange(t)[None] < new_counts[:, None]          # (B, T)
    page_idx = jnp.minimum(positions // ps, block_tables.shape[1] - 1)
    bidx = jnp.arange(b)[:, None]
    pids = jnp.where(write, block_tables[bidx, page_idx], 0)
    offs = jnp.where(write, positions % ps, 0)
    quantized = k_scales is not None
    if quantized:
        kq = quantize_channelwise(k_new, axis=-1)   # per (token, head)
        vq = quantize_channelwise(v_new, axis=-1)
        k_pages = k_pages.at[pids, offs].set(kq.q)
        v_pages = v_pages.at[pids, offs].set(vq.q)
        k_scales = k_scales.at[pids, offs].set(kq.scale[..., 0])
        v_scales = v_scales.at[pids, offs].set(vq.scale[..., 0])
    else:
        k_pages = k_pages.at[pids, offs].set(k_new.astype(k_pages.dtype))
        v_pages = v_pages.at[pids, offs].set(v_new.astype(v_pages.dtype))
    # logical contiguous view: (B, P_max * page_size, Hkv, Dh)
    k_all = jnp.take(k_pages, block_tables, axis=0).reshape(
        b, -1, *k_pages.shape[2:])
    v_all = jnp.take(v_pages, block_tables, axis=0).reshape(
        b, -1, *v_pages.shape[2:])
    if quantized:  # dequantize the gathered view before the softmax
        ks_all = jnp.take(k_scales, block_tables, axis=0).reshape(
            b, -1, k_scales.shape[2])
        vs_all = jnp.take(v_scales, block_tables, axis=0).reshape(
            b, -1, v_scales.shape[2])
        k_all = (k_all.astype(jnp.float32) * ks_all[..., None]).astype(x.dtype)
        v_all = (v_all.astype(jnp.float32) * vs_all[..., None]).astype(x.dtype)
    s = k_all.shape[1]
    # causal within the chunk: query i sees logical positions <= lengths + i
    mask = (jnp.arange(s)[None, None] <= positions[:, :, None])[:, None, None]
    out = gqa_scores_attend(q, k_all, v_all, mask)
    out = tp_einsum("btk,kd->btd", out, p[f"{prefix}.wo"], cfg)
    if quantized:
        return out, k_pages, v_pages, k_scales, v_scales
    return out, k_pages, v_pages
