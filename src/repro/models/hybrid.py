"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block
applied every ``cfg.attn_every`` layers (weight reuse is the Zamba trick —
attention quality at almost no parameter cost).

Layer layout for num_layers=38, attn_every=6: 6 super-blocks of
(6 mamba layers + shared-attn application) + 2 tail mamba layers.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import ParallelContext
from .layers import (ParamBuilder, Params, attention, attention_decode,
                     attn_params, mask_vocab_logits, rms_norm)
from .ssm import CONV_K, mamba2_decode, mamba2_mixer, ssm_params


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    ae = cfg.attn_every
    n_sb = cfg.num_layers // ae
    tail = cfg.num_layers - n_sb * ae
    return n_sb, ae, tail


def build_params(cfg: ModelConfig) -> ParamBuilder:
    pb = ParamBuilder(dtype=jnp.bfloat16)
    d = cfg.d_model
    n_sb, ae, tail = _layout(cfg)
    pb.param("embed", (cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02)
    for j in range(ae):
        ssm_params(pb, f"sb.{j}.ssm", cfg, n_sb)
        pb.param(f"sb.{j}.ln", (n_sb, d), ("layers", None), scale=0.0)
    for j in range(tail):
        ssm_params(pb, f"tail.{j}.ssm", cfg, None)
        pb.param(f"tail.{j}.ln", (d,), (None,), scale=0.0)
    # ONE shared attention block (not stacked)
    attn_params(pb, "shared.attn", cfg, None)
    pb.param("shared.ln", (d,), (None,), scale=0.0)
    pb.param("final_norm", (d,), (None,), scale=0.0)
    pb.param("lm_head", (d, cfg.padded_vocab), ("embed", "vocab"))
    return pb


def _mamba_layer(cfg, x, lp, chunk):
    h = rms_norm(x, lp["ln"] + 1.0, cfg.norm_eps)
    return x + mamba2_mixer(lp, "ssm", cfg, h, chunk=chunk)


def adaptive_chunk(t: int) -> int:
    """SSD chunk size: cap the python-unrolled chunk count at 32 so the
    lowered HLO stays partitioner-friendly at 32k+ sequences, while short
    sequences keep MXU-sized 256 chunks."""
    return max(256, -(-t // 32))


def hybrid_forward(params: Params, cfg: ModelConfig, pctx: ParallelContext,
                   tokens: jax.Array, *, scan_layers: bool = True,
                   chunk: int = 0) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s = tokens.shape
    chunk = chunk or adaptive_chunk(s)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    n_sb, ae, tail = _layout(cfg)
    shared = {k[len("shared."):]: v for k, v in params.items() if k.startswith("shared.")}
    sb = {k[len("sb."):]: v for k, v in params.items() if k.startswith("sb.")}

    def super_block(x, sb_p):
        for j in range(ae):
            lp = {k[len(f"{j}."):]: v for k, v in sb_p.items() if k.startswith(f"{j}.")}
            x = _mamba_layer(cfg, x, lp, chunk)
        h = rms_norm(x, shared["ln"] + 1.0, cfg.norm_eps)
        return x + attention(shared, "attn", cfg, h, positions=positions, causal=True)

    body = super_block
    if cfg.remat:
        body = jax.checkpoint(super_block, policy=jax.checkpoint_policies.nothing_saveable)
    if scan_layers:
        x, _ = jax.lax.scan(lambda c, p_: (body(c, p_), None), x, sb)
    else:
        for i in range(n_sb):
            x = body(x, jax.tree.map(lambda a: a[i], sb))
    for j in range(tail):
        lp = {k[len(f"tail.{j}."):]: v for k, v in params.items()
              if k.startswith(f"tail.{j}.")}
        x = _mamba_layer(cfg, x, lp, chunk)
    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    return mask_vocab_logits(jnp.einsum("btd,dv->btv", x, params["lm_head"]), cfg.vocab_size)


# ---------------------------------------------------------------------------
# Serving state: per-mamba-layer (conv, ssm) + shared-attn KV caches.
# ---------------------------------------------------------------------------


def init_state_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    n_sb, ae, tail = _layout(cfg)
    L = cfg.num_layers
    ch = cfg.d_inner + 2 * cfg.ssm_state
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    # conv is a sliding window of *raw* pre-conv activations: re-quantizing
    # it to bf16 every decode step compounds rounding through the recurrent
    # decay dynamics across all L layers (decode drifted past the forward
    # pass's tolerance band).  It is tiny ((K-1) * ch per token slot), so it
    # stays fp32 like the SSM state; only the large attention KV caches are
    # held in the production bf16 cache dtype.
    return {
        "conv": jax.ShapeDtypeStruct((L, batch, CONV_K - 1, ch), jnp.float32),
        "ssm": jax.ShapeDtypeStruct((L, batch, h, p, n), jnp.float32),
        "attn_k": jax.ShapeDtypeStruct((n_sb, batch, max_seq, hkv, dh), jnp.bfloat16),
        "attn_v": jax.ShapeDtypeStruct((n_sb, batch, max_seq, hkv, dh), jnp.bfloat16),
    }


def init_state(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_state_abstract(cfg, batch, max_seq))


def hybrid_decode_step(
    params: Params, cfg: ModelConfig, pctx: ParallelContext,
    state: Dict[str, jax.Array], tokens: jax.Array, lengths: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = jnp.take(params["embed"], tokens, axis=0)
    n_sb, ae, tail = _layout(cfg)
    shared = {k[len("shared."):]: v for k, v in params.items() if k.startswith("shared.")}

    conv_states, ssm_states = [], []
    ak, av = [], []
    li = 0
    for i in range(n_sb):
        for j in range(ae):
            lp = {k[len(f"sb.{j}."):]: params[k][i]
                  for k in params if k.startswith(f"sb.{j}.")}
            h = rms_norm(x, lp["ln"] + 1.0, cfg.norm_eps)
            out, cs, ss = mamba2_decode(lp, "ssm", cfg, h,
                                        state["conv"][li], state["ssm"][li])
            x = x + out
            conv_states.append(cs)
            ssm_states.append(ss)
            li += 1
        h = rms_norm(x, shared["ln"] + 1.0, cfg.norm_eps)
        out, k_new, v_new = attention_decode(
            shared, "attn", cfg, h, state["attn_k"][i], state["attn_v"][i], lengths
        )
        x = x + out
        ak.append(k_new)
        av.append(v_new)
    for j in range(tail):
        lp = {k[len(f"tail.{j}."):]: v for k, v in params.items()
              if k.startswith(f"tail.{j}.")}
        h = rms_norm(x, lp["ln"] + 1.0, cfg.norm_eps)
        out, cs, ss = mamba2_decode(lp, "ssm", cfg, h,
                                    state["conv"][li], state["ssm"][li])
        x = x + out
        conv_states.append(cs)
        ssm_states.append(ss)
        li += 1

    x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
    logits = mask_vocab_logits(jnp.einsum("btd,dv->btv", x, params["lm_head"]), cfg.vocab_size)
    new_state = {
        "conv": jnp.stack(conv_states),
        "ssm": jnp.stack(ssm_states),
        "attn_k": jnp.stack(ak),
        "attn_v": jnp.stack(av),
    }
    return logits, new_state

# ---------------------------------------------------------------------------
# Paged serving: KV page pools for the shared-attention applications +
# state-slot pools for the mamba layers — the hybrid case is the point of
# the state cache (one engine tick drives both through one block table).
# ---------------------------------------------------------------------------


def init_paged_cache_abstract(cfg: ModelConfig, pool_pages: int,
                              page_size: int, kv_dtype: str = "bfloat16",
                              state_slots: int = 0,
                              state_dtype: str = "float32"):
    """Attention KV as per-super-block page pools (dummy axis 1 keeps the
    physical page at axis 2, the engine's page-copy convention) + mamba
    state pools with the physical state slot at axis 1."""
    from . import ssm as ssm_mod

    n_sb, _, _ = _layout(cfg)
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    kdt = jnp.int8 if kv_dtype == "int8" else jnp.bfloat16
    pools = {
        "k": jax.ShapeDtypeStruct((n_sb, 1, pool_pages, page_size, hkv, dh), kdt),
        "v": jax.ShapeDtypeStruct((n_sb, 1, pool_pages, page_size, hkv, dh), kdt),
    }
    if kv_dtype == "int8":
        pools["k_scale"] = jax.ShapeDtypeStruct(
            (n_sb, 1, pool_pages, page_size, hkv), jnp.float32)
        pools["v_scale"] = jax.ShapeDtypeStruct(
            (n_sb, 1, pool_pages, page_size, hkv), jnp.float32)
    pools.update(ssm_mod.init_paged_state_abstract(cfg, state_slots,
                                                   state_dtype))
    return pools


def init_paged_cache(cfg: ModelConfig, pool_pages: int, page_size: int,
                     kv_dtype: str = "bfloat16", state_slots: int = 0,
                     state_dtype: str = "float32"):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        init_paged_cache_abstract(cfg, pool_pages, page_size, kv_dtype,
                                  state_slots, state_dtype))


def hybrid_decode_paged(params: Params, cfg: ModelConfig, cache,
                        tokens: jax.Array, lengths: jax.Array,
                        new_counts: jax.Array, block_tables: jax.Array,
                        pctx: ParallelContext):
    """Paged decode/prefill chunk over the hybrid stack, one token at a
    time — the same per-token recurrence as ``hybrid_decode_step`` (so
    greedy outputs are bit-identical to the slot engine), with the shared
    attention reading/writing the KV page pools via the block-table part
    of the combined table and the mamba state gathered/scattered via its
    read/write columns."""
    from .layers import attention_decode_paged
    from .paged_state import gather_state, scatter_state, split_state_tables

    b, t_total = tokens.shape
    kv_bt, read, writes = split_state_tables(block_tables, t_total)
    state = gather_state(cache, read)
    conv, ssm = state["conv"], state["ssm"]
    n_sb, ae, tail = _layout(cfg)
    shared = {k[len("shared."):]: v for k, v in params.items()
              if k.startswith("shared.")}
    quantized = "k_scale" in cache
    kpools = {k: cache[k] for k in ("k", "v", "k_scale", "v_scale")
              if k in cache}
    outs = []
    for t in range(t_total):
        count_t = (new_counts > t).astype(jnp.int32)           # (B,) 0/1
        len_t = lengths + jnp.minimum(new_counts, t)
        x = jnp.take(params["embed"], tokens[:, t:t + 1], axis=0)
        conv_l, ssm_l = [], []
        new_k, new_v, new_ks, new_vs = [], [], [], []
        li = 0
        for i in range(n_sb):
            for j in range(ae):
                lp = {k[len(f"sb.{j}."):]: params[k][i]
                      for k in params if k.startswith(f"sb.{j}.")}
                h = rms_norm(x, lp["ln"] + 1.0, cfg.norm_eps)
                out, cs, ss = mamba2_decode(lp, "ssm", cfg, h,
                                            conv[li], ssm[li])
                x = x + out
                conv_l.append(cs)
                ssm_l.append(ss)
                li += 1
            h = rms_norm(x, shared["ln"] + 1.0, cfg.norm_eps)
            if quantized:
                out, kp, vp, ks, vs = attention_decode_paged(
                    shared, "attn", cfg, h, kpools["k"][i, 0],
                    kpools["v"][i, 0], len_t, count_t, kv_bt,
                    k_scales=kpools["k_scale"][i, 0],
                    v_scales=kpools["v_scale"][i, 0])
                new_ks.append(ks)
                new_vs.append(vs)
            else:
                out, kp, vp = attention_decode_paged(
                    shared, "attn", cfg, h, kpools["k"][i, 0],
                    kpools["v"][i, 0], len_t, count_t, kv_bt)
            x = x + out
            new_k.append(kp)
            new_v.append(vp)
        for j in range(tail):
            lp = {k[len(f"tail.{j}."):]: v for k, v in params.items()
                  if k.startswith(f"tail.{j}.")}
            h = rms_norm(x, lp["ln"] + 1.0, cfg.norm_eps)
            out, cs, ss = mamba2_decode(lp, "ssm", cfg, h, conv[li], ssm[li])
            x = x + out
            conv_l.append(cs)
            ssm_l.append(ss)
            li += 1
        x = rms_norm(x, params["final_norm"] + 1.0, cfg.norm_eps)
        outs.append(mask_vocab_logits(
            jnp.einsum("btd,dv->btv", x, params["lm_head"]), cfg.vocab_size))
        conv = jnp.stack(conv_l)
        ssm = jnp.stack(ssm_l)
        kpools = {"k": jnp.stack(new_k)[:, None],
                  "v": jnp.stack(new_v)[:, None]}
        if quantized:
            kpools["k_scale"] = jnp.stack(new_ks)[:, None]
            kpools["v_scale"] = jnp.stack(new_vs)[:, None]
        cache = scatter_state(cache, {"conv": conv, "ssm": ssm},
                              writes[:, t])
    cache = dict(cache)
    cache.update(kpools)
    return jnp.concatenate(outs, axis=1), cache
