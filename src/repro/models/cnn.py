"""The paper's three benchmark CNNs (LeNet-5, ResNet-20, MobileNet-V1) as
runnable JAX models whose convolutions execute on the APR-resident Pallas
kernel — the Level-B twin of the Level-A instruction-trace workloads in
``core/workloads.py`` (same layer geometry, same reduction structure).

``conv_impl``: "pallas" routes through kernels/apr_conv (interpret mode on
CPU); "xla" uses lax.conv (fast path for CPU examples/tests).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from ..kernels.apr_conv import apr_conv2d, conv2d_ref
from .layers import ParamBuilder


def _conv(x, f, stride, padding, impl):
    if impl == "pallas":
        return apr_conv2d(x, f, stride=stride, padding=padding)
    return conv2d_ref(x, f, stride=stride, padding=padding)


def _avgpool(x, k=2):
    b, h, w, c = x.shape
    return x.reshape(b, h // k, k, w // k, k, c).mean(axis=(2, 4))


def _gap(x):
    return x.mean(axis=(1, 2))


# ---------------------------------------------------------------------------
# LeNet-5
# ---------------------------------------------------------------------------


def lenet_params(key) -> Dict[str, jax.Array]:
    pb = ParamBuilder(dtype=jnp.float32)
    pb.param("c1", (5, 5, 1, 6), (None,) * 4)
    pb.param("c2", (5, 5, 6, 16), (None,) * 4)
    pb.param("f1", (400, 120), (None,) * 2)
    pb.param("f2", (120, 84), (None,) * 2)
    pb.param("f3", (84, 10), (None,) * 2)
    return pb.build(key)


def lenet_forward(p, x, *, conv_impl="xla"):
    """x: (B, 32, 32, 1) -> logits (B, 10)."""
    x = jax.nn.relu(_conv(x, p["c1"], 1, 0, conv_impl))   # 28x28x6
    x = _avgpool(x)                                        # 14x14x6
    x = jax.nn.relu(_conv(x, p["c2"], 1, 0, conv_impl))   # 10x10x16
    x = _avgpool(x)                                        # 5x5x16
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ p["f1"])
    x = jax.nn.relu(x @ p["f2"])
    return x @ p["f3"]


# ---------------------------------------------------------------------------
# ResNet-20 (CIFAR)
# ---------------------------------------------------------------------------


def resnet20_params(key) -> Dict[str, jax.Array]:
    pb = ParamBuilder(dtype=jnp.float32)
    pb.param("conv1", (3, 3, 3, 16), (None,) * 4)
    ch_in = 16
    for stage, ch in enumerate((16, 32, 64)):
        for b in range(3):
            cin = ch_in if b == 0 else ch
            pb.param(f"s{stage}b{b}c1", (3, 3, cin, ch), (None,) * 4)
            pb.param(f"s{stage}b{b}c2", (3, 3, ch, ch), (None,) * 4)
            if cin != ch:
                pb.param(f"s{stage}b{b}sc", (1, 1, cin, ch), (None,) * 4)
        ch_in = ch
    pb.param("fc", (64, 10), (None,) * 2)
    return pb.build(key)


def resnet20_forward(p, x, *, conv_impl="xla"):
    """x: (B, 32, 32, 3) -> logits (B, 10)."""
    x = jax.nn.relu(_conv(x, p["conv1"], 1, 1, conv_impl))
    for stage in range(3):
        for b in range(3):
            stride = 2 if (stage > 0 and b == 0) else 1
            h = jax.nn.relu(_conv(x, p[f"s{stage}b{b}c1"], stride, 1, conv_impl))
            h = _conv(h, p[f"s{stage}b{b}c2"], 1, 1, conv_impl)
            sc = p.get(f"s{stage}b{b}sc")
            shortcut = _conv(x, sc, stride, 0, conv_impl) if sc is not None else x
            x = jax.nn.relu(h + shortcut)
    return _gap(x) @ p["fc"]


# ---------------------------------------------------------------------------
# MobileNet-V1 (32x32, the paper's "(Scaled)" variant)
# ---------------------------------------------------------------------------

_MOBILENET_CFG = [
    (32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
    (256, 256, 1), (256, 512, 2),
    (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 512, 1), (512, 512, 1),
    (512, 1024, 2), (1024, 1024, 1),
]


def mobilenet_params(key) -> Dict[str, jax.Array]:
    pb = ParamBuilder(dtype=jnp.float32)
    pb.param("conv1", (3, 3, 3, 32), (None,) * 4)
    for i, (cin, cout, _s) in enumerate(_MOBILENET_CFG):
        pb.param(f"dw{i}", (3, 3, cin, 1), (None,) * 4)
        pb.param(f"pw{i}", (1, 1, cin, cout), (None,) * 4)
    pb.param("fc", (1024, 10), (None,) * 2)
    return pb.build(key)


def _depthwise(x, f, stride, impl):
    # grouped conv: one filter per channel; express as feature_group_count
    if impl == "pallas":
        # per-channel APR conv: fold channels into batch (C small convs);
        # for CPU validation just use the grouped lax path with the same
        # reduction structure (depthwise = C=1 convs, see core/workloads).
        pass
    return jax.lax.conv_general_dilated(
        x, f, window_strides=(stride, stride), padding=[(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1],
    )


def mobilenet_forward(p, x, *, conv_impl="xla"):
    """x: (B, 32, 32, 3) -> logits (B, 10)."""
    x = jax.nn.relu(_conv(x, p["conv1"], 1, 1, conv_impl))
    for i, (cin, cout, s) in enumerate(_MOBILENET_CFG):
        x = jax.nn.relu(_depthwise(x, p[f"dw{i}"], s, conv_impl))
        x = jax.nn.relu(_conv(x, p[f"pw{i}"], 1, 0, conv_impl))
    return _gap(x) @ p["fc"]


CNNS: Dict[str, Dict[str, Callable]] = {
    "lenet": {"params": lenet_params, "forward": lenet_forward, "input": (32, 32, 1)},
    "resnet20": {"params": resnet20_params, "forward": resnet20_forward, "input": (32, 32, 3)},
    "mobilenet_v1": {"params": mobilenet_params, "forward": mobilenet_forward, "input": (32, 32, 3)},
}
