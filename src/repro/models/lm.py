"""Decoder-only LM covering the dense / moe / vlm families.

Layers are scanned (stacked params, one compiled body) in super-blocks of
``cfg.moe_every`` layers so MoE interleaving (llama4: every 2nd layer) stays
homogeneous under ``lax.scan``; the dry-run can also unroll
(``scan_layers=False``) for cost-analysis extrapolation.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.sharding import ParallelContext
from .layers import (ParamBuilder, Params, attention, attention_decode,
                     attention_decode_paged, attn_params, current_tp_axis,
                     mask_vocab_logits, materialize_weight, rms_norm, swiglu)
from .moe import moe_block, moe_params


def _lm_head(params: Params, rest: Params, cfg: ModelConfig,
             x: jax.Array) -> jax.Array:
    """Final projection; tied embeddings stay full precision (the embedding
    is gathered per token on the way in), an untied lm_head may be an int8
    :class:`~repro.quant.QuantizedTensor`.

    Inside a manual-TP region (repro.parallel.tp) an untied lm_head arrives
    vocab-sharded: each shard's einsum emits its own logit columns (no
    cross-shard reduction — vocab is an *output* dim, so the columns are
    bit-identical to the unsharded ones) and an all_gather reassembles the
    full vocab before padded-slot masking."""
    head = rest.get("lm_head")
    if head is None:
        head = params["embed"].T
    else:
        head = materialize_weight(head, x.dtype)
    logits = jnp.einsum("btd,dv->btv", x, head)
    axis = current_tp_axis()
    if axis is not None and logits.shape[-1] != cfg.padded_vocab:
        logits = jax.lax.all_gather(logits, axis, axis=-1, tiled=True)
    return mask_vocab_logits(logits, cfg.vocab_size)


def mlp_params(pb: ParamBuilder, prefix: str, cfg: ModelConfig, layers: Optional[int]):
    d, ff = cfg.d_model, cfg.d_ff
    lead = () if layers is None else (layers,)
    llog = () if layers is None else ("layers",)
    pb.param(f"{prefix}.w_gate", lead + (d, ff), llog + ("embed", "ff"))
    pb.param(f"{prefix}.w_up", lead + (d, ff), llog + ("embed", "ff"))
    pb.param(f"{prefix}.w_down", lead + (ff, d), llog + ("ff", "embed"))


def build_params(cfg: ModelConfig) -> ParamBuilder:
    pb = ParamBuilder(dtype=jnp.bfloat16)
    d = cfg.d_model
    pb.param("embed", (cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02)
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    n_sb = cfg.num_layers // me
    n_dense = me - 1 if cfg.num_experts else me
    # attention + norms for every layer: stacked (n_sb, me, ...)
    for j in range(me):
        attn_params(pb, f"blk.{j}.attn", cfg, n_sb)
        pb.param(f"blk.{j}.ln1", (n_sb, d), ("layers", None), scale=0.0)
        pb.param(f"blk.{j}.ln2", (n_sb, d), ("layers", None), scale=0.0)
        if cfg.num_experts and j == me - 1:
            moe_params(pb, f"blk.{j}.moe", cfg, n_sb)
        else:
            mlp_params(pb, f"blk.{j}.mlp", cfg, n_sb)
    pb.param("final_norm", (d,), (None,), scale=0.0)
    if not cfg.tie_embeddings:
        pb.param("lm_head", (d, cfg.padded_vocab), ("embed", "vocab"))
    return pb


def _split_block_params(p: Params) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    blk = {k: v for k, v in p.items() if k.startswith("blk.")}
    rest = {k: v for k, v in p.items() if not k.startswith("blk.")}
    return blk, rest


def _sub(p: Params, j: int, name: str) -> Params:
    pre = f"blk.{j}.{name}"
    return {k[len(f"blk.{j}."):]: v for k, v in p.items() if k.startswith(pre)}


def _super_block(cfg: ModelConfig, pctx: ParallelContext, x, blk_p, positions):
    """One scanned unit: ``moe_every`` transformer layers."""
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    for j in range(me):
        lp = {k[len(f"blk.{j}."):]: v for k, v in blk_p.items()
              if k.startswith(f"blk.{j}.")}
        h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
        x = x + attention(lp, "attn", cfg, h, positions=positions, causal=True)
        h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
        if cfg.num_experts and j == me - 1:
            x = x + moe_block(lp, "moe", cfg, h, pctx)
        else:
            x = x + swiglu(h, lp["mlp.w_gate"], lp["mlp.w_up"], lp["mlp.w_down"], cfg)
    return x


def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    if cfg.remat_policy == "save_coll":
        return jax.checkpoint_policies.save_only_these_names("moe_a2a")
    return jax.checkpoint_policies.nothing_saveable


def _run_blocks(cfg, pctx, x, blk, positions, *, scan_layers: bool, remat: bool):
    body = functools.partial(_super_block, cfg, pctx)
    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(cfg))
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    n_sb = cfg.num_layers // me
    if scan_layers:
        def scan_body(carry, layer_p):
            return body(carry, layer_p, positions), None
        x, _ = jax.lax.scan(scan_body, x, blk)
    else:
        for i in range(n_sb):
            x = body(x, jax.tree.map(lambda a: a[i], blk), positions)
    return x


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    pctx: ParallelContext,
    tokens: jax.Array,                       # (B, S_text)
    *,
    prefix_embeds: Optional[jax.Array] = None,  # (B, Nv, d) vlm/audio stubs
    scan_layers: bool = True,
) -> jax.Array:
    """Returns logits (B, S_total, V)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    blk, rest = _split_block_params(params)
    x = _run_blocks(cfg, pctx, x, blk, positions,
                    scan_layers=scan_layers, remat=cfg.remat)
    x = rms_norm(x, rest["final_norm"] + 1.0, cfg.norm_eps)
    return _lm_head(params, rest, cfg, x)


# ---------------------------------------------------------------------------
# Serving: prefill (build cache) + single-token decode.
# ---------------------------------------------------------------------------


def init_cache_abstract(cfg: ModelConfig, batch: int, max_seq: int):
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    n_sb = cfg.num_layers // me
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (n_sb, me, batch, max_seq, hkv, dh)
    return {
        "k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
        "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_abstract(cfg, batch, max_seq))


def lm_decode_step(
    params: Params,
    cfg: ModelConfig,
    pctx: ParallelContext,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,        # (B, 1)
    lengths: jax.Array,       # (B,)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x = jnp.take(params["embed"], tokens, axis=0)
    blk, rest = _split_block_params(params)
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1

    def scan_body(carry, xs):
        x = carry
        blk_p, kc_blk, vc_blk = xs
        new_k, new_v = [], []
        for j in range(me):
            lp = {k[len(f"blk.{j}."):]: v for k, v in blk_p.items()
                  if k.startswith(f"blk.{j}.")}
            h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
            attn_out, k_new, v_new = attention_decode(
                lp, "attn", cfg, h, kc_blk[j], vc_blk[j], lengths
            )
            new_k.append(k_new)
            new_v.append(v_new)
            x = x + attn_out
            h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
            if cfg.num_experts and j == me - 1:
                x = x + moe_block(lp, "moe", cfg, h, pctx)
            else:
                x = x + swiglu(h, lp["mlp.w_gate"], lp["mlp.w_up"], lp["mlp.w_down"], cfg)
        return x, (jnp.stack(new_k), jnp.stack(new_v))

    if cfg.scan_layers:
        x, (k_upd, v_upd) = jax.lax.scan(scan_body, x, (blk, cache["k"], cache["v"]))
    else:  # unrolled (cost-extrapolation dry-run compiles)
        n_sb = cfg.num_layers // me
        ys = []
        for i in range(n_sb):
            x, y = scan_body(x, jax.tree.map(lambda a: a[i],
                                             (blk, cache["k"], cache["v"])))
            ys.append(y)
        k_upd = jnp.stack([y[0] for y in ys])
        v_upd = jnp.stack([y[1] for y in ys])
    x = rms_norm(x, rest["final_norm"] + 1.0, cfg.norm_eps)
    logits = _lm_head(params, rest, cfg, x)
    return logits, {"k": k_upd, "v": v_upd}


# ---------------------------------------------------------------------------
# Paged serving: shared page pool + block tables instead of (B, max_seq).
# ---------------------------------------------------------------------------


def init_paged_cache_abstract(cfg: ModelConfig, pool_pages: int,
                              page_size: int, kv_dtype: str = "bfloat16"):
    """Per-layer KV page pools.  Unlike :func:`init_cache_abstract` there is
    no batch axis: slots own disjoint page subsets via block tables (one
    int32 table shared by every layer), so total KV memory scales with the
    *live* token count, not slots x max_seq.

    ``kv_dtype="int8"`` halves the pool footprint vs bf16: pages hold int8
    payloads and two extra fp32 scale pools carry one symmetric scale per
    (page slot, kv head) — written together with the payload so a slot is
    always self-consistent (see ``docs/quantization.md``)."""
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    n_sb = cfg.num_layers // me
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (n_sb, me, pool_pages, page_size, hkv, dh)
    if kv_dtype == "int8":
        sshape = shape[:-1]
        return {
            "k": jax.ShapeDtypeStruct(shape, jnp.int8),
            "v": jax.ShapeDtypeStruct(shape, jnp.int8),
            "k_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
            "v_scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
        }
    return {
        "k": jax.ShapeDtypeStruct(shape, jnp.dtype(kv_dtype)),
        "v": jax.ShapeDtypeStruct(shape, jnp.dtype(kv_dtype)),
    }


def init_paged_cache(cfg: ModelConfig, pool_pages: int, page_size: int,
                     kv_dtype: str = "bfloat16"):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_paged_cache_abstract(cfg, pool_pages, page_size,
                                                  kv_dtype))


def lm_decode_paged(
    params: Params,
    cfg: ModelConfig,
    pctx: ParallelContext,
    cache: Dict[str, jax.Array],
    tokens: jax.Array,        # (B, T) new tokens; T=1 decode, T=chunk prefill
    lengths: jax.Array,       # (B,) tokens already cached per slot
    new_counts: jax.Array,    # (B,) real new tokens this call (<= T)
    block_tables: jax.Array,  # (B, P_max)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Multi-token decode/prefill step over the paged cache.

    Returns logits ``(B, T, V)`` — the caller reads row ``new_counts[b]-1``
    of slot ``b`` for the next-token distribution and ignores padded rows.

    Three callers share this one contract: the decode tick (``T = 1``),
    chunked prefill (``T = prefill_chunk``), and speculative verification
    (``T = spec_k + 1``, ``repro.spec``) — a verify step is just a "prefill
    chunk" of candidate tokens whose logits are *all* read back (row ``i``
    is the target's next-token distribution after candidate ``i``), with the
    rejected suffix rolled back host-side via ``PagedKVCache.truncate``.
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    blk, rest = _split_block_params(params)
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1
    quantized_kv = "k_scale" in cache  # int8 page pools carry scale pools

    def scan_body(carry, xs):
        x = carry
        if quantized_kv:
            blk_p, kc_blk, vc_blk, ks_blk, vs_blk = xs
        else:
            blk_p, kc_blk, vc_blk = xs
        new = []
        for j in range(me):
            lp = {k[len(f"blk.{j}."):]: v for k, v in blk_p.items()
                  if k.startswith(f"blk.{j}.")}
            h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
            scales = ({"k_scales": ks_blk[j], "v_scales": vs_blk[j]}
                      if quantized_kv else {})
            attn_out, *upd = attention_decode_paged(
                lp, "attn", cfg, h, kc_blk[j], vc_blk[j],
                lengths, new_counts, block_tables, **scales
            )
            new.append(upd)
            x = x + attn_out
            h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
            if cfg.num_experts and j == me - 1:
                x = x + moe_block(lp, "moe", cfg, h, pctx)
            else:
                x = x + swiglu(h, lp["mlp.w_gate"], lp["mlp.w_up"], lp["mlp.w_down"], cfg)
        # transpose [per-layer][field] -> per-field stacks (k, v[, ks, vs])
        return x, tuple(jnp.stack([u[f] for u in new])
                        for f in range(len(new[0])))

    if quantized_kv:
        xs = (blk, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
    else:
        xs = (blk, cache["k"], cache["v"])
    if cfg.scan_layers:
        x, upd = jax.lax.scan(scan_body, x, xs)
    else:
        n_sb = cfg.num_layers // me
        ys = []
        for i in range(n_sb):
            x, y = scan_body(x, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        upd = tuple(jnp.stack([y[f] for y in ys]) for f in range(len(ys[0])))
    x = rms_norm(x, rest["final_norm"] + 1.0, cfg.norm_eps)
    logits = _lm_head(params, rest, cfg, x)
    new_cache = {"k": upd[0], "v": upd[1]}
    if quantized_kv:
        new_cache["k_scale"], new_cache["v_scale"] = upd[2], upd[3]
    return logits, new_cache


def lm_prefill(
    params: Params,
    cfg: ModelConfig,
    pctx: ParallelContext,
    tokens: jax.Array,         # (B, S)
    max_seq: Optional[int] = None,
    prefix_embeds: Optional[jax.Array] = None,
    scan_layers: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Forward pass that also returns the populated KV cache."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    max_seq = max_seq or s
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    blk, rest = _split_block_params(params)
    me = max(cfg.moe_every, 1) if cfg.num_experts else 1

    from .layers import project_qkv, gqa_scores_attend, tp_einsum

    def scan_body(carry, blk_p):
        x = carry
        ks, vs = [], []
        for j in range(me):
            lp = {k[len(f"blk.{j}."):]: v for k, v in blk_p.items()
                  if k.startswith(f"blk.{j}.")}
            h = rms_norm(x, lp["ln1"] + 1.0, cfg.norm_eps)
            q, k, v = project_qkv(lp, "attn", cfg, h, positions)
            mask = jnp.tril(jnp.ones((s, s), bool))[None, None, None]
            o = gqa_scores_attend(q, k, v, mask)
            x = x + tp_einsum("btk,kd->btd", o, lp["attn.wo"])
            pad = max_seq - s
            ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16))
            vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(jnp.bfloat16))
            h = rms_norm(x, lp["ln2"] + 1.0, cfg.norm_eps)
            if cfg.num_experts and j == me - 1:
                x = x + moe_block(lp, "moe", cfg, h, pctx)
            else:
                x = x + swiglu(h, lp["mlp.w_gate"], lp["mlp.w_up"], lp["mlp.w_down"], cfg)
        return x, (jnp.stack(ks), jnp.stack(vs))

    if scan_layers:
        x, (k_all, v_all) = jax.lax.scan(scan_body, x, blk)
    else:
        n_sb = cfg.num_layers // me
        outs = []
        for i in range(n_sb):
            x, o = scan_body(x, jax.tree.map(lambda a: a[i], blk))
            outs.append(o)
        k_all = jnp.stack([o[0] for o in outs])
        v_all = jnp.stack([o[1] for o in outs])
    x = rms_norm(x, rest["final_norm"] + 1.0, cfg.norm_eps)
    logits = _lm_head(params, rest, cfg, x[:, -1:])
    return logits, {"k": k_all, "v": v_all}
