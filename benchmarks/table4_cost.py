"""Paper Table IV analogue: mechanism cost.

The paper synthesises both cores on an FPGA (RV64R vs baseline: -1.76% LUT,
+1.63% FF — the APR is one 32-bit register + muxes).  The TPU analogue of
'area overhead' is the VMEM budget the APR mechanism claims: the fp32
accumulator tile per kernel instance vs the ~128 MiB VMEM budget, and vs
the working set the baseline residency would re-stream from HBM instead.
"""
import time

from repro.core.apr import AccumulatorSpec
from repro.roofline import hw


KERNELS = [
    ("apr_matmul 128x128", AccumulatorSpec((128, 128)), "one MXU output tile"),
    ("apr_matmul 256x256", AccumulatorSpec((256, 256)), "4-tile superblock"),
    ("flash_decode G=8,D=128", AccumulatorSpec((8, 130)), "m,l,acc per group"),
    ("rwkv6 state D=64", AccumulatorSpec((64, 64)), "per-head decay state"),
    ("mamba2 state P=64,N=64", AccumulatorSpec((64, 64)), "per-head SSD state"),
]


def run(csv=False):
    rows = []
    t0 = time.time()
    if not csv:
        print(f"{'kernel accumulator':26s} {'APR bytes':>10s} {'% of VMEM':>10s}  role")
        print(f"{'paper FPGA overhead':26s} {'LUT -1.76%, FF +1.63% (one 32-bit APR)':>10s}")
    for name, spec, role in KERNELS:
        frac = 100.0 * spec.bytes / hw.VMEM_BYTES
        if not csv:
            print(f"{name:26s} {spec.bytes:10,} {frac:9.3f}%  {role}")
        rows.append(f"table4.{name.split()[0]},{(time.time()-t0)*1e6:.0f},"
                    f"bytes={spec.bytes};vmem_pct={frac:.4f}")
    return rows
