"""Serving benchmark: chunked prefill vs token-by-token, paged vs slot.

Drives the same request trace through three engine configurations —

* ``paged_chunked``  — PagedServeEngine, prefill_chunk > 1 (the production
  configuration: one multi-token ``decode_paged`` call per chunk),
* ``paged_token``    — PagedServeEngine, prefill_chunk = 1 (token-by-token
  prefill over the *same* paged cache: isolates the chunking win from the
  paging change),
* ``slot``           — the contiguous-cache seed engine (prefills through
  the decode path; timed with the same wall clock for reference)

— and writes ``BENCH_serve.json`` (schema in benchmarks/README.md).  The
headline number is prefill tokens/s: chunked prefill amortises one model
invocation over ``prefill_chunk`` prompt tokens, so it must beat the
token-by-token loop.

A fourth section, ``prefix_cache``, drives a shared-system-prompt workload
(every prompt = one common head + a per-request tail) through the paged
engine with ``prefix_sharing`` off and on: outputs must stay token-identical
and the sharing run reports its **effective-KV-capacity multiplier** —
logical prompt pages admitted per physical page materialized (the gate
requires >= 2x; with sharing off the same workload sits at ~1x).

    PYTHONPATH=src python benchmarks/bench_serve.py --quick
"""
import argparse
import datetime
import json
import sys
import time
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
for _p in (str(_REPO / "src"), str(_REPO / "benchmarks")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from _serve_common import request_trace as _trace  # noqa: E402
from _serve_common import shared_prefix_trace, warm_engine  # noqa: E402

SCHEMA_VERSION = 2

#: the prefix-cache gate: the shared-prompt workload must show at least
#: this effective-KV-capacity multiplier with sharing on
MIN_KV_MULTIPLIER = 2.0


def _run_paged(bundle, params, pctx, reqs, *, slots, page_size, prefill_chunk):
    from repro.serve import PagedServeEngine
    eng = PagedServeEngine(bundle, params, pctx, slots=slots,
                           page_size=page_size, prefill_chunk=prefill_chunk)
    # warm the jit caches (prefill-chunk + decode shapes) so the timed trace
    # measures steady-state serving, not XLA compilation
    warm_engine(eng, prompt_len=prefill_chunk + 1)
    for r in reqs:
        eng.submit(r)
    m = eng.run_until_drained()
    out = m.summary()
    out["outputs"] = [r.output for r in reqs]
    return out


def _run_slot(bundle, params, pctx, reqs, *, slots, max_seq):
    from repro.serve import ServeEngine
    eng = ServeEngine(bundle, params, pctx, slots=slots, max_seq=max_seq)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in reqs)
    return {"elapsed_s": round(dt, 4), "total_tokens": total,
            "tokens_per_s": round(total / max(dt, 1e-9), 2),
            "outputs": [r.output for r in reqs]}


def _run_prefix_cache(bundle, params, pctx, *, requests, shared_len,
                      unique_len, max_new, slots, page_size, prefill_chunk):
    """Shared-system-prompt workload, sharing off vs on, same trace."""
    from repro.serve import PagedServeEngine

    def run(sharing):
        eng = PagedServeEngine(bundle, params, pctx, slots=slots,
                               page_size=page_size,
                               prefill_chunk=prefill_chunk,
                               prefix_sharing=sharing)
        warm_engine(eng, prompt_len=prefill_chunk + 1)
        reqs = shared_prefix_trace(requests, shared_len, unique_len, max_new)
        for r in reqs:
            eng.submit(r)
        m = eng.run_until_drained()
        return [r.output for r in reqs], m

    out_off, m_off = run(sharing=False)
    out_on, m_on = run(sharing=True)
    return {
        "workload": {"requests": requests, "shared_len": shared_len,
                     "unique_len": unique_len, "max_new": max_new},
        "outputs_identical": out_on == out_off,
        "effective_kv_multiplier": round(m_on.effective_kv_multiplier, 3),
        "effective_kv_multiplier_off": round(m_off.effective_kv_multiplier,
                                             3),
        "prompt_pages_logical": m_on.prompt_pages_logical,
        "prompt_pages_unique": m_on.prompt_pages_unique,
        "unique_pages_per_request": round(
            m_on.prompt_pages_unique / max(requests, 1), 3),
        "prefix_hit_requests": m_on.prefix_hit_requests,
        "prefix_hit_tokens": m_on.prefix_hit_tokens,
        "cow_copies": m_on.cow_copies,
        "prefill_tokens_on": m_on.prefill_tokens,
        "prefill_tokens_off": m_off.prefill_tokens,
        "min_kv_multiplier": MIN_KV_MULTIPLIER,
    }


def bench(*, arch: str, requests: int, prompt_len: int, max_new: int,
          slots: int, page_size: int, prefill_chunk: int,
          prefix_requests: int, shared_len: int, unique_len: int):
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.parallel.sharding import ParallelContext

    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    pctx = ParallelContext(None)

    chunked = _run_paged(bundle, params, pctx,
                         _trace(requests, prompt_len, max_new),
                         slots=slots, page_size=page_size,
                         prefill_chunk=prefill_chunk)
    token = _run_paged(bundle, params, pctx,
                       _trace(requests, prompt_len, max_new),
                       slots=slots, page_size=page_size, prefill_chunk=1)
    slot = _run_slot(bundle, params, pctx,
                     _trace(requests, prompt_len, max_new),
                     slots=slots, max_seq=max(128, prompt_len + max_new + 2))

    prefix = _run_prefix_cache(bundle, params, pctx,
                               requests=prefix_requests,
                               shared_len=shared_len, unique_len=unique_len,
                               max_new=max_new, slots=slots,
                               page_size=page_size,
                               prefill_chunk=prefill_chunk)

    identical = (chunked.pop("outputs") == token.pop("outputs")
                 == slot.pop("outputs"))
    speedup = chunked["prefill_tps"] / max(token["prefill_tps"], 1e-9)
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "arch": arch,
        "workload": {"requests": requests, "prompt_len": prompt_len,
                     "max_new": max_new, "slots": slots,
                     "page_size": page_size, "prefill_chunk": prefill_chunk},
        "engines": {"paged_chunked": chunked, "paged_token": token,
                    "slot": slot},
        "prefix_cache": prefix,
        "outputs_identical": identical,
        "prefill_chunk_speedup": round(speedup, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized trace (fewer/shorter requests)")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--out", default=str(_REPO / "BENCH_serve.json"))
    args = ap.parse_args()

    defaults = ((4, 24, 8) if args.quick else (8, 64, 16))
    requests = args.requests or defaults[0]
    prompt_len = args.prompt_len or defaults[1]
    max_new = args.max_new or defaults[2]
    # prefix-cache workload: the shared head spans whole pages so the gate
    # reflects page-level dedup, the unique tail spans one page
    ps = args.page_size
    prefix_requests, shared_len, unique_len = \
        ((6, 3 * ps, ps) if args.quick else (8, 4 * ps, ps))

    report = bench(arch=args.arch, requests=requests, prompt_len=prompt_len,
                   max_new=max_new, slots=args.slots,
                   page_size=args.page_size,
                   prefill_chunk=min(args.prefill_chunk, prompt_len),
                   prefix_requests=prefix_requests, shared_len=shared_len,
                   unique_len=unique_len)
    Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")

    e = report["engines"]
    p = report["prefix_cache"]
    print(f"wrote {args.out} (backend={report['backend']}, "
          f"outputs_identical={report['outputs_identical']})")
    print(f"  prefill tok/s: chunked={e['paged_chunked']['prefill_tps']:.1f}  "
          f"token-by-token={e['paged_token']['prefill_tps']:.1f}  "
          f"speedup={report['prefill_chunk_speedup']:.2f}x")
    print(f"  decode tok/s:  chunked={e['paged_chunked']['decode_tps']:.1f}  "
          f"ttft p50: {e['paged_chunked']['p50_ttft_s']}s vs "
          f"{e['paged_token']['p50_ttft_s']}s token-by-token")
    print(f"  prefix cache: effective-KV x{p['effective_kv_multiplier']:.2f}"
          f" (off: x{p['effective_kv_multiplier_off']:.2f})  "
          f"{p['prompt_pages_logical']} logical / "
          f"{p['prompt_pages_unique']} unique pages  "
          f"hits={p['prefix_hit_requests']}/"
          f"{p['workload']['requests']} req  cow={p['cow_copies']}")
    failed = False
    if not report["outputs_identical"]:
        print("FAIL: the three engine configurations emitted different "
              "tokens for the same trace", file=sys.stderr)
        failed = True
    if not p["outputs_identical"]:
        print("FAIL: prefix sharing changed the shared-prompt workload's "
              "tokens (must be identical to sharing off)", file=sys.stderr)
        failed = True
    if p["effective_kv_multiplier"] < MIN_KV_MULTIPLIER:
        print(f"FAIL: effective KV multiplier "
              f"{p['effective_kv_multiplier']:.2f}x < "
              f"{MIN_KV_MULTIPLIER}x gate on the shared-prompt workload",
              file=sys.stderr)
        failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
