"""Speculative-decoding benchmark: draft-and-verify vs the plain paged engine.

Drives one request trace through the plain ``PagedServeEngine`` and through
``SpeculativeServeEngine`` (``repro.spec``) at several K (drafted tokens
verified per step) with both proposers — the n-gram self-draft and, when a
draft config is registered for the arch, the paired draft model — and
writes ``BENCH_spec.json`` (schema in benchmarks/README.md).

Two things are measured per configuration:

* **Correctness** (the CI gate): speculative greedy outputs must be
  token-identical to the plain engine's for every request at every K — the
  process exits non-zero otherwise.
* **Throughput**: decode tokens/s *including draft time*
  (``spec_decode_tps``) against the plain engine's ``decode_tps``, plus the
  acceptance rate and tokens emitted per verify step that explain it.  The
  headline (``best_speedup``) is the best ratio across configurations; the
  ISSUE-4 acceptance bar is >= 1.5x at some K.

Engines are warmed with a throwaway request before the timed trace so XLA
compilation is excluded (same protocol as bench_serve.py).

    PYTHONPATH=src python benchmarks/bench_spec.py --quick
"""
import argparse
import datetime
import json
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
for _p in (str(_REPO / "src"), str(_REPO / "benchmarks")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from _serve_common import request_trace, warm_engine  # noqa: E402

SCHEMA_VERSION = 1


def _drain(eng, make_trace, *, warm_prompt_len, warm_max_new, reps,
           tps_key="decode_tps"):
    """Warm the engine's jit shapes with a throwaway request, then run the
    timed trace ``reps`` times (fresh metrics per rep, same compiled fns)
    and keep the rep with the median ``tps_key`` — the host this runs on is
    shared, so single-shot wall-clock throughput is noisy while the
    tick/token counts are deterministic."""
    from repro.serve import EngineMetrics
    warm_engine(eng, prompt_len=warm_prompt_len, max_new=warm_max_new)
    outs = []
    for _ in range(reps):
        eng.metrics = EngineMetrics()
        reqs = make_trace()
        for r in reqs:
            eng.submit(r)
        m = eng.run_until_drained()
        out = m.summary()
        out["outputs"] = [r.output for r in reqs]
        outs.append(out)
    # .get: a trace whose requests all finish during prefill never runs a
    # verify step, so the speculative summary fields are absent
    outs.sort(key=lambda o: o.get(tps_key, 0.0))
    return outs[len(outs) // 2]


def bench(*, arch: str, requests: int, prompt_len: int, max_new: int,
          slots: int, page_size: int, prefill_chunk: int, ks,
          with_model_draft: bool, reps: int):
    import jax

    from repro.configs import get_config, get_draft_config
    from repro.models import build_draft_model, build_model
    from repro.parallel.sharding import ParallelContext
    from repro.serve import PagedServeEngine
    from repro.spec import NgramDraft, SpeculativeServeEngine

    cfg = get_config(arch, smoke=True)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.PRNGKey(0))
    pctx = ParallelContext(None)
    engine_kw = dict(slots=slots, page_size=page_size,
                     prefill_chunk=prefill_chunk)
    warm = dict(warm_prompt_len=prefill_chunk + 1, warm_max_new=4, reps=reps)
    make_trace = lambda: request_trace(requests, prompt_len, max_new)  # noqa: E731

    plain = PagedServeEngine(bundle, params, pctx, **engine_kw)
    plain_out = _drain(plain, make_trace, **warm)
    reference = plain_out["outputs"]

    draft_cfg = get_draft_config(arch, smoke=True) if with_model_draft else None
    draft_bundle = draft_params = None
    if draft_cfg is not None:
        draft_bundle = build_draft_model(cfg, draft_cfg)
        draft_params = draft_bundle.init_params(jax.random.PRNGKey(1))

    engines = {"plain": plain_out}
    per_k = []
    identical = True
    configs = [("ngram", k) for k in ks]
    if draft_bundle is not None:
        # the paired draft model rides at the largest K (its per-step cost
        # is K draft forwards, so that is where pairing pays or hurts most)
        configs += [("model", max(ks))]
    for kind, k in configs:
        if kind == "ngram":
            eng = SpeculativeServeEngine(
                bundle, params, pctx, spec_k=k, draft=NgramDraft(),
                **engine_kw)
        else:
            eng = SpeculativeServeEngine(
                bundle, params, pctx, spec_k=k, draft_bundle=draft_bundle,
                draft_params=draft_params, **engine_kw)
        out = _drain(eng, make_trace, tps_key="spec_decode_tps", **warm)
        same = out["outputs"] == reference
        identical = identical and same
        name = f"spec_{kind}_k{k}"
        engines[name] = out
        per_k.append({
            "engine": name,
            "draft": kind,
            "k": k,
            "acceptance_rate": out.get("acceptance_rate", 0.0),
            "tokens_per_step": out.get("tokens_per_step", 0.0),
            "spec_decode_tps": out.get("spec_decode_tps", 0.0),
            "speedup_vs_plain": round(
                out.get("spec_decode_tps", 0.0)
                / max(plain_out["decode_tps"], 1e-9), 3),
            "outputs_identical": same,
        })

    for out in engines.values():
        out.pop("outputs")
    best = max((row["speedup_vs_plain"] for row in per_k), default=0.0)
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "arch": arch,
        "draft_arch": draft_cfg.name if draft_cfg is not None else None,
        "workload": {"requests": requests, "prompt_len": prompt_len,
                     "max_new": max_new, "slots": slots,
                     "page_size": page_size, "prefill_chunk": prefill_chunk,
                     "ks": list(ks), "reps": reps},
        "engines": engines,
        "per_k": per_k,
        "outputs_identical": identical,
        "best_speedup": best,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized trace (fewer/shorter requests, fewer K)")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--ks", type=int, nargs="+", default=None,
                    help="spec_k values to sweep")
    ap.add_argument("--reps", type=int, default=3,
                    help="trace repetitions per engine; the median-"
                         "throughput rep is reported (noisy shared hosts)")
    ap.add_argument("--no-model-draft", action="store_true",
                    help="skip the paired-draft-model configuration")
    ap.add_argument("--out", default=str(_REPO / "BENCH_spec.json"))
    args = ap.parse_args()

    defaults = ((6, 16, 48, (2, 4)) if args.quick else (8, 32, 64, (2, 4, 8)))
    requests = args.requests or defaults[0]
    prompt_len = args.prompt_len or defaults[1]
    max_new = args.max_new or defaults[2]
    ks = tuple(args.ks) if args.ks else defaults[3]

    report = bench(arch=args.arch, requests=requests, prompt_len=prompt_len,
                   max_new=max_new, slots=args.slots,
                   page_size=args.page_size,
                   prefill_chunk=min(args.prefill_chunk, prompt_len),
                   ks=ks, with_model_draft=not args.no_model_draft,
                   reps=max(1, args.reps))
    Path(args.out).write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")

    print(f"wrote {args.out} (backend={report['backend']}, "
          f"outputs_identical={report['outputs_identical']})")
    print(f"  plain decode tok/s: {report['engines']['plain']['decode_tps']:.1f}")
    for row in report["per_k"]:
        print(f"  {row['engine']:>14}: acceptance={row['acceptance_rate']:.0%} "
              f"tokens/step={row['tokens_per_step']:.2f} "
              f"decode tok/s={row['spec_decode_tps']:.1f} "
              f"({row['speedup_vs_plain']:.2f}x)")
    print(f"  best speedup: {report['best_speedup']:.2f}x")
    if not report["outputs_identical"]:
        print("FAIL: speculative greedy outputs differ from the plain paged "
              "engine", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
