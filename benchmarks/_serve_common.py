"""Shared request-trace + engine-warm protocol for the serving-side
benchmark drivers (``bench_serve.py`` / ``bench_quant.py`` /
``bench_spec.py``).

All three drivers must measure the *same* workload shape under the *same*
steady-state protocol for their numbers to be comparable — a driver that
warmed differently would silently time XLA compilation or a different
trace.  Keeping the trace builder and the warm step here makes a protocol
change a one-place edit.
"""


def request_trace(n_requests: int, prompt_len: int, max_new: int):
    """The canonical benchmark trace: per-request unique first token, then
    a period-7 repeating prompt body."""
    from repro.serve import Request
    return [Request(rid=i,
                    prompt=[1 + i] + [2 + (j % 7) for j in range(prompt_len - 1)],
                    max_new_tokens=max_new)
            for i in range(n_requests)]


def shared_prefix_trace(n_requests: int, shared_len: int, unique_len: int,
                        max_new: int):
    """Shared-system-prompt workload: every request carries the same
    ``shared_len``-token head (the content prefix sharing can dedupe)
    followed by a ``unique_len``-token per-request tail."""
    from repro.serve import Request
    head = [2 + (j % 7) for j in range(shared_len)]
    return [Request(rid=i, prompt=head + [100 + i] * unique_len,
                    max_new_tokens=max_new)
            for i in range(n_requests)]


def warm_engine(eng, *, prompt_len: int, max_new: int = 2) -> None:
    """Run one throwaway request through ``eng`` so the timed trace
    measures steady-state serving (jit caches for the prefill-chunk,
    decode, and — on a speculative engine — verify shapes are all
    populated), then reset the metrics."""
    from repro.serve import EngineMetrics, Request
    eng.submit(Request(rid=-1, prompt=[1] * prompt_len,
                       max_new_tokens=max_new))
    eng.run_until_drained()
    eng.metrics = EngineMetrics()
