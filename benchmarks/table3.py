"""Paper Table III reproduction: 3 DNNs x 3 ISAs x 5 metrics + enhancement
percentages, ours vs published."""
import time

from repro.core.isa import Isa
from repro.core.simulate import enhancement, simulate_model

PAPER = {
    ("lenet", "RV64F"): (0.066, 44_310_154, 0.666, 19_288_578, 23_071_838),
    ("lenet", "Baseline"): (0.048, 35_792_547, 0.740, 16_043_778, 19_841_884),
    ("lenet", "RV64R"): (0.032, 27_010_675, 0.847, 12_045_594, 15_449_482),
    ("resnet20", "RV64F"): (6.210, 4_103_496_569, 0.661, 1_795_154_166, 2_103_847_934),
    ("resnet20", "Baseline"): (4.413, 3_246_429_938, 0.736, 1_468_652_534, 1_736_203_748),
    ("resnet20", "RV64R"): (2.691, 2_352_965_745, 0.874, 1_062_330_923, 1_289_180_424),
    ("mobilenet_v1", "RV64F"): (7.035, 4_923_965_486, 0.700, 2_130_037_330, 2_599_414_994),
    ("mobilenet_v1", "Baseline"): (5.255, 4_122_177_959, 0.784, 1_824_588_370, 2_222_467_107),
    ("mobilenet_v1", "RV64R"): (3.720, 3_307_689_859, 0.889, 1_453_124_800, 1_813_851_904),
}

PAPER_ENH = {  # (runtime%, IC%, IPC%, mem%, L1%) RV64R over base
    ("lenet", "RV64F"): (52.05, 39.04, 27.13, 37.55, 33.04),
    ("lenet", "Baseline"): (34.05, 24.54, 14.43, 24.92, 22.14),
    ("resnet20", "RV64F"): (56.66, 42.66, 32.30, 40.82, 38.72),
    ("resnet20", "Baseline"): (39.02, 27.52, 18.85, 27.67, 25.75),
    ("mobilenet_v1", "RV64F"): (47.12, 32.82, 27.04, 31.78, 30.22),
    ("mobilenet_v1", "Baseline"): (29.21, 19.76, 13.34, 20.36, 18.39),
}


def run(csv=False):
    rows = []
    t0 = time.time()
    table = {}
    for model in ("lenet", "resnet20", "mobilenet_v1"):
        for isa in Isa:
            m = simulate_model(model, isa)
            table[(model, isa)] = m
            p = PAPER[(model, isa.pretty)]
            rows.append(
                f"table3.{model}.{isa.value},{(time.time()-t0)*1e6/9:.0f},"
                f"rt={m.runtime_s:.4f}/{p[0]};IC={m.instructions}/{p[1]};"
                f"IPC={m.ipc:.3f}/{p[2]};mem={m.mem_instrs}/{p[3]};"
                f"L1={m.l1_accesses}/{p[4]}"
            )
    if not csv:
        print(f"{'model':13s} {'ISA':9s} {'rt(s)':>14s} {'IC':>24s} "
              f"{'IPC':>13s} {'mem':>24s} {'L1':>24s}   (ours/paper)")
        for (model, isa), m in table.items():
            p = PAPER[(model, isa.pretty)]
            print(f"{model:13s} {isa.pretty:9s} "
                  f"{m.runtime_s:6.3f}/{p[0]:<6.3f} "
                  f"{m.instructions:>11,}/{p[1]:<11,} "
                  f"{m.ipc:5.3f}/{p[2]:<5.3f} "
                  f"{m.mem_instrs:>11,}/{p[3]:<11,} "
                  f"{m.l1_accesses:>11,}/{p[4]:<11,}")
        print("\nEnhancements of RV64R (ours vs paper):")
        for model in ("lenet", "resnet20", "mobilenet_v1"):
            for base in (Isa.RV64F, Isa.BASELINE):
                e = enhancement(table[(model, base)], table[(model, Isa.RV64R)])
                pe = PAPER_ENH[(model, base.pretty)]
                print(f"  {model:13s} over {base.pretty:9s} "
                      f"rt {e['runtime']:5.1f}%/{pe[0]:<6.2f} "
                      f"IC {e['IC']:5.1f}%/{pe[1]:<6.2f} "
                      f"IPC {e['IPC']:5.1f}%/{pe[2]:<6.2f} "
                      f"mem {e['mem_instrs']:5.1f}%/{pe[3]:<6.2f} "
                      f"L1 {e['l1_accesses']:5.1f}%/{pe[4]:<6.2f}")
    return rows
