"""Unified kernel benchmark driver: sweep, validate, record, self-gate.

Runs the ``repro.bench`` autotuner over every registered kernel family and
emits ``BENCH_kernels.json`` — per (kernel, shape, dtype): the best
validated :class:`BlockConfig`, median us/call (+ the min-max sample
spread), analytic GFLOP/s, the analytic HBM traffic at that config (the
Table-III 'memory access' analogue, via
:func:`repro.core.apr.reduction_hbm_traffic`), and the ``repro.cost``
prediction for the winner.  The JSON schema (v2) is documented in
``benchmarks/README.md``.

With pruning on (the default) every shape is swept twice: exhaustively,
then with cost-model pruning (only the predicted-cheapest K candidates are
timed).  The run **gates itself**: it exits non-zero unless (i) the pruned
sweep picks the exhaustive winner for every shape — literally the same
config, a predicted tie within 1%, or a measured time within the recorded
timer spreads — and (ii) the pruned sweeps time >= 2x fewer candidates in
aggregate.  Per-family predicted-vs-measured error lands in the report, so
the analytic model is re-validated against the very sweep it prunes on
every CI run.  ``--no-prune`` reverts to the single exhaustive sweep.

Usage::

    python benchmarks/bench_kernels.py --quick            # tiny shapes, CI
    python benchmarks/bench_kernels.py                    # full suite
    python benchmarks/bench_kernels.py --out /tmp/b.json --cache /tmp/tc.json

Off-TPU the kernels run in Pallas interpret mode, so absolute times are a
correctness-path proxy (the ``backend`` field records this — interpret-mode
``prediction_error`` is similarly a proxy; on TPU it measures the model);
relative ordering still exercises the full tune/prune/cache plumbing.
Tuned winners also land in the shared config cache, so every later
``repro.kernels`` call site picks them up automatically.
"""
import argparse
import datetime
import json
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
if str(_REPO / "src") not in sys.path:
    sys.path.insert(0, str(_REPO / "src"))

SCHEMA_VERSION = 2

#: pruned sweeps must time at least this factor fewer candidates than the
#: exhaustive sweeps, summed over the whole suite (the CI gate)
PRUNE_SPEEDUP_GATE = 2.0

# Per-family benchmark shapes.  quick: small enough for interpret-mode CI;
# full: LM-layer-sized geometries (run these on real hardware).
SUITES = {
    "quick": {
        "apr_matmul": [{"m": 64, "k": 128, "n": 64}],
        "apr_matmul_fused": [{"m": 64, "k": 128, "n": 64}],
        "quant_matmul": [{"m": 64, "k": 128, "n": 64}],
        "quant_matmul_fused": [{"m": 64, "k": 128, "n": 64}],
        "apr_conv": [{"b": 1, "h": 8, "w": 8, "c": 4, "hf": 3, "wf": 3,
                      "m": 8, "stride": 1, "padding": 1}],
        "apr_conv_fused": [{"b": 1, "h": 8, "w": 8, "c": 4, "hf": 3, "wf": 3,
                            "m": 8, "stride": 1, "padding": 1}],
        "flash_decode": [{"b": 2, "hq": 4, "hkv": 2, "d": 32, "s": 128}],
        "flash_decode_paged": [{"b": 2, "hq": 4, "hkv": 2, "d": 32,
                                "pages": 4, "ps": 32},
                               {"b": 2, "hq": 4, "hkv": 2, "d": 32,
                                "pages": 4, "ps": 32, "kv_int8": 1}],
        "mamba2": [{"b": 1, "t": 32, "h": 2, "p": 8, "n": 8}],
        "rwkv6": [{"b": 1, "t": 32, "h": 2, "d": 8}],
    },
    "full": {
        "apr_matmul": [
            {"m": 256, "k": 512, "n": 256},
            {"m": 512, "k": 2048, "n": 512},
        ],
        "apr_matmul_fused": [
            {"m": 256, "k": 512, "n": 256},
            {"m": 512, "k": 2048, "n": 512},
        ],
        "quant_matmul": [
            {"m": 256, "k": 512, "n": 256},
            {"m": 512, "k": 2048, "n": 512},
        ],
        "quant_matmul_fused": [
            {"m": 256, "k": 512, "n": 256},
        ],
        "apr_conv": [
            # LeNet conv2-sized im2col (the paper's benchmark operator)
            {"b": 4, "h": 14, "w": 14, "c": 6, "hf": 5, "wf": 5,
             "m": 16, "stride": 1, "padding": 0},
        ],
        "apr_conv_fused": [
            {"b": 4, "h": 14, "w": 14, "c": 6, "hf": 5, "wf": 5,
             "m": 16, "stride": 1, "padding": 0},
        ],
        "flash_decode": [
            {"b": 4, "hq": 8, "hkv": 4, "d": 64, "s": 1024},
        ],
        "flash_decode_paged": [
            {"b": 4, "hq": 8, "hkv": 4, "d": 64, "pages": 8, "ps": 128},
            {"b": 4, "hq": 8, "hkv": 4, "d": 64, "pages": 8, "ps": 128,
             "kv_int8": 1},
        ],
        "mamba2": [
            {"b": 2, "t": 256, "h": 4, "p": 32, "n": 16},
        ],
        "rwkv6": [
            {"b": 2, "t": 256, "h": 4, "d": 32},
        ],
    },
}


def prune_top_k(n_candidates: int) -> int:
    """How many predicted-cheapest candidates a pruned sweep times: half
    the space, capped at 3 (large full-suite spaces), floored at 1."""
    return max(1, min(n_candidates // 2, 3))


def _configs_match(res_pruned, res_exhaustive, predicted_us) -> str:
    """'' when the pruned sweep's pick agrees with the exhaustive one,
    else a reason string.  Agreement = same config, a cost-model tie
    (predictions within 1% — either is a legitimate winner), or measured
    times within the two runs' recorded timer spreads (interpret-mode
    timing noise, not a mis-ranking)."""
    if not res_pruned.ok or not res_exhaustive.ok:
        return "a sweep found no valid config"
    if res_pruned.config == res_exhaustive.config:
        return ""
    p_pr = predicted_us.get(res_pruned.config)
    p_ex = predicted_us.get(res_exhaustive.config)
    if p_pr is not None and p_ex is not None \
            and abs(p_pr - p_ex) <= 0.01 * max(p_pr, p_ex):
        return ""
    if abs(res_pruned.us - res_exhaustive.us) \
            <= res_pruned.spread_us + res_exhaustive.spread_us:
        return ""
    return (f"pruned pick {res_pruned.config.to_dict()} ({res_pruned.us:.1f}"
            f"us) vs exhaustive {res_exhaustive.config.to_dict()} "
            f"({res_exhaustive.us:.1f}us) beyond spread")


def bench_all(*, quick: bool = False, dtype: str = "float32",
              cache_path=None, iters=None, warmup=None,
              max_candidates=None, prune: bool = True):
    import jax

    from repro.bench import ConfigCache, all_specs, autotune, default_cache
    from repro.cost import get_profile, rank_candidates

    cache = ConfigCache(cache_path) if cache_path else default_cache()
    suite = SUITES["quick" if quick else "full"]
    if quick and max_candidates is None:
        max_candidates = 4

    report = {
        "schema_version": SCHEMA_VERSION,
        "generated_at": datetime.datetime.now().isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "mode": "quick" if quick else "full",
        "dtype": dtype,
        "profile": get_profile().name,
        "kernels": {},
        "prediction_error": {},
    }
    timed_exhaustive = 0
    timed_pruned = 0
    parity_failures = []
    for name, spec in sorted(all_specs().items()):
        entries = []
        family_errs = []
        for shape in suite.get(name, []):
            kw = dict(dtype=dtype, cache=cache, iters=iters, warmup=warmup,
                      max_candidates=max_candidates)
            if prune:
                res_ex = autotune(spec, shape, **kw)
                cands = spec.candidates(shape)[:max_candidates]
                k = prune_top_k(len(cands))
                res = autotune(spec, shape, prune_top_k=k, **kw)
                predicted = {cfg: est.predicted_us for cfg, est
                             in rank_candidates(spec, shape, cands)}
                timed_exhaustive += res_ex.n_timed
                timed_pruned += res.n_timed
                mismatch = _configs_match(res, res_ex, predicted)
                if mismatch:
                    parity_failures.append(f"{name}/{res.shape_key}: "
                                           f"{mismatch}")
                pruning = {
                    "match": not mismatch,
                    "timed": res.n_timed,
                    "timed_exhaustive": res_ex.n_timed,
                    "exhaustive_config": (res_ex.config.to_dict()
                                          if res_ex.ok else None),
                    "exhaustive_us": round(res_ex.us, 2)
                    if res_ex.ok else None,
                }
            else:
                res = autotune(spec, shape, **kw)
                pruning = None
            if res.ok and res.predicted_us is not None:
                family_errs.append(abs(res.predicted_us - res.us)
                                   / max(res.us, 1e-9))
            entries.append({
                "shape": dict(shape),
                "shape_key": res.shape_key,
                "dtype": res.dtype,
                "best_config": res.config.to_dict() if res.ok else None,
                "us_per_call": round(res.us, 2) if res.ok else None,
                "spread_us": round(res.spread_us, 2) if res.ok else None,
                "predicted_us": (round(res.predicted_us, 4)
                                 if res.predicted_us is not None else None),
                "gflops": round(res.gflops, 4) if res.ok else None,
                "hbm_bytes_analytic": res.hbm_bytes,
                "n_candidates": res.n_candidates,
                "n_timed": res.n_timed,
                "pruned_from": res.pruned_from,
                "n_rejected": len(res.rejected),
                "pruning": pruning,
            })
        report["kernels"][name] = entries
        if family_errs:
            report["prediction_error"][name] = round(
                sum(family_errs) / len(family_errs), 4)
    if prune:
        speedup = timed_exhaustive / max(timed_pruned, 1)
        report["pruning_gate"] = {
            "timed_exhaustive": timed_exhaustive,
            "timed_pruned": timed_pruned,
            "speedup": round(speedup, 3),
            "speedup_required": PRUNE_SPEEDUP_GATE,
            "config_parity": not parity_failures,
            "parity_failures": parity_failures,
            "passed": (not parity_failures
                       and speedup >= PRUNE_SPEEDUP_GATE - 1e-9),
        }
    return report


def run(csv: bool = False, quick: bool = True):
    """benchmarks/run.py integration: quick sweep, CSV row per kernel."""
    report = bench_all(quick=quick)
    rows = []
    for name, entries in sorted(report["kernels"].items()):
        for e in entries:
            if e["best_config"] is None:
                continue
            cfg = "/".join(f"{k}={v}" for k, v in sorted(e["best_config"].items()))
            rows.append(f"bench_kernels.{name}.{e['shape_key']},"
                        f"{e['us_per_call']:.2f},"
                        f"gflops={e['gflops']};cfg={cfg}")
            if not csv:
                print(f"{name:14s} {e['shape_key']:32s} {e['us_per_call']:10.1f}us "
                      f"{e['gflops']:8.3f} GF/s  {cfg}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes + pruned candidate list (CI-sized)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--out", default=str(_REPO / "BENCH_kernels.json"),
                    help="report path (default: repo-root BENCH_kernels.json)")
    ap.add_argument("--cache", default=None,
                    help="tuned-config cache path (default: $REPRO_TUNE_CACHE "
                         "or ~/.cache/repro/tune_cache.json)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timed reps per candidate (default: "
                         "$REPRO_BENCH_ITERS or 3)")
    ap.add_argument("--max-candidates", type=int, default=None)
    ap.add_argument("--no-prune", dest="prune", action="store_false",
                    help="single exhaustive sweep: no cost-model pruning, "
                         "no predicted-vs-measured gate")
    args = ap.parse_args()

    report = bench_all(quick=args.quick, dtype=args.dtype,
                       cache_path=args.cache, iters=args.iters,
                       max_candidates=args.max_candidates, prune=args.prune)
    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")

    n = sum(len(v) for v in report["kernels"].values())
    print(f"wrote {out} ({n} entries, backend={report['backend']}, "
          f"mode={report['mode']}, profile={report['profile']})")
    for name, entries in sorted(report["kernels"].items()):
        for e in entries:
            status = (f"{e['us_per_call']:.1f}us {e['gflops']:.3f} GF/s "
                      f"cfg={e['best_config']}"
                      if e["best_config"] is not None else "NO VALID CONFIG")
            if e["pruned_from"]:
                status += (f"  [timed {e['n_timed']}/{e['pruned_from']}, "
                           f"predicted {e['predicted_us']}us]")
            print(f"  {name:14s} {e['shape_key']:36s} {status}")
    gate = report.get("pruning_gate")
    if gate is not None:
        print(f"pruning gate: timed {gate['timed_pruned']} vs "
              f"{gate['timed_exhaustive']} exhaustive "
              f"({gate['speedup']:.2f}x >= {gate['speedup_required']:.1f}x), "
              f"config parity: {gate['config_parity']}")
        for f in gate["parity_failures"]:
            print(f"  PARITY FAIL {f}")
        if not gate["passed"]:
            sys.exit(1)


if __name__ == "__main__":
    main()
